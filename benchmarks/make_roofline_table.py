"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results/dryrun JSONs.

  PYTHONPATH=src python benchmarks/make_roofline_table.py [--mesh 16x16]
"""
import argparse
import glob
import json
import os


def load(results_dir):
    recs = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b:.0f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--consensus", action="store_true")
    args = ap.parse_args()

    recs = [
        r for r in load(args.results)
        if r["mesh"] == args.mesh and bool(r.get("consensus")) == args.consensus
    ]
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    recs.sort(key=lambda r: (r["arch"], shapes.index(r["shape"]) if r["shape"] in shapes else 9))

    print("| arch | shape | policy | compute s | memory s | collective s | dominant "
          "| MODEL/HLO flops | bytes/dev | coll bytes/dev | temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP: {r.get('reason','')[:40]} | — | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | — | — | — | — |")
            continue
        rf = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        temp = r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        print(
            f"| {r['arch']} | {r['shape']} | {r.get('policy','tp')} "
            f"| {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | {rf['collective_s']:.3f} "
            f"| **{rf['dominant'][:-2]}** "
            f"| {'' if ratio is None else format(ratio, '.2f')} "
            f"| {fmt_bytes(r['bytes_per_device'])} "
            f"| {fmt_bytes(r['collective_bytes']['total'])} "
            f"| {temp:.1f} |"
        )


if __name__ == "__main__":
    main()

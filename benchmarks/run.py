"""Benchmark harness — one function per paper table/figure + system benches.

Paper artifacts (reduced-scale synthetic reproductions; repro band 2/5 —
orderings are the claim, not absolute CIFAR numbers):
  table1_noniid       — §5.1 / Table 1: non-IID Dirichlet, fixed lr/epochs
  table2_async        — §5.2 / Table 2: IID, heterogeneous lr_i/e_i (43)-(44)
  fig6_combined       — §9 / Fig 6: non-IID + heterogeneous, larger model
System benches:
  consensus_step      — fused Pallas kernel vs jnp reference (µs/call)
  gamma_kernel        — Γ kernel vs reference
  adaptive_overhead   — Algorithm-1 substeps/backtracks per round vs δ
  engine              — sequential vs vectorized vs event vs sharded vs
                        event_buffered (fully-asynchronous K-trigger
                        server) execution backend rounds/sec at n_clients ∈
                        {10, 100, 1000} on 8 forced host devices, with a
                        per-algorithm axis (--algorithms, names from the
                        fed/algorithms registry; event rows are flow-only)
                        plus an n=10^4 heavy-traffic buffered cell and the
                        sparse client-cache cells (n=10^4 q=0.01,
                        n=10^5 q=0.001); persists BENCH_engine.json
                        (schema v6)
  scenarios           — a reduced algorithms × heterogeneity-scenarios
                        matrix through launch/sweep.py (the full
                        committed BENCH_scenarios.json is produced by
                        ``python -m repro.launch.sweep`` directly)
  roofline_summary    — per (arch x shape) terms from results/dryrun JSONs

Prints ``name,us_per_call,derived`` CSV rows; the engine bench additionally
writes a machine-readable JSON report (schema in tests/test_bench_engine.py).
"""
from __future__ import annotations

import glob
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# shared small-model federated setup
# ---------------------------------------------------------------------------


def _mlp_problem(dim=32, classes=10, n=2048, seed=0, hidden=48):
    from repro.data import make_classification

    data = make_classification(n, dim=dim, n_classes=classes, seed=seed)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    params0 = {
        "w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
        "b0": jnp.zeros((hidden,)),
        "w1": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
        "b1": jnp.zeros((classes,)),
    }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    return data, params0, loss_fn, eval_fn


def _run_algorithms(data, params0, loss_fn, eval_fn, parts, rounds, hetero, seed):
    from repro.core import ConsensusConfig
    from repro.fed import FedSim, FedSimConfig, last_finite_loss
    from repro.fed.algorithms import comparison_algorithms

    out = {}
    for alg in comparison_algorithms():
        cfg = FedSimConfig(
            algorithm=alg, n_clients=len(parts), participation=0.2,
            rounds=rounds, batch_size=32, steps_per_epoch=5,
            epochs_fixed=2, lr_fixed=1e-2,
            hetero=hetero, seed=seed, eval_every=rounds,
            # L tuned on the table-1 config (see EXPERIMENTS.md §Paper-validation)
            consensus=ConsensusConfig(L=0.01),
        )
        t0 = time.time()
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        out[alg] = {
            "acc": hist.metrics[-1]["acc"],
            # nan-aware: the event backend marks all-busy rounds with nan
            "loss": last_finite_loss(hist.loss),
            "wall_s": time.time() - t0,
        }
    return out


def table1_noniid(rounds=40, seed=0):
    """Paper Table 1: non-IID Dir(0.1), fixed client lr/epochs."""
    from repro.fed import dirichlet_partition

    data, params0, loss_fn, eval_fn = _mlp_problem(seed=seed)
    parts = dirichlet_partition(data["y"], 25, alpha=0.1, seed=seed)
    t0 = time.time()
    res = _run_algorithms(data, params0, loss_fn, eval_fn, parts, rounds, None, seed)
    derived = ";".join(f"{k}_acc={v['acc']:.3f}" for k, v in res.items())
    _row("table1_noniid_dirichlet", (time.time() - t0) * 1e6, derived)
    return res


def table2_async(rounds=40, seed=0):
    """Paper Table 2: IID data, heterogeneous lr_i/e_i (eqs. 43-44, scaled
    for the synthetic problem)."""
    from repro.fed import HeteroConfig, iid_partition

    data, params0, loss_fn, eval_fn = _mlp_problem(seed=seed)
    parts = iid_partition(len(data["y"]), 25, seed=seed)
    het = HeteroConfig(1e-3, 1e-2, 1, 5)
    t0 = time.time()
    res = _run_algorithms(data, params0, loss_fn, eval_fn, parts, rounds, het, seed)
    derived = ";".join(f"{k}_acc={v['acc']:.3f}" for k, v in res.items())
    _row("table2_async_hetero", (time.time() - t0) * 1e6, derived)
    return res


def fig6_combined(rounds=40, seed=0):
    """Paper Fig. 6: non-IID AND heterogeneous computation, bigger model."""
    from repro.fed import HeteroConfig, dirichlet_partition

    data, params0, loss_fn, eval_fn = _mlp_problem(
        dim=48, classes=10, n=4096, hidden=96, seed=seed
    )
    parts = dirichlet_partition(data["y"], 25, alpha=0.1, seed=seed)
    het = HeteroConfig(1e-3, 1e-2, 1, 5)
    t0 = time.time()
    res = _run_algorithms(data, params0, loss_fn, eval_fn, parts, rounds, het, seed)
    derived = ";".join(f"{k}_acc={v['acc']:.3f}" for k, v in res.items())
    _row("fig6_combined_hetero", (time.time() - t0) * 1e6, derived)
    return res


def ablation_ecado(rounds=60, seed=0):
    """§4 motivation ablation: plain ECADO (full participation, uniform
    gains, synchronous Γ) vs FedECADO vs FedECADO-without-gains, under
    non-IID + heterogeneous clients — isolates the two contributions."""
    from repro.core import ConsensusConfig
    from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition

    data, params0, loss_fn, eval_fn = _mlp_problem(seed=seed)
    parts = dirichlet_partition(data["y"], 25, alpha=0.1, seed=seed)
    het = HeteroConfig(1e-3, 1e-2, 1, 5)
    out = {}
    for label, alg, hetero in (
        ("fedecado", "fedecado", het),
        ("ecado_fullpart_sync", "ecado", None),   # ECADO needs synchronous clients
    ):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=25, participation=0.2, rounds=rounds,
            batch_size=32, steps_per_epoch=5, epochs_fixed=2, lr_fixed=1e-2,
            hetero=hetero, seed=seed, eval_every=rounds,
            consensus=ConsensusConfig(L=0.01),
        )
        t0 = time.time()
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        out[label] = {"acc": hist.metrics[-1]["acc"], "wall_s": time.time() - t0}
    derived = ";".join(f"{k}_acc={v['acc']:.3f}" for k, v in out.items())
    _row("ablation_ecado_vs_fedecado", sum(v["wall_s"] for v in out.values()) * 1e6, derived)
    return out


# ---------------------------------------------------------------------------
# system µbenches
# ---------------------------------------------------------------------------


def consensus_step_bench(A=16, D=1 << 16):
    from repro.kernels.ops import fused_consensus_step

    rng = np.random.RandomState(0)
    tree = {"w": jnp.asarray(rng.randn(D), jnp.float32)}
    st = lambda s: {"w": jnp.asarray(rng.randn(A, D) * s, jnp.float32)}
    Sf = {"w": jnp.zeros((D,), jnp.float32)}
    T = jnp.asarray(rng.uniform(0.01, 0.1, A), jnp.float32)
    gi = jnp.asarray(rng.uniform(0.05, 0.2, A), jnp.float32)
    dt, tau = jnp.float32(0.02), jnp.float32(0.01)
    I_a, J_a, xn = st(0.1), st(0.1), st(1.0)
    xp = {"w": jnp.broadcast_to(tree["w"][None], (A, D))}  # synchronous anchors

    for use_kernel, name in ((True, "pallas_interpret"), (False, "jnp_ref")):
        fn = jax.jit(
            lambda xc, Sf, I, J, xp, xn, T, gi, uk=use_kernel: fused_consensus_step(
                xc, Sf, I, J, xp, xn, T, gi, dt, tau, 1.0, use_kernel=uk
            )
        )
        us = _timeit(fn, tree, Sf, I_a, J_a, xp, xn, T, gi, iters=10)
        gb = (A * D * 3 + 2 * D) * 4 / 1e9
        _row(f"consensus_step_{name}_A{A}_D{D}", us,
             f"traffic={gb:.3f}GB;GBps={gb / (us / 1e6):.1f}")


def gamma_kernel_bench(A=16, D=1 << 16):
    from repro.kernels.ops import gamma_op

    rng = np.random.RandomState(0)
    x_c = {"w": jnp.asarray(rng.randn(D), jnp.float32)}
    xn = {"w": jnp.asarray(rng.randn(A, D), jnp.float32)}
    T = jnp.asarray(rng.uniform(0.01, 0.1, A), jnp.float32)
    for use_kernel, name in ((True, "pallas_interpret"), (False, "jnp_ref")):
        fn = jax.jit(partial(gamma_op, use_kernel=use_kernel))
        us = _timeit(fn, x_c, xn, T, jnp.float32(0.05), iters=10)
        _row(f"gamma_{name}_A{A}_D{D}", us)


def adaptive_overhead_bench():
    """Algorithm-1 cost: substeps + backtracks per round vs δ."""
    from repro.core import ConsensusConfig, init_server_state, server_round, set_gains

    n, dim, A = 16, 256, 4
    rng = np.random.RandomState(0)
    state = init_server_state({"w": jnp.zeros((dim,))}, n)
    state = set_gains(state, jnp.full((n,), 0.05))
    xn = {"w": jnp.asarray(rng.randn(A, dim), jnp.float32)}
    T = jnp.asarray(rng.uniform(0.02, 0.1, A), jnp.float32)
    idx = jnp.arange(A, dtype=jnp.int32)
    for delta in (1e-2, 1e-3, 1e-4):
        ccfg = ConsensusConfig(delta=delta, max_substeps=64)
        fn = jax.jit(lambda s, x, t, i, c=ccfg: server_round(s, x, t, i, c))
        t0 = time.perf_counter()
        new_state, stats = fn(state, xn, T, idx)
        jax.block_until_ready(new_state.x_c["w"])
        us = (time.perf_counter() - t0) * 1e6
        _row(
            f"adaptive_dt_delta{delta:g}", us,
            f"substeps={int(stats.n_substeps)};backtracks={int(stats.n_backtracks)};"
            f"final_dt={float(stats.final_dt):.4g}",
        )


# v4: rows gain compile_seconds (warm-up minus steady-state wall) and the
# shared-telemetry solver/async columns (substeps_per_round, waves_per_round,
# stale, dropped) from the timed run's RunHistory
# v5: adds the event_buffered backend axis (fully-asynchronous buffered
# server on the flight table: K-trigger drains at K = cohort/2 with
# staleness-weighted merges), a max_stale column on every row, and the
# heavy_traffic section (sustained buffered rounds/sec at n=10^4 under the
# Poisson-arrival scenario, with the bounded max-staleness witness)
# v6: every row gains participation (cohort fraction; 1.0 on the dense
# cells), peak_state_bytes (resident per-client state via
# repro.sim.cache.state_nbytes — deterministic accounting, gated at 2x
# growth by repro.tune.gate) and state_rows (leading-axis length of the
# per-client arrays: cache capacity when the client-state cache is on,
# else n); adds the sparse client-cache cells (n=10^4 q=0.01 and
# n=10^5 q=0.001, fedecado on the sharded backend) where per-round state
# scales with the cohort instead of the population, each carrying its
# materialized-projection witness
ENGINE_BENCH_SCHEMA_VERSION = 6


def _heavy_traffic_cell(rounds=20, n=10_000, buffer_size=16, batch=8):
    """Sustained buffered-server throughput under the ``heavy-traffic``
    arrival scenario: n clients, Poisson endpoint arrivals, K-trigger
    drains with staleness-weighted merges — the fully-asynchronous regime
    where cohort sizes vary per round and no round barrier exists. The
    dataset is sized so every client holds >= batch samples (uniform batch
    shape keeps the stacked segment jit-resident)."""
    from repro.fed import FedSim, FedSimConfig, last_finite_loss

    data, params0, loss_fn, _ = _mlp_problem(n=n * 2 * batch, seed=0)
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=n, participation=1.0,
        rounds=rounds, batch_size=batch, steps_per_epoch=1,
        hetero=None, seed=0, eval_every=1 << 30, backend="event",
        scenario="heavy-traffic", event_buffered=True,
        event_buffer_size=buffer_size,
    )
    warm = FedSim(loss_fn, params0, data, None, cfg)
    tw = time.perf_counter()
    warm.run(rounds)
    warm_wall = time.perf_counter() - tw
    sim = FedSim(loss_fn, params0, data, None, cfg)
    sim.backend = warm.backend        # keep the warmed jit caches
    t0 = time.perf_counter()
    hist = sim.run(rounds)
    wall = time.perf_counter() - t0
    summ = hist.summary()
    row = {
        "scenario": "heavy-traffic",
        "algorithm": "fedecado",
        "n_clients": int(n),
        "rounds": int(rounds),
        "buffer_size": int(buffer_size),
        "stale_gamma": float(cfg.event_stale_gamma),
        "rounds_per_sec": float(rounds / wall),
        "compile_seconds": max(0.0, warm_wall - wall),
        "waves_per_round": float(summ.get("waves_per_round", 0.0)),
        "stale": int(summ.get("stale", 0)),
        "dropped": int(summ.get("dropped", 0)),
        "final_loss": last_finite_loss(hist.loss),
        "max_stale": int(getattr(sim.backend, "max_stale", 0) or 0),
    }
    _row(
        f"engine_heavy_traffic_n{n}", 1e6 * wall / rounds,
        f"rps={row['rounds_per_sec']:.3f};K={buffer_size};"
        f"max_stale={row['max_stale']};stale={row['stale']}",
    )
    return row


def _sparse_cell(n, participation, rounds=8, batch=4, algorithm="fedecado",
                 backend="sharded"):
    """Million-client-regime witness: participation q << 1 with the
    client-state cache on (sim/cache.py, DESIGN.md §13). Per-round state
    scales with the DISTINCT participants seen so far — ``state_rows`` is
    the packed capacity, and ``peak_state_bytes`` sits orders of magnitude
    below the materialized projection (the same arrays with leading axis
    n). The dataset gives every client exactly ``batch`` samples so the
    population-sized objects are the partitions and the cohort plans, both
    cohort-streamed."""
    from repro.fed import FedSim, FedSimConfig, iid_partition, last_finite_loss
    from repro.sim.cache import state_nbytes

    data, params0, loss_fn, _ = _mlp_problem(n=n * batch, seed=0)
    parts = iid_partition(len(data["y"]), n, seed=0)
    cfg = FedSimConfig(
        algorithm=algorithm, n_clients=n, participation=participation,
        rounds=rounds, batch_size=batch, steps_per_epoch=1,
        hetero=None, seed=0, eval_every=1 << 30, backend=backend,
        client_cache=True,
    )
    warm = FedSim(loss_fn, params0, data, parts, cfg)
    tw = time.perf_counter()
    warm.run(rounds)
    warm_wall = time.perf_counter() - tw
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    sim.backend = warm.backend        # keep the warmed jit caches (the
    # fresh cache retraces the warm run's capacity trajectory — same seed,
    # same admissions — so every segment shape is already compiled)
    t0 = time.perf_counter()
    hist = sim.run(rounds)
    wall = time.perf_counter() - t0
    state_bytes = int(state_nbytes(sim))
    state_rows = int(sim.state_rows)
    # the same arrays with the cache off: leading axis n instead of the
    # packed capacity (row count dominates; scalar slack is negligible)
    projected = int(round(state_bytes * (n / max(1, state_rows))))
    summ = hist.summary()
    row = {
        "algorithm": algorithm,
        "backend": backend,
        "n_clients": int(n),
        "participation": float(participation),
        "client_cache": True,
        "rounds_per_sec": float(rounds / wall),
        "compile_seconds": max(0.0, warm_wall - wall),
        "substeps_per_round": float(summ.get("substeps_per_round", 0.0)),
        "waves_per_round": float(summ.get("waves_per_round", 0.0)),
        "stale": int(summ.get("stale", 0)),
        "dropped": int(summ.get("dropped", 0)),
        "max_stale": int(getattr(sim.backend, "max_stale", 0) or 0),
        "peak_state_bytes": state_bytes,
        "state_rows": state_rows,
        "materialized_state_bytes": projected,
        "final_loss": last_finite_loss(hist.loss),
    }
    ratio = projected / max(1, state_bytes)
    _row(
        f"engine_sparse_{algorithm}_n{n}_q{participation:g}",
        1e6 * wall / rounds,
        f"rps={row['rounds_per_sec']:.3f};state_rows={state_rows};"
        f"state_bytes={state_bytes};materialized_x={ratio:.0f}",
    )
    return row


def engine_bench(
    rounds=10,
    sizes=(10, 100, 1000),
    backends=("sequential", "vectorized", "event", "sharded",
              "event_buffered"),
    algorithms=("fedecado",),
    json_path="BENCH_engine.json",
    heavy_traffic=None,
    sparse=None,
):
    """Multi-rate execution engine: sequential (one jit dispatch per client,
    the seed hot path) vs vectorized (whole cohort in one vmap-over-scan
    dispatch) vs event (the device-resident flight-table scheduler at
    horizon_quantile=1.0, whole segments jit-resident) vs sharded (the
    cohort shard_map-ed across every local device with psum consensus
    reductions and the whole multi-round segment jit-resident) rounds/sec —
    full participation, heterogeneous e_i/lr_i in the cross-device regime
    (many clients, small local batches) where Python-bound per-round
    dispatch dominates the seed hot path.

    ``algorithms`` adds a per-algorithm axis (any names from the
    fed/algorithms registry — ``--algorithms fedecado,fednova,fedadmm``),
    so the flow-consensus and weighted-delta aggregation paths can be
    compared on the same cohort shapes. The event backend only schedules
    flow dynamics, so event rows exist only for algorithms whose plugin
    declares ``has_flow_dynamics``.

    The ``event_buffered`` backend is the event scheduler in
    fully-asynchronous buffered-server mode (K = cohort/2 endpoints
    trigger each staleness-weighted aggregation — no round barrier), and
    ``heavy_traffic`` (a kwargs dict for ``_heavy_traffic_cell``) appends
    the sustained n=10^4 Poisson-arrival cell with its bounded
    max-staleness witness.

    ``sparse`` (a tuple of ``(n, participation)`` cells) appends the
    client-cache rows where state_rows tracks the cohort, not n — the
    n=10^5 q=0.001 cell is the million-client-engine acceptance witness.

    Emits the usual CSV rows AND persists a machine-readable
    ``BENCH_engine.json`` (algorithm × backend × n_clients × participation
    → rounds/sec + compile_seconds + peak_state_bytes + solver/async
    telemetry columns; schema v6, pinned by tests/test_bench_engine.py).
    Returns the report dict. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (main() sets it
    for ``--only engine``) to give the sharded backend a real device axis.
    """
    import jax as _jax

    from repro.fed import FedSim, FedSimConfig, HeteroConfig, iid_partition
    from repro.fed.algorithms import get_algorithm
    from repro.sim.cache import state_nbytes

    assert algorithms, "engine_bench needs at least one algorithm"
    for a in algorithms:           # fail fast, before any warm-up work
        get_algorithm(a)

    data, params0, loss_fn, _ = _mlp_problem(n=16384, dim=32, classes=10, seed=0)

    def make_cfg(n, backend, algorithm):
        # "event_buffered" is the event backend in fully-asynchronous
        # buffered-server mode: K = cohort/2 endpoints trigger each
        # staleness-weighted aggregation instead of the round barrier
        buffered = backend == "event_buffered"
        return FedSimConfig(
            algorithm=algorithm, n_clients=n, participation=1.0,
            rounds=rounds, batch_size=8, steps_per_epoch=1,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 5), seed=0,
            eval_every=1 << 30, backend="event" if buffered else backend,
            event_buffered=buffered,
            event_buffer_size=max(1, n // 2) if buffered else 0,
        )

    # the report's config block is derived from the ACTUAL benched config so
    # the persisted JSON can never drift from the measurement
    cfg0 = make_cfg(sizes[0], backends[0], algorithms[0])
    report = {
        "schema_version": ENGINE_BENCH_SCHEMA_VERSION,
        "benchmark": "engine",
        "n_devices": len(_jax.devices()),
        "rounds": int(rounds),
        "sizes": [int(n) for n in sizes],
        "backends": list(backends),
        "algorithms": list(algorithms),
        "config": {
            "participation": cfg0.participation,
            "batch_size": cfg0.batch_size,
            "steps_per_epoch": cfg0.steps_per_epoch,
            "epochs_range": [cfg0.hetero.epochs_min, cfg0.hetero.epochs_max],
            "lr_range": [cfg0.hetero.lr_min, cfg0.hetero.lr_max],
            "seed": cfg0.seed,
            "event_horizon": cfg0.event_horizon,
            "event_max_waves": cfg0.event_max_waves,
            "event_stale_gamma": cfg0.event_stale_gamma,
            # the buffered axis triggers at K = n_clients // 2
            "event_buffered_k": "n_clients//2",
        },
        "results": [],
    }
    for n in sizes:
        parts = iid_partition(len(data["y"]), n, seed=0)
        for algorithm in algorithms:
            rps = {}
            for backend in backends:
                if (backend in ("event", "event_buffered")
                        and not get_algorithm(algorithm).has_flow_dynamics):
                    continue       # the event scheduler is flow-only
                cfg = make_cfg(n, backend, algorithm)
                # warm-up covers every jit variant the timed run will hit
                # (for the sharded backend that includes the R=rounds
                # segment shape), then a fresh sim SHARING the warmed
                # backend is timed
                warm = FedSim(loss_fn, params0, data, parts, cfg)
                tw = time.perf_counter()
                warm.run(rounds)
                warm_wall = time.perf_counter() - tw
                if backend == "sequential":
                    # prime the batch-shape jit variants the warm-up rounds
                    # happened not to draw
                    from repro.sim import CohortPlan

                    h = cfg.hetero
                    for e in range(h.epochs_min, h.epochs_max + 1):
                        ns = e * cfg.steps_per_epoch
                        warm.backend.run_cohort(warm, CohortPlan(
                            rnd=-1, idx=np.asarray([0]),
                            lrs=np.asarray([1e-3], np.float32),
                            epochs=np.asarray([e]), n_steps=np.asarray([ns]),
                            batch_idx=[np.zeros((ns, cfg.batch_size), np.int64)],
                        ))
                sim = FedSim(loss_fn, params0, data, parts, cfg)
                sim.backend = warm.backend       # keep the warmed jit caches
                t0 = time.perf_counter()
                hist = sim.run(rounds)
                timed_wall = time.perf_counter() - t0
                rps[backend] = rounds / timed_wall
                # compile cost ≈ cold warm-up wall minus the steady-state
                # wall the timed run just measured (recorded separately so
                # rounds/sec stays a pure steady-state number)
                summ = hist.summary()
                report["results"].append({
                    "algorithm": algorithm,
                    "backend": backend,
                    "n_clients": int(n),
                    "participation": float(cfg.participation),
                    "rounds_per_sec": float(rps[backend]),
                    "compile_seconds": max(0.0, warm_wall - timed_wall),
                    "substeps_per_round": float(summ.get("substeps_per_round", 0.0)),
                    "waves_per_round": float(summ.get("waves_per_round", 0.0)),
                    "stale": int(summ.get("stale", 0)),
                    "dropped": int(summ.get("dropped", 0)),
                    # buffered-mode staleness witness (event backend only;
                    # 0 on barrier backends by construction)
                    "max_stale": int(getattr(sim.backend, "max_stale", 0) or 0),
                    # resident per-client state (materialized here: the
                    # dense cells run cache-off, so rows == n) — the
                    # tune/gate 2x-growth memory floor
                    "peak_state_bytes": int(state_nbytes(sim)),
                    "state_rows": int(sim.state_rows),
                })
            base = rps.get("sequential", next(iter(rps.values())))
            derived = ";".join(f"{b}_rps={v:.3f}" for b, v in rps.items())
            if "vectorized" in rps and "sharded" in rps:
                derived += (
                    f";sharded_vs_vectorized="
                    f"{rps['sharded'] / rps['vectorized']:.2f}x"
                )
            _row(f"engine_round_us_{algorithm}_n{n}", 1e6 / base, derived)
    if heavy_traffic:
        report["heavy_traffic"] = _heavy_traffic_cell(**heavy_traffic)
    if sparse:
        report["sparse_cells"] = [
            {"n_clients": int(n), "participation": float(q)}
            for n, q in sparse
        ]
        for n, q in sparse:
            report["results"].append(_sparse_cell(n, q))
    if json_path:
        from repro.tune.bench_io import write_bench_report

        write_bench_report(report, json_path)
        print(f"# wrote {json_path}", flush=True)
    return report


# v1: accuracy-vs-bytes frontier rows (algorithm × scenario × compressor ×
# level → final acc + measured bytes_up/bytes_down totals + ratios vs the
# lossless baseline row), a per-family bytes-monotonicity witness (higher
# compression level → strictly fewer bytes_up), and the dirichlet01
# acceptance criterion block (>= 95% of the uncompressed accuracy at
# <= 25% of its uplink bytes)
COMM_BENCH_SCHEMA_VERSION = 1

# (compressor, level) grid: the lossless baseline first, then the quantizer
# tiers and the top-k keep-fraction tiers; forbidden compressor × algorithm
# combos (topk × flow dynamics) are skipped per row, mirroring the engine
# bench's flow-only event rows
COMM_SETTINGS = (
    (None, None),
    ("int8", None),
    ("int4", None),
    ("topk", 1),
    ("topk", 2),
)


def comm_bench(
    rounds=30,
    clients=10,
    participation=0.4,
    scenarios=("dirichlet01", "feature-shift"),
    algorithms=("fedecado", "fedprox", "fednova"),
    settings=COMM_SETTINGS,
    json_path="BENCH_comm.json",
    seed=0,
):
    """Accuracy-vs-bytes frontier for the repro/comm wire models: every
    (algorithm × scenario) trains once per compressor setting on the
    vectorized backend, and the row records the measured telemetry bytes
    totals next to final accuracy. FedECADO compresses its consensus
    endpoints EF-free (flow family); FedProx/FedNova carry error-feedback
    residuals, and additionally admit top-k sparsification (refused for
    flow dynamics — ``repro.comm.check_algorithm``). Persists
    ``BENCH_comm.json`` (schema v1, pinned by tests/test_bench_comm.py)."""
    from repro.comm import check_algorithm, get_compressor
    from repro.core import ConsensusConfig
    from repro.fed import FedSim, FedSimConfig, last_finite_loss
    from repro.fed.algorithms import get_algorithm

    # validate names + levels against the registry before any cell runs
    for name, level in settings:
        if name is not None:
            get_compressor(name)(level)
    for a in algorithms:
        get_algorithm(a)

    data, params0, loss_fn, eval_fn = _mlp_problem(seed=seed)
    report = {
        "schema_version": COMM_BENCH_SCHEMA_VERSION,
        "benchmark": "comm",
        "rounds": int(rounds),
        "clients": int(clients),
        "participation": float(participation),
        "scenarios": list(scenarios),
        "algorithms": list(algorithms),
        "settings": [
            {"compress": n or "identity", "level": level}
            for n, level in settings
        ],
        "config": {
            "batch_size": 32,
            "steps_per_epoch": 5,
            "lr_fixed": 1e-2,
            "epochs_fixed": 2,
            "consensus_L": 0.01,
            "backend": "vectorized",
            "seed": int(seed),
        },
        "results": [],
    }

    for scenario in scenarios:
        for algorithm in algorithms:
            base = None
            for name, level in settings:
                if name is not None:
                    try:
                        check_algorithm(name, get_algorithm(algorithm))
                    except ValueError:
                        continue   # forbidden combo (topk × flow dynamics)
                cfg = FedSimConfig(
                    algorithm=algorithm, n_clients=clients,
                    participation=participation, rounds=rounds,
                    batch_size=32, steps_per_epoch=5, lr_fixed=1e-2,
                    epochs_fixed=2, hetero=None, seed=1000 + seed,
                    eval_every=rounds, backend="vectorized",
                    scenario=scenario, compress=name, compress_level=level,
                    consensus=ConsensusConfig(L=0.01),
                )
                t0 = time.time()
                sim = FedSim(loss_fn, params0, data, None, cfg, eval_fn)
                hist = sim.run()
                summ = hist.summary()
                row = {
                    "algorithm": algorithm,
                    "scenario": scenario,
                    "compress": name or "identity",
                    "level": None if level is None else int(level),
                    "acc": float(hist.metrics[-1]["acc"]),
                    "final_loss": last_finite_loss(hist.loss),
                    "bytes_up": int(summ["bytes_up"]),
                    "bytes_down": int(summ["bytes_down"]),
                    "wall_s": float(time.time() - t0),
                }
                if name is None:
                    base = row
                # ratios vs the lossless baseline row of the same
                # (algorithm, scenario) — the frontier coordinates
                row["bytes_ratio"] = row["bytes_up"] / base["bytes_up"]
                row["acc_ratio"] = (
                    row["acc"] / base["acc"] if base["acc"] > 0 else 0.0
                )
                report["results"].append(row)
                _row(
                    f"comm_{scenario}_{algorithm}_{row['compress']}"
                    + ("" if level is None else f"_l{level}"),
                    row["wall_s"] * 1e6,
                    f"acc={row['acc']:.3f};bytes_ratio={row['bytes_ratio']:.3f};"
                    f"acc_ratio={row['acc_ratio']:.3f}",
                )

    # -- bytes monotonicity: within a family, a higher compression tier
    # must measure strictly fewer uplink bytes on the same cell
    families = (("topk", [("topk", 1), ("topk", 2)]),
                ("quant", [("int8", None), ("int4", None)]))
    rows_by = {
        (r["algorithm"], r["scenario"], r["compress"], r["level"]): r
        for r in report["results"]
    }
    report["monotonicity"] = []
    for scenario in scenarios:
        for algorithm in algorithms:
            for fam, tiers in families:
                got = [
                    rows_by.get((algorithm, scenario, n, level))
                    for n, level in tiers
                ]
                if not all(got):
                    continue
                ups = [g["bytes_up"] for g in got]
                report["monotonicity"].append({
                    "algorithm": algorithm,
                    "scenario": scenario,
                    "family": fam,
                    "settings": [
                        {"compress": n, "level": level} for n, level in tiers
                    ],
                    "bytes_up": ups,
                    "ok": all(a > b for a, b in zip(ups, ups[1:])),
                })

    # -- the acceptance frontier: on dirichlet01, at least one lossy
    # setting must hold >= 95% of its algorithm's uncompressed accuracy
    # at <= 25% of its uplink bytes
    witnesses = [
        {k: r[k] for k in ("algorithm", "compress", "level",
                           "acc_ratio", "bytes_ratio")}
        for r in report["results"]
        if r["scenario"] == "dirichlet01" and r["compress"] != "identity"
        and r["acc_ratio"] >= 0.95 and r["bytes_ratio"] <= 0.25
    ]
    report["criterion"] = {
        "scenario": "dirichlet01",
        "acc_floor": 0.95,
        "bytes_ceiling": 0.25,
        "witnesses": witnesses,
        "ok": bool(witnesses),
    }
    _row(
        "comm_criterion_dirichlet01", 0.0,
        f"witnesses={len(witnesses)};ok={bool(witnesses)}",
    )

    if json_path:
        from repro.tune.bench_io import write_bench_report

        write_bench_report(report, json_path)
        print(f"# wrote {json_path}", flush=True)
    return report


def scenario_matrix_bench(rounds=10):
    """Reduced scenario × algorithm matrix via the sweep runner
    (launch/sweep.py): CSV rows with final accuracy + wall time per cell.
    Covers one label-skew, one covariate-shift and one availability-trace
    regime so the scenario plumbing stays exercised by the bench sweep."""
    from repro.launch.sweep import run_sweep

    report = run_sweep(
        algorithms=("fedecado", "fednova"),
        scenarios=("dirichlet01", "feature-shift", "diurnal"),
        seeds=1, rounds=rounds, clients=10, equiv_scenarios=(),
        json_path=None, table=False,
    )
    for row in report["results"]:
        _row(
            f"scenario_{row['scenario']}_{row['algorithm']}",
            row["wall_s"] * 1e6,
            f"acc={row['acc']:.3f};loss={row['final_loss']:.3f}",
        )


def roofline_summary(results_dir="results/dryrun"):
    """Echo the dry-run roofline terms as CSV (no compute)."""
    paths = sorted(glob.glob(os.path.join(results_dir, "*.json")))
    if not paths:
        _row("roofline_summary", 0.0, "no dryrun results found")
        return
    for path in paths:
        with open(path) as f:
            r = json.load(f)
        tag = os.path.basename(path)[:-5]
        if r.get("status") != "ok":
            _row(f"roofline_{tag}", 0.0, f"status={r.get('status')}")
            continue
        rf = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        _row(
            f"roofline_{tag}", rf["bound_s"] * 1e6,
            f"dom={rf['dominant']};compute={rf['compute_s']:.4g};"
            f"mem={rf['memory_s']:.4g};coll={rf['collective_s']:.4g};"
            f"ratio={ratio if ratio is None else round(ratio, 3)}",
        )


KNOWN_BENCHES = (
    "table1", "table2", "fig6", "kernels", "adaptive", "engine",
    "scenarios", "comm", "ablation", "roofline",
)


def run_perf_gate(args) -> int:
    """``--gate``: regenerate a small bench slice on THIS machine and
    compare it against the committed BENCH_engine.json / BENCH_comm.json
    via the repro.tune.gate comparators (machine-normalized rounds/sec
    floor; per-round bytes-frontier erosion). Writes one comparator report
    per kind under ``--gate-report``. Returns the exit status: 0 = pass
    (or --gate-warn-only), 1 = regression, 2 = missing baseline."""
    from repro.tune.bench_io import machine_block
    from repro.tune.gate import compare_comm, compare_engine, write_report

    kinds = tuple(k for k in args.gate_kinds.split(",") if k)
    unknown = [k for k in kinds if k not in ("engine", "comm")]
    if unknown:
        print(f"--gate-kinds: unknown kind(s) {unknown}; "
              "choose from engine,comm", flush=True)
        return 2
    sizes = tuple(int(s) for s in args.gate_sizes.split(",") if s)
    status = 0
    reports = {}
    for kind in kinds:
        baseline_path = (
            args.engine_json if kind == "engine" else args.comm_json
        )
        try:
            with open(baseline_path) as f:
                baseline = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[gate:{kind}] cannot load baseline "
                  f"{baseline_path!r}: {e}", flush=True)
            return 2
        if kind == "engine":
            cand = engine_bench(
                rounds=args.gate_rounds, sizes=sizes,
                algorithms=tuple(a for a in args.algorithms.split(",") if a),
                json_path=None, heavy_traffic=None,
            )
        else:
            cand = comm_bench(
                rounds=args.gate_rounds,
                scenarios=("dirichlet01",),
                json_path=None,
            )
        cand["machine"] = machine_block()
        cmp_fn = compare_engine if kind == "engine" else compare_comm
        rep = cmp_fn(baseline, cand, threshold=args.gate_threshold)
        rep["warn_only"] = args.gate_warn_only
        write_report(rep, os.path.join(args.gate_report, f"{kind}.json"))
        reports[kind] = rep
        verdict = (
            "PASS" if rep["ok"] else
            "WARN" if args.gate_warn_only else "FAIL"
        )
        print(
            f"# gate:{kind} {verdict} — {len(rep['violations'])} "
            f"violation(s) over {rep['n_checked']} matched row(s) at "
            f"threshold {args.gate_threshold:.0%}",
            flush=True,
        )
        for v in rep["violations"]:
            print(f"#   {v['key']}: "
                  f"{v.get('problems') or v}", flush=True)
        if not rep["ok"] and not args.gate_warn_only:
            status = 1
    return status


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches to run; "
                    f"choices: {','.join(KNOWN_BENCHES)}")
    ap.add_argument("--comm-json", default="BENCH_comm.json",
                    help="where the comm bench persists its JSON report "
                    "(and the comm gate's committed baseline)")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--engine-json", default="BENCH_engine.json",
                    help="where the engine bench persists its JSON report "
                    "(and the engine gate's committed baseline)")
    ap.add_argument("--algorithms", default="fedecado",
                    help="comma-separated fed/algorithms registry names for "
                    "the engine bench's per-algorithm axis")
    ap.add_argument("--devices", type=int, default=8,
                    help="host devices forced for the engine bench (via "
                    "XLA_FLAGS, only when not already set)")
    # --- BENCH_* perf regression gate (repro.tune.gate, DESIGN.md §12) ---
    ap.add_argument("--gate", action="store_true",
                    help="regenerate a small bench slice and compare it "
                    "against the committed BENCH_*.json baselines; exits "
                    "non-zero on a regression (unless --gate-warn-only)")
    ap.add_argument("--gate-kinds", default="engine,comm",
                    help="which gates to run: engine,comm")
    ap.add_argument("--gate-threshold", type=float, default=None,
                    help="allowed rounds/sec regression fraction "
                    "(default: repro.tune.gate.DEFAULT_THRESHOLD)")
    ap.add_argument("--gate-warn-only", action="store_true",
                    help="report regressions but exit 0 (CI noise mode)")
    ap.add_argument("--gate-report", default="gate-report",
                    help="directory for the comparator report JSONs")
    ap.add_argument("--gate-sizes", default="10,100",
                    help="engine-bench n_clients slice for the gate run")
    ap.add_argument("--gate-rounds", type=int, default=10,
                    help="rounds per gate bench cell")
    args = ap.parse_args()
    if args.only is not None:
        sel = set(s for s in args.only.split(",") if s)
        unknown = sorted(sel - set(KNOWN_BENCHES))
        if unknown:
            ap.error(
                f"--only: unknown bench name(s) {', '.join(unknown)}; "
                f"choose from: {', '.join(KNOWN_BENCHES)}"
            )
        if not sel:
            ap.error(
                "--only needs at least one bench name; "
                f"choose from: {', '.join(KNOWN_BENCHES)}"
            )
    else:
        sel = None

    if args.gate:
        if args.gate_threshold is None:
            from repro.tune.gate import DEFAULT_THRESHOLD

            args.gate_threshold = DEFAULT_THRESHOLD
        if args.devices > 1 and "XLA_FLAGS" not in os.environ:
            # the committed engine baseline was measured on a forced
            # multi-device axis; the candidate slice must match it
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={args.devices}"
            )
        raise SystemExit(run_perf_gate(args))

    def want(name):
        return sel is None or name in sel

    if sel == {"engine"} and args.devices > 1 and "XLA_FLAGS" not in os.environ:
        # must precede the first jax device query; gives the sharded engine
        # backend a real multi-device axis on CPU hosts. Only for a
        # dedicated --only engine run — forcing virtual devices would skew
        # every other bench's timings when engine is part of a sweep
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    print("name,us_per_call,derived")
    if want("kernels"):
        consensus_step_bench()
        gamma_kernel_bench()
    if want("adaptive"):
        adaptive_overhead_bench()
    if want("engine"):
        # validate the algorithm names against the registry BEFORE any
        # bench work runs (a typo at the end of the axis must not discard
        # minutes of earlier timing)
        from repro.fed.algorithms import get_algorithm

        algorithms = tuple(a for a in args.algorithms.split(",") if a)
        if not algorithms:
            ap.error("--algorithms must name at least one registered algorithm")
        for a in algorithms:
            try:
                get_algorithm(a)
            except ValueError as e:
                ap.error(str(e))
        # persist the JSON artifact only on a dedicated --only engine run
        # (which forces the multi-device axis above) — a full sweep would
        # silently overwrite the committed 8-device BENCH_engine.json with
        # single-device numbers
        engine_bench(
            algorithms=algorithms,
            json_path=args.engine_json if sel == {"engine"} else None,
            # the n=10^4 heavy-traffic cell only on the dedicated run that
            # persists the artifact — it would dominate a full bench sweep
            heavy_traffic=(
                {"n": 10_000, "rounds": 20} if sel == {"engine"} else None
            ),
            # the client-cache sparse cells (incl. the n=10^5 q=0.001
            # acceptance witness) only on the dedicated artifact run
            sparse=(
                ((10_000, 0.01), (100_000, 0.001))
                if sel == {"engine"} else None
            ),
        )
    if want("comm"):
        # persist the JSON artifact only on a dedicated --only comm run,
        # mirroring the engine bench's overwrite guard
        comm_bench(
            rounds=min(args.rounds, 30),
            json_path=args.comm_json if sel == {"comm"} else None,
        )
    if want("scenarios"):
        scenario_matrix_bench(rounds=min(args.rounds, 10))
    if want("table1"):
        table1_noniid(rounds=args.rounds)
    if want("table2"):
        table2_async(rounds=args.rounds)
    if want("fig6"):
        fig6_combined(rounds=args.rounds)
    if want("ablation"):
        ablation_ecado(rounds=args.rounds)
    if want("roofline"):
        roofline_summary()


if __name__ == "__main__":
    main()

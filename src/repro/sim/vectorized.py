"""Vectorized local integration: the whole cohort in one jit dispatch.

The seed executed clients one-by-one — A jit dispatches per round plus A
host-side batch assemblies. Here the cohort's heterogeneous step counts
(e_i·steps_per_epoch) are padded to a common length S_pad and all clients
advance together in a single ``jax.vmap``-over-``jax.lax.scan`` call:

  * every client runs S_pad scan iterations;
  * iteration k of client j applies the update only when k < n_steps_j —
    masked with a ``jnp.where`` *select* on the carry (not arithmetic
    masking), so a padded step leaves the carry byte-identical to never
    having run and NaN/Inf from garbage padded batches cannot leak in;
  * padded minibatch slots repeat the client's last real step's indices
    (always valid data), so the gathered batch tensor is dense;
  * the per-step arithmetic is fed/client.py::client_step — the same
    function the sequential oracle scans over — which is what makes the
    two backends bit-for-bit comparable (tests/test_engine.py).

The client kind, its ``mu``, the per-client objective weights, and any
per-client state rows (flow variables, FedADMM duals, ...) come from the
``FederatedAlgorithm`` plugin at ``sim.alg`` via the client-kind registry
(fed/client.py) — this backend carries zero algorithm-specific branches.

Clients whose partitions are smaller than the batch size produce ragged
batch shapes; the runner groups the cohort by per-client batch size and
issues one vmapped dispatch per group (one group in the common case).

S_pad is derived from the config ceiling (epochs_max·steps_per_epoch), not
the cohort max, so the jitted runner compiles exactly once per client kind.

Server aggregation happens in the algorithm plugin (``FedSim._apply_round``
→ ``alg.aggregate``), where the optional Pallas batched-aggregation kernel
(kernels/batch_agg.py, ``FedSimConfig.agg_kernels``) fuses the cohort
weighted-delta reduction for the averaging family.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.engine import CohortPlan, CohortResult, ExecutionBackend

Pytree = Any


def cohort_vmap_fn(loss_fn: Callable, kind: str, mu: float = 0.0) -> Callable:
    """The UNJITTED vmap-over-scan cohort function for one client kind.

    ``fn(x_c, I_a, batches, lrs, ps, n_valid) -> (x_new_a, losses)`` — see
    ``build_cohort_runner`` for the contract. Exposed separately so the
    sharded backend can call it on each device's cohort shard inside its
    ``shard_map`` program (sim/sharded.py), where the outer jit is owned by
    the segment runner rather than per-dispatch. Whether ``I_a`` (the
    per-client state rows) is consumed or ignored comes from the registered
    kind's ``takes_flow`` flag (fed/client.py).
    """
    from repro.fed.client import client_kind_spec, client_step

    step = client_step(loss_fn, kind, mu)
    takes_I = client_kind_spec(kind).takes_flow

    def one_client(x_c, I_i, batches, lr, p_i, n_valid):
        steps = jnp.arange(jax.tree.leaves(batches)[0].shape[0], dtype=jnp.int32)

        def body(carry, xs):
            x, last_loss = carry
            batch, k = xs
            x_upd, loss = step(x, batch, x_c, I_i, lr, p_i)
            valid = k < n_valid
            x = jax.tree.map(lambda a, b: jnp.where(valid, a, b), x_upd, x)
            last_loss = jnp.where(valid, loss, last_loss)
            return (x, last_loss), None

        (x, last_loss), _ = jax.lax.scan(
            body, (x_c, jnp.zeros((), jnp.float32)), (batches, steps)
        )
        return x, last_loss

    if takes_I:
        return jax.vmap(one_client, in_axes=(None, 0, 0, 0, 0, 0))

    def one_client_no_I(x_c, batches, lr, p_i, n_valid):
        return one_client(x_c, None, batches, lr, p_i, n_valid)

    fn = jax.vmap(one_client_no_I, in_axes=(None, 0, 0, 0, 0))
    return lambda x_c, I_a, batches, lrs, ps, nv: fn(x_c, batches, lrs, ps, nv)


def build_cohort_runner(loss_fn: Callable, kind: str, mu: float = 0.0) -> Callable:
    """Build the jitted vmap-over-scan cohort runner for one client kind.

    Returns ``runner(x_c, I_a, batches, lrs, ps, n_valid) -> (x_new_a,
    losses)`` where leaves of ``batches`` are (A, S_pad, bs, ...), ``I_a``
    leaves are (A, ...) (required for kinds whose registered spec has
    ``takes_flow``; other kinds ignore it and may receive ``None``), and
    ``n_valid`` (A,) int32 gives each client's true step count. ``x_new_a``
    leaves are (A, ...); ``losses`` is (A,) — each client's last *valid*
    minibatch loss. Re-traces only when shapes change (once per
    (A, S_pad, bs)).
    """
    return jax.jit(cohort_vmap_fn(loss_fn, kind, mu))


class VectorizedBackend(ExecutionBackend):
    """Batched cohort execution; numerically equivalent to SequentialBackend
    on the same ``CohortPlan`` (asserted bit-for-bit in tests/test_engine.py)."""

    name = "vectorized"

    def __init__(self):
        self._runners: Dict[Tuple, Callable] = {}

    def _runner(self, sim) -> Callable:
        kind, mu = sim.alg.client_kind, float(sim.alg.client_mu())
        key = (kind, mu)
        if key not in self._runners:
            self._runners[key] = build_cohort_runner(sim.loss_fn, kind, mu)
        return self._runners[key]

    @staticmethod
    def _pad_steps(sim) -> int:
        """Config-stable scan length: the cohort ceiling, so the runner
        compiles once instead of once per distinct round maximum. Scenario
        device profiles (repro/scenarios) supersede ``cfg.hetero`` as the
        rate source, so their epochs ceiling wins when active."""
        cfg = sim.cfg
        scn = getattr(sim, "scn", None)
        if scn is not None and sim.alg.supports_hetero:
            ceil = scn.step_ceiling(cfg.steps_per_epoch)
            if ceil is not None:
                return int(ceil)
        if cfg.hetero is not None and sim.alg.supports_hetero:
            return int(cfg.hetero.epochs_max) * cfg.steps_per_epoch
        return int(cfg.epochs_fixed) * cfg.steps_per_epoch

    def run_cohort(self, sim, plan: CohortPlan) -> CohortResult:
        alg = sim.alg
        x_c = sim.state.x_c if sim.state is not None else sim.params
        A = plan.cohort_size
        S_pad = max(self._pad_steps(sim), int(plan.n_steps.max()))
        runner = self._runner(sim)

        # group clients by their (possibly ragged) per-client batch size
        groups: Dict[int, list] = {}
        for j in range(A):
            groups.setdefault(plan.batch_idx[j].shape[1], []).append(j)

        order, xs, losses_g = [], [], []
        for bs, js in sorted(groups.items()):
            sel = np.stack([
                np.pad(
                    plan.batch_idx[j],
                    ((0, S_pad - plan.batch_idx[j].shape[0]), (0, 0)),
                    mode="edge",
                )
                for j in js
            ])                                             # (Ag, S_pad, bs)
            batches = {k: jnp.asarray(v[sel]) for k, v in sim.data.items()}
            lrs = jnp.asarray(plan.lrs[js], jnp.float32)
            nv = jnp.asarray(plan.n_steps[js], jnp.int32)
            I_g = alg.client_rows(sim, plan.idx[js])
            ps = jnp.asarray(alg.client_weights(sim, plan.idx[js]), jnp.float32)
            x_g, loss_g = runner(x_c, I_g, batches, lrs, ps, nv)
            order.extend(js)
            xs.append(x_g)
            losses_g.append(loss_g)

        inv = np.argsort(np.asarray(order))
        x_new_a = jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0)[inv], *xs)
        loss_a = jnp.concatenate(losses_g)[inv]

        Ts = [float(t) for t in plan.windows()]
        return CohortResult(
            x_new_a=x_new_a,
            Ts=Ts,
            taus=[int(n) for n in plan.n_steps],
            losses=[float(l) for l in loss_a],
        )

"""Multi-rate client execution engine — backend protocol + sequential oracle.

FedECADO's defining mechanism is multi-rate integration: every client
advances its own local ODE over its own window T_i = e_i·lr_i·steps and the
server synchronizes the cohort in continuous time. This module gives that
mechanism a dedicated subsystem with three interchangeable execution
backends behind one ``ExecutionBackend`` interface:

  sequential  — one jit dispatch per client (the seed behaviour, kept
                verbatim as the numerical reference oracle);
  vectorized  — the whole cohort in a single ``vmap``-over-``lax.scan``
                dispatch with per-client step masks (sim/vectorized.py);
  event       — a continuous-time event scheduler that advances clients
                asynchronously between Backward-Euler synchronization
                points and supports staleness (sim/events.py).

The round is split into two phases so the backends stay composable:

  1. ``FedSim._draw_plan`` rolls ALL host-side randomness (cohort choice,
     lr_i/e_i heterogeneity, minibatch indices) into a ``CohortPlan``.
     Because the plan is drawn once by shared code, every backend sees
     byte-identical inputs — backend equivalence then reduces to the local
     integration arithmetic, which lives in one place
     (fed/client.py::client_step).
  2. ``ExecutionBackend.run_round`` executes the cohort and applies the
     server aggregation (``FedSim._apply_round``); the event backend
     overrides the whole round to interleave arrivals with BE sync steps.

Padding/masking semantics of the vectorized path are documented in
DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass
class CohortPlan:
    """Host-side randomness for one communication round, drawn up front.

    ``batch_idx[j]`` holds client j's minibatch data indices, shape
    (n_steps_j, bs_j) — bs_j = min(batch_size, |partition_j|), matching the
    sequential seed semantics (sampling with replacement iff the partition
    is smaller than the batch size).
    """
    rnd: int
    idx: np.ndarray                 # (A,) participating client ids
    lrs: np.ndarray                 # (A,) float32 local learning rates Δt_i
    epochs: np.ndarray              # (A,) int local epoch counts e_i
    n_steps: np.ndarray             # (A,) int e_i · steps_per_epoch
    batch_idx: List[np.ndarray]     # per client (n_steps_j, bs_j) indices

    @property
    def cohort_size(self) -> int:
        return len(self.idx)

    def windows(self) -> np.ndarray:
        """(A,) float32 continuous-time windows T_i = lr_i · n_steps_i."""
        return np.asarray(
            [np.float32(float(lr) * int(ns)) for lr, ns in zip(self.lrs, self.n_steps)],
            np.float32,
        )


@dataclasses.dataclass
class CohortResult:
    """Local-integration outputs for one cohort, in plan order."""
    x_new_a: Pytree                 # stacked final client states, leaves (A, ...)
    Ts: List[float]                 # per-client windows T_i (fedecado/ecado)
    taus: List[int]                 # per-client local step counts
    losses: List[float]             # per-client last-minibatch losses


class ExecutionBackend:
    """How a round's cohort is executed. Subclasses override ``run_cohort``
    (local integration only) or ``run_round`` (the whole round, for
    schedulers that interleave aggregation with client arrivals)."""

    name = "base"

    def run_cohort(self, sim, plan: CohortPlan) -> CohortResult:
        raise NotImplementedError

    def run_round(self, sim, plan: CohortPlan) -> Dict[str, Any]:
        result = self.run_cohort(sim, plan)
        return sim._apply_round(plan, result)


class SequentialBackend(ExecutionBackend):
    """Reference oracle: one jitted ``lax.scan`` dispatch per client, exactly
    the seed ``FedSim.run`` inner loop. Slow (Python-bound) but simple; the
    vectorized backend is tested bit-for-bit against it."""

    name = "sequential"

    def __init__(self):
        self._jit_cache: Dict[Tuple, Any] = {}

    # -- per-kind jitted client fns (moved verbatim from the seed FedSim) --
    def _client_fn(self, sim, kind: str, n_steps: int):
        from repro.fed.client import fedecado_client_sim, fedprox_client, sgd_client

        key = (kind, n_steps)
        if key not in self._jit_cache:
            if kind == "fedecado":
                fn = jax.jit(
                    lambda x0, I, batches, lr, p: fedecado_client_sim(
                        sim.loss_fn, x0, I, batches, lr, p
                    )
                )
            elif kind == "fedprox":
                fn = jax.jit(
                    lambda x0, batches, lr, mu: fedprox_client(
                        sim.loss_fn, x0, batches, lr, mu
                    )
                )
            else:  # sgd
                fn = jax.jit(
                    lambda x0, batches, lr: sgd_client(sim.loss_fn, x0, batches, lr)
                )
            self._jit_cache[key] = fn
        return self._jit_cache[key]

    def run_cohort(self, sim, plan: CohortPlan) -> CohortResult:
        cfg = sim.cfg
        x_c = sim.state.x_c if sim.state is not None else sim.params
        x_news, Ts, taus, losses = [], [], [], []
        for j, i in enumerate(plan.idx):
            n_steps = int(plan.n_steps[j])
            batches = {
                k: jnp.asarray(v[plan.batch_idx[j]]) for k, v in sim.data.items()
            }
            if cfg.algorithm in ("fedecado", "ecado"):
                I_i = jax.tree.map(lambda l: l[int(i)], sim.state.I)
                p_i = float(sim.p_hat[int(i)]) if cfg.algorithm == "fedecado" else 1.0
                out = self._client_fn(sim, "fedecado", n_steps)(
                    x_c, I_i, batches, float(plan.lrs[j]), p_i
                )
                x_news.append(out.x_new)
                Ts.append(float(out.T))
                losses.append(float(out.loss))
            elif cfg.algorithm == "fedprox":
                x_new, loss = self._client_fn(sim, "fedprox", n_steps)(
                    x_c, batches, float(plan.lrs[j]), cfg.mu
                )
                x_news.append(x_new)
                losses.append(float(loss))
            else:  # fedavg, fednova
                x_new, loss = self._client_fn(sim, "sgd", n_steps)(
                    x_c, batches, float(plan.lrs[j])
                )
                x_news.append(x_new)
                losses.append(float(loss))
            taus.append(n_steps)

        x_new_a = jax.tree.map(lambda *xs: jnp.stack(xs), *x_news)
        return CohortResult(x_new_a=x_new_a, Ts=Ts, taus=taus, losses=losses)


BACKENDS = ("sequential", "vectorized", "event")


def get_backend(cfg) -> ExecutionBackend:
    """Instantiate the execution backend named by ``cfg.backend``."""
    from repro.sim.events import EventBackend
    from repro.sim.vectorized import VectorizedBackend

    if cfg.backend == "sequential":
        return SequentialBackend()
    if cfg.backend == "vectorized":
        return VectorizedBackend()
    if cfg.backend == "event":
        return EventBackend(
            horizon_quantile=cfg.event_horizon, max_waves=cfg.event_max_waves
        )
    raise ValueError(f"unknown backend {cfg.backend!r}; choose from {BACKENDS}")

"""Multi-rate client execution engine — backend protocol + sequential oracle.

FedECADO's defining mechanism is multi-rate integration: every client
advances its own local ODE over its own window T_i = e_i·lr_i·steps and the
server synchronizes the cohort in continuous time. This module gives that
mechanism a dedicated subsystem with three interchangeable execution
backends behind one ``ExecutionBackend`` interface:

  sequential  — one jit dispatch per client (the seed behaviour, kept
                verbatim as the numerical reference oracle);
  vectorized  — the whole cohort in a single ``vmap``-over-``lax.scan``
                dispatch with per-client step masks (sim/vectorized.py);
  event       — a device-resident continuous-time scheduler: a
                fixed-capacity ``FlightTable`` (core/multirate.py) absorbs
                asynchronous arrivals in quantile-horizon waves between
                Backward-Euler syncs, supports straggler staleness via
                Γ re-anchoring, consumes ``StackedPlan`` segments
                jit-resident, and optionally shards the flight table over
                the client mesh (sim/events.py, DESIGN.md §8);
  sharded     — the vectorized dispatch split across devices with
                ``shard_map`` over the client axis, psum consensus
                reductions, and whole multi-round segments resident in one
                jit via ``lax.fori_loop`` over a pre-drawn ``StackedPlan``
                (sim/sharded.py, DESIGN.md §5.5).

The round is split into two phases so the backends stay composable:

  1. ``FedSim._draw_plan`` rolls ALL host-side randomness (cohort choice,
     lr_i/e_i heterogeneity, minibatch indices) into a ``CohortPlan``.
     Because the plan is drawn once by shared code, every backend sees
     byte-identical inputs — backend equivalence then reduces to the local
     integration arithmetic, which lives in one place
     (fed/client.py::client_step).
  2. ``ExecutionBackend.run_round`` executes the cohort and applies the
     server aggregation (``FedSim._apply_round``); the event backend
     overrides the whole round to interleave arrivals with BE sync steps.

Backends carry NO algorithm knowledge: the client kind, its ``mu``, the
per-client objective weights, and any per-client state rows all come from
the ``FederatedAlgorithm`` plugin at ``sim.alg`` (fed/algorithms/,
DESIGN.md §6), so a newly registered algorithm runs on every backend with
zero edits here.

Padding/masking semantics of the vectorized path are documented in
DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass
class CohortPlan:
    """Host-side randomness for one communication round, drawn up front.

    ``batch_idx[j]`` holds client j's minibatch data indices, shape
    (n_steps_j, bs_j) — bs_j = min(batch_size, |partition_j|), matching the
    sequential seed semantics (sampling with replacement iff the partition
    is smaller than the batch size).
    """
    rnd: int
    idx: np.ndarray                 # (A,) participating client ids — or
                                    # cache SLOTS once FedSim has translated
                                    # the plan (client_cache mode); backends
                                    # never distinguish the two
    lrs: np.ndarray                 # (A,) float32 local learning rates Δt_i
    epochs: np.ndarray              # (A,) int local epoch counts e_i
    n_steps: np.ndarray             # (A,) int e_i · steps_per_epoch
    batch_idx: List[np.ndarray]     # per client (n_steps_j, bs_j) indices
    cids: Optional[np.ndarray] = None   # (A,) REAL client ids when ``idx``
                                        # holds cache slots (participation
                                        # accounting stays population-indexed)

    @property
    def cohort_size(self) -> int:
        return len(self.idx)

    def windows(self) -> np.ndarray:
        """(A,) float32 continuous-time windows T_i = lr_i · n_steps_i.

        float32·int64 promotes to float64 (the exact product — lr_i is an
        exact double, n_steps_i an exact int) and a single rounding back to
        float32 — the same value as the historical per-element
        ``np.float32(float(lr) * int(ns))`` path, pinned by
        tests/test_algorithms.py::test_windows_vectorized_rounding.
        """
        return (self.lrs * self.n_steps).astype(np.float32)


@dataclasses.dataclass
class StackedPlan:
    """R ``CohortPlan``s densified into device-ready arrays for a jit-resident
    multi-round loop (the sharded backend's ``lax.fori_loop`` segment).

    The cohort axis is padded from A to ``A_pad`` (a multiple of the device
    count) so it shards evenly; padded slots carry ``mask = 0``, ``idx = 0``
    (a valid row for gathers), ``scatter_idx = n_clients`` (dropped by
    out-of-bounds scatter), ``n_steps = 0`` (every scan iteration masked, so
    the padded client's endpoint is exactly the broadcast x_c), and
    ``T = 0`` (excluded from the masked T_max horizon). Step padding follows
    the vectorized backend: each client's index rows are edge-padded to
    ``S_pad``. Stacking requires a uniform per-client batch size across all
    rounds — ``stack_plans`` returns None for ragged cohorts and the caller
    falls back to per-round execution.
    """
    rnd0: int
    idx: np.ndarray          # (R, A_pad) int32 gather ids (0 on padding)
    scatter_idx: np.ndarray  # (R, A_pad) int32 scatter ids (n_clients on padding)
    mask: np.ndarray         # (R, A_pad) float32 1=real client, 0=padding
    lrs: np.ndarray          # (R, A_pad) float32
    n_steps: np.ndarray      # (R, A_pad) int32
    Ts: np.ndarray           # (R, A_pad) float32 windows lr_i·n_steps_i
    sel: np.ndarray          # (R, A_pad, S_pad, bs) int32 minibatch indices
    taus: np.ndarray         # (R, A_pad) float32 local step counts (= n_steps)

    @property
    def n_rounds(self) -> int:
        return self.idx.shape[0]

    @property
    def cohort_pad(self) -> int:
        return self.idx.shape[1]


def pad_cohort_ids(
    idx: np.ndarray, A_pad: int, n_clients: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The sharded backend's cohort-padding sentinels, in ONE place
    (DESIGN.md §5.5): returns (gather_idx, scatter_idx, mask) of length
    ``A_pad`` where padded slots carry gather id 0 (a valid row, so device
    gathers stay in bounds), scatter id ``n_clients`` (dropped by the
    ``mode="drop"`` out-of-bounds scatter), and mask 0. Used by
    ``stack_plans``, the sharded ragged fallback, and launch/fedrun.py —
    change a sentinel here and every consumer follows."""
    A = len(idx)
    pad = A_pad - A
    gather = np.concatenate([idx, np.zeros(pad, idx.dtype)]).astype(np.int32)
    scatter = np.concatenate(
        [idx, np.full(pad, n_clients, idx.dtype)]
    ).astype(np.int32)
    mask = np.concatenate([np.ones(A), np.zeros(pad)]).astype(np.float32)
    return gather, scatter, mask


def stack_plans(
    plans: List[CohortPlan], n_clients: int, A_pad: int, S_pad: int,
    allow_uneven: bool = False,
) -> Optional[StackedPlan]:
    """Densify a segment of plans into a StackedPlan, or None if the
    segment cannot share one dense tensor layout: ragged cohorts (mixed
    per-client batch sizes change the minibatch-mean arithmetic) or uneven
    cohort sizes across rounds (availability-trace scenarios admit fewer
    clients on sparse rounds). Refused segments fall back to per-round
    execution.

    ``allow_uneven=True`` lifts the uneven-cohort refusal by padding every
    round to the segment's largest cohort with the §5.5 sentinels (mask 0,
    n_steps 0, T 0) — the buffered event backend uses this so
    arrival-process cohorts of varying size still run as one jit-resident
    segment. Mixed per-client batch sizes always refuse: padding cannot fix
    minibatch-mean arithmetic."""
    plans = list(plans)   # accepts any iterable (streaming plan draw)
    bss = {p.batch_idx[j].shape[1] for p in plans for j in range(p.cohort_size)}
    if len(bss) != 1:
        return None
    bs = bss.pop()
    R = len(plans)
    A = max(p.cohort_size for p in plans)
    if not allow_uneven and any(p.cohort_size != A for p in plans):
        return None
    assert A_pad >= A and S_pad >= int(max(p.n_steps.max() for p in plans))

    idx = np.zeros((R, A_pad), np.int32)
    sidx = np.full((R, A_pad), n_clients, np.int32)
    mask = np.zeros((R, A_pad), np.float32)
    lrs = np.zeros((R, A_pad), np.float32)
    n_steps = np.zeros((R, A_pad), np.int32)
    Ts = np.zeros((R, A_pad), np.float32)
    sel = np.zeros((R, A_pad, S_pad, bs), np.int32)
    for r, p in enumerate(plans):
        a = p.cohort_size
        idx[r], sidx[r], mask[r] = pad_cohort_ids(p.idx, A_pad, n_clients)
        lrs[r, :a] = p.lrs
        n_steps[r, :a] = p.n_steps
        Ts[r, :a] = p.windows()
        for j in range(a):
            sel[r, j] = np.pad(
                p.batch_idx[j],
                ((0, S_pad - p.batch_idx[j].shape[0]), (0, 0)),
                mode="edge",
            )
    return StackedPlan(
        rnd0=plans[0].rnd, idx=idx, scatter_idx=sidx, mask=mask, lrs=lrs,
        n_steps=n_steps, Ts=Ts, sel=sel, taus=n_steps.astype(np.float32),
    )


@dataclasses.dataclass
class CohortResult:
    """Local-integration outputs for one cohort, in plan order."""
    x_new_a: Pytree                 # stacked final client states, leaves (A, ...)
    Ts: List[float]                 # per-client windows T_i (fedecado/ecado)
    taus: List[int]                 # per-client local step counts
    losses: List[float]             # per-client last-minibatch losses


class ExecutionBackend:
    """How a round's cohort is executed. Subclasses override ``run_cohort``
    (local integration only) or ``run_round`` (the whole round, for
    schedulers that interleave aggregation with client arrivals)."""

    name = "base"

    # how many rounds of host rng FedSim.run may pre-draw into one
    # run_rounds segment. Backends that execute round-by-round keep the
    # seed behaviour (one plan alive at a time); the sharded backend raises
    # this to amortize its jit-resident fori_loop over many rounds.
    max_segment_rounds = 1

    def run_cohort(self, sim, plan: CohortPlan) -> CohortResult:
        raise NotImplementedError

    def run_round(self, sim, plan: CohortPlan) -> Dict[str, Any]:
        result = self.run_cohort(sim, plan)
        return sim._apply_round(plan, result)

    def run_rounds(self, sim, plans: List[CohortPlan]) -> List[Dict[str, Any]]:
        """Execute a segment of pre-drawn plans. The default is the per-round
        Python loop; the sharded backend overrides this with one jit-resident
        ``lax.fori_loop`` over the whole stacked segment. Every returned
        record follows the shared telemetry schema (repro.obs.telemetry)."""
        return [self.run_round(sim, plan) for plan in plans]

    def pop_participation(self) -> Optional["np.ndarray"]:
        """Per-client dispatch counts accumulated since the last pop, or
        None when the backend dispatches exactly what the plans say — the
        caller (fed/server.py) then counts participation from the plans.
        Only backends that drop planned clients (the event backend's busy
        re-draws) need device-exact counts."""
        return None

    def on_cache_repack(self, sim, repack) -> None:
        """Client-state-cache hook (sim/cache.py, DESIGN.md §13): the packed
        per-client capacity changed/permuted; backends holding capacity-
        indexed device state (the event backend's flight table) must apply
        the ``RepackPlan``. Default: nothing to move."""
        return None


CLIENT_AXIS = "clients"   # the 1-D launch mesh axis (launch/mesh.py)


class MeshedBackendMixin:
    """Device-mesh infrastructure shared by the backends that run on the
    1-D clients launch mesh (sharded, event): lazy mesh construction, the
    lcm-based cohort/capacity padding unit (``pad_multiple`` forces it
    above the device count so tests exercise uneven padding on any host,
    DESIGN.md §5.5), a keyed jit-closure cache, and the identity-keyed
    device-data upload cache (scenario drift re-materializes a NEW data
    dict, so identity keying is exactly what forces the re-upload —
    holding the dict itself also prevents id() reuse after gc). One
    implementation so the two backends cannot drift."""

    def _init_mesh_infra(self, pad_multiple: Optional[int],
                         max_devices: Optional[int],
                         groups: Optional[int] = None) -> None:
        self.pad_multiple = pad_multiple
        self.max_devices = max_devices
        self.groups = groups
        self._mesh = None
        self._fns: Dict[Tuple, Any] = {}
        self._data_cache: Tuple[Optional[Dict], Optional[Dict]] = (None, None)

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_client_mesh

            self._mesh = make_client_mesh(self.max_devices, groups=self.groups)
        return self._mesh

    @property
    def n_devices(self) -> int:
        # total devices under the client-sharding axes (1 for the 1-D mesh,
        # groups × per-group for the hierarchical 2-D mesh, DESIGN.md §13)
        return int(self.mesh.devices.size)

    def _pad_unit(self) -> int:
        n_dev = self.n_devices
        if self.pad_multiple:
            return int(np.lcm(n_dev, int(self.pad_multiple)))
        return n_dev

    def _a_pad(self, A: int) -> int:
        unit = self._pad_unit()
        return int(-(-A // unit) * unit)

    def _fn(self, key: Tuple, builder: Any) -> Any:
        if key not in self._fns:
            self._fns[key] = builder()
        return self._fns[key]

    def _device_data(self, sim) -> Dict[str, Any]:
        if self._data_cache[0] is not sim.data:
            self._data_cache = (
                sim.data, {k: jnp.asarray(v) for k, v in sim.data.items()}
            )
        return self._data_cache[1]


class SequentialBackend(ExecutionBackend):
    """Reference oracle: one jitted ``lax.scan`` dispatch per client, exactly
    the seed ``FedSim.run`` inner loop. Slow (Python-bound) but simple; the
    vectorized backend is tested bit-for-bit against it."""

    name = "sequential"

    def __init__(self):
        self._jit_cache: Dict[Tuple, Any] = {}

    # -- one jitted client fn per (kind, mu); retraces per batch shape ------
    def _client_fn(self, sim, kind: str, mu: float):
        from functools import partial

        from repro.fed.client import run_client

        key = (kind, float(mu))
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                partial(run_client, sim.loss_fn, kind, float(mu))
            )
        return self._jit_cache[key]

    def run_cohort(self, sim, plan: CohortPlan) -> CohortResult:
        alg = sim.alg
        kind, mu = alg.client_kind, alg.client_mu()
        x_c = sim.state.x_c if sim.state is not None else sim.params
        rows = alg.client_rows(sim, plan.idx)      # (A, ...) or None
        ps = alg.client_weights(sim, plan.idx)     # (A,) fp32
        fn = self._client_fn(sim, kind, mu)

        x_news, taus, losses = [], [], []
        for j in range(plan.cohort_size):
            batches = {
                k: jnp.asarray(v[plan.batch_idx[j]]) for k, v in sim.data.items()
            }
            I_j = (
                jax.tree.map(lambda l: l[j], rows) if rows is not None else None
            )
            x_new, loss = fn(x_c, I_j, batches, float(plan.lrs[j]), float(ps[j]))
            x_news.append(x_new)
            losses.append(float(loss))
            taus.append(int(plan.n_steps[j]))

        x_new_a = jax.tree.map(lambda *xs: jnp.stack(xs), *x_news)
        return CohortResult(
            x_new_a=x_new_a,
            Ts=[float(t) for t in plan.windows()],
            taus=taus,
            losses=losses,
        )


BACKENDS = ("sequential", "vectorized", "event", "sharded")


def get_backend(cfg) -> ExecutionBackend:
    """Instantiate the execution backend named by ``cfg.backend``."""
    from repro.sim.events import EventBackend
    from repro.sim.sharded import ShardedBackend
    from repro.sim.vectorized import VectorizedBackend

    if cfg.backend == "sequential":
        return SequentialBackend()
    if cfg.backend == "vectorized":
        return VectorizedBackend()
    if cfg.backend == "event":
        return EventBackend(
            horizon_quantile=cfg.event_horizon, max_waves=cfg.event_max_waves,
            sharded=cfg.event_sharded,
            pad_multiple=cfg.sharded_pad_multiple,
            buffered=cfg.event_buffered,
            buffer_size=cfg.event_buffer_size,
            stale_gamma=cfg.event_stale_gamma if cfg.event_buffered else 0.0,
        )
    if cfg.backend == "sharded":
        return ShardedBackend(
            pad_multiple=cfg.sharded_pad_multiple,
            groups=getattr(cfg, "sharded_groups", None),
        )
    if cfg.backend == "auto":
        raise ValueError(
            "backend='auto' is resolved at FedSim construction "
            "(repro.tune.autotune.resolve_auto scores the candidates "
            "against the HLO cost model); get_backend needs a concrete "
            f"name from {BACKENDS}"
        )
    raise ValueError(f"unknown backend {cfg.backend!r}; choose from {BACKENDS}")

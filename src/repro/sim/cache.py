"""Device-resident client-state cache: participants-only packed state.

The million-client regime (DESIGN.md §13) has a huge registered population
and a small active cohort per round. Materializing per-client state rows
for the whole population — FedECADO's flow variables I_i and gains,
FedADMM's duals, error-feedback residuals, the event backend's flight
table — costs O(n_clients · |params|) device memory even when only
O(cohort) rows are ever touched. This module packs all of it into
``(capacity, ...)`` pytrees indexed by **slot**, with ``ClientStateCache``
owning the cid→slot mapping.

Contract (every consumer relies on all four properties):

  * **sorted slots** — admitted cids occupy slots ``0..len(cids)-1`` in
    increasing-cid order. Global reductions over the packed leading axis
    (``tree_sum_clients``) then visit the same nonzero rows in the same
    order as the materialized ``(n, ...)`` layout would, with exact
    ``+0.0`` no-ops interleaved — which is what makes cached runs
    bitwise-equal to materialized runs (pinned by
    tests/test_client_cache.py).
  * **eviction-free** — a cid admitted once keeps a slot forever; capacity
    only grows. Federated state is tiny per client relative to the model,
    and eviction would forget flow variables that the Σ_i I_i = 0
    invariant still accounts for.
  * **geometric growth** — capacity doubles (power-of-two, floor
    ``MIN_CAPACITY``), so jit recompilations triggered by a new packed
    shape are O(log participants) over a whole run, not O(rounds).
  * **segment-boundary admission** — ``FedSim`` admits a whole segment's
    cohorts at once (two-phase: draw plans with real cids, then admit +
    repack, then translate plan ids to slots), so packed shapes are
    stable inside every jit-resident segment.

A repack (``RepackPlan``) is a gather: new slot ``j`` reads old slot
``src[j]`` (or is freshly zeroed where ``src[j] < 0``). ``repack_rows``
applies it to any packed pytree; fresh rows are exact zeros (the additive
identity every all-clients reduction relies on) unless a consumer fills
them itself (gains, flight-table sentinels).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

MIN_CAPACITY = 64


@dataclasses.dataclass(frozen=True)
class RepackPlan:
    """One capacity change/permutation of the packed state."""
    src: np.ndarray          # (capacity,) int64: old slot feeding each new
                             # slot, -1 = fresh (zero-filled) row
    fresh: np.ndarray        # (k,) int64 new-slot positions of newly
                             # admitted cids, in increasing-cid order
    fresh_cids: np.ndarray   # (k,) int64 the cids admitted by this repack
    capacity: int            # new packed leading-axis length
    n_admitted: int          # admitted cids (<= capacity; tail is padding)


def _grow(count: int, floor: int) -> int:
    cap = MIN_CAPACITY
    while cap < max(int(count), int(floor)):
        cap *= 2
    return cap


class ClientStateCache:
    """cid→slot mapping for the packed per-client state."""

    def __init__(self, n_clients: int, capacity: int = 0):
        self.n = int(n_clients)
        self.cids = np.empty((0,), np.int64)    # sorted admitted cids
        self._floor = int(capacity) or MIN_CAPACITY
        # capacity is live from construction: per-client state is allocated
        # (at this size) before the first admission ever happens
        self.capacity = _grow(0, self._floor)

    @property
    def n_admitted(self) -> int:
        return len(self.cids)

    def slots_of(self, cids: np.ndarray) -> np.ndarray:
        """Slots of already-admitted cids (callers admit first)."""
        slots = np.searchsorted(self.cids, cids)
        assert slots.size == 0 or (
            slots.max(initial=0) < len(self.cids)
            and (self.cids[slots] == np.asarray(cids)).all()
        ), "slots_of called with unadmitted cids — admit the segment first"
        return slots.astype(np.int64)

    def admit(self, cand_cids: np.ndarray) -> Optional[RepackPlan]:
        """Admit every cid in ``cand_cids``; None when all are already
        cached (no repack needed), else the ``RepackPlan`` the caller must
        apply to every packed consumer BEFORE resolving slots."""
        cand = np.unique(np.asarray(cand_cids, np.int64))
        if cand.size and (cand.min() < 0 or cand.max() >= self.n):
            raise ValueError(
                f"cids out of range [0, {self.n}): "
                f"[{cand.min()}, {cand.max()}]"
            )
        fresh_cids = np.setdiff1d(cand, self.cids, assume_unique=True)
        if fresh_cids.size == 0:
            return None
        merged = np.union1d(self.cids, fresh_cids)
        capacity = _grow(len(merged), max(self._floor, self.capacity))
        src = np.full((capacity,), -1, np.int64)
        if len(self.cids):
            src[np.searchsorted(merged, self.cids)] = np.arange(
                len(self.cids), dtype=np.int64
            )
        fresh = np.searchsorted(merged, fresh_cids).astype(np.int64)
        plan = RepackPlan(
            src=src, fresh=fresh, fresh_cids=fresh_cids,
            capacity=int(capacity), n_admitted=len(merged),
        )
        self.cids = merged
        self.capacity = int(capacity)
        return plan


def repack_rows(tree: Pytree, plan: RepackPlan) -> Pytree:
    """Apply a ``RepackPlan`` to a packed pytree (leaves ``(old_cap, ...)``):
    gather surviving rows into their new slots, zero-fill fresh/padding
    slots. A pure gather + select, so it composes with jit and preserves
    row values bitwise."""
    if tree is None:
        return None
    src = jnp.asarray(plan.src)
    keep = src >= 0
    safe = jnp.where(keep, src, 0)

    def leaf(l):
        rows = l[safe]
        m = keep.reshape((-1,) + (1,) * (rows.ndim - 1))
        return jnp.where(m, rows, jnp.zeros((), l.dtype))

    return jax.tree.map(leaf, tree)


def state_nbytes(sim) -> int:
    """Resident per-client state bytes of a running sim: the packed (or
    materialized) flow rows + gains, algorithm-owned client rows, comm
    error-feedback residuals, and the event backend's flight table. The
    BENCH_engine.json ``peak_state_bytes`` column (schema v6) — capacity
    is monotone (eviction-free), so end-of-run == peak."""
    trees = []
    if sim.state is not None:
        trees += [sim.state.I, sim.state.g_inv]
    trees.append(getattr(sim.alg, "client_state", None))
    trees.append(getattr(sim.alg, "comm_state", None))
    trees.append(getattr(sim.backend, "_table", None))
    total = 0
    for t in trees:
        if t is None:
            continue
        for l in jax.tree.leaves(t):
            total += int(np.asarray(l.size)) * jnp.dtype(l.dtype).itemsize
    return total

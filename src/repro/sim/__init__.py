"""Multi-rate client execution engine (DESIGN.md §5).

engine.py     — CohortPlan/CohortResult, ExecutionBackend, sequential oracle
vectorized.py — whole-cohort vmap-over-scan runner with per-client step masks
events.py     — continuous-time event scheduler with straggler staleness
"""
from repro.sim.engine import (
    BACKENDS,
    CohortPlan,
    CohortResult,
    ExecutionBackend,
    SequentialBackend,
    get_backend,
)
from repro.sim.events import EventBackend, InFlight
from repro.sim.vectorized import VectorizedBackend, build_cohort_runner

__all__ = [
    "BACKENDS", "CohortPlan", "CohortResult", "ExecutionBackend",
    "SequentialBackend", "VectorizedBackend", "EventBackend", "InFlight",
    "build_cohort_runner", "get_backend",
]

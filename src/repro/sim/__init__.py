"""Multi-rate client execution engine (DESIGN.md §5).

engine.py     — CohortPlan/StackedPlan, ExecutionBackend, sequential oracle
vectorized.py — whole-cohort vmap-over-scan runner with per-client step masks
events.py     — device-resident event scheduler (core/multirate.py flight
                table): jit-resident segments, quantile-horizon waves,
                straggler staleness, optional sharded event mode
sharded.py    — shard_map multi-device backend: psum consensus reductions +
                jit-resident fori_loop over pre-drawn round segments
"""
from repro.sim.engine import (
    BACKENDS,
    CohortPlan,
    CohortResult,
    ExecutionBackend,
    SequentialBackend,
    StackedPlan,
    get_backend,
    pad_cohort_ids,
    stack_plans,
)
from repro.core.multirate import FlightTable
from repro.sim.events import EventBackend
from repro.sim.sharded import ShardedBackend
from repro.sim.vectorized import (
    VectorizedBackend,
    build_cohort_runner,
    cohort_vmap_fn,
)

__all__ = [
    "BACKENDS", "CohortPlan", "CohortResult", "ExecutionBackend",
    "SequentialBackend", "VectorizedBackend", "EventBackend", "FlightTable",
    "ShardedBackend", "StackedPlan", "pad_cohort_ids", "stack_plans",
    "build_cohort_runner", "cohort_vmap_fn", "get_backend",
]

"""Multi-rate client execution engine (DESIGN.md §5).

engine.py     — CohortPlan/StackedPlan, ExecutionBackend, sequential oracle
vectorized.py — whole-cohort vmap-over-scan runner with per-client step masks
events.py     — continuous-time event scheduler with straggler staleness
sharded.py    — shard_map multi-device backend: psum consensus reductions +
                jit-resident fori_loop over pre-drawn round segments
"""
from repro.sim.engine import (
    BACKENDS,
    CohortPlan,
    CohortResult,
    ExecutionBackend,
    SequentialBackend,
    StackedPlan,
    get_backend,
    pad_cohort_ids,
    stack_plans,
)
from repro.sim.events import EventBackend, InFlight
from repro.sim.sharded import ShardedBackend
from repro.sim.vectorized import (
    VectorizedBackend,
    build_cohort_runner,
    cohort_vmap_fn,
)

__all__ = [
    "BACKENDS", "CohortPlan", "CohortResult", "ExecutionBackend",
    "SequentialBackend", "VectorizedBackend", "EventBackend", "InFlight",
    "ShardedBackend", "StackedPlan", "pad_cohort_ids", "stack_plans",
    "build_cohort_runner", "cohort_vmap_fn", "get_backend",
]

"""Sharded multi-device execution: the round loop resident on the mesh.

The vectorized backend already collapses a cohort into one vmap-over-scan
dispatch, but that dispatch lands on a single device and every round makes
a host round-trip (plan staging, aggregation, history bookkeeping). This
backend scales the same mechanism across the launch mesh
(launch/mesh.py::make_client_mesh, a 1-D "clients" axis over all local
devices) and moves the *multi-round* loop on-device:

  * the cohort axis is padded to a multiple of the device count and
    ``shard_map``-ed over the mesh, so each device runs the vmap-over-scan
    local integration for its A_pad/n_dev clients;
  * the Backward-Euler Schur-arrowhead reduction (Σ_a u_a, Σ_a w_a of
    DESIGN.md §2) runs as device-local partial sums + ``psum`` along the
    client axis — core/consensus.py's ``be_step``/``lte`` take the mesh
    axis name directly, so the dense synchronous round and this backend
    execute the very same Algorithm-1 loop (core/fedecado.py::
    consensus_integrate), differing only in reduction topology;
  * a whole segment of rounds executes inside ONE jit: host rng for R
    rounds is pre-drawn into a ``StackedPlan`` (engine.py) and a
    ``lax.fori_loop`` consumes it round by round — zero host syncs between
    rounds;
  * the averaging family aggregates through the sharded batch-agg entry
    (kernels/ops.py::batch_agg_psum): local masked weighted-delta partials
    + psum, with the (w, scale) spec and the optional endpoint transform
    coming from the ``FederatedAlgorithm`` plugin (fed/algorithms/).

Which path a simulation takes is decided by capability flags on
``sim.alg``, never by algorithm names: ``has_flow_dynamics`` selects the
consensus segment, ``has_client_state`` threads the algorithm's per-client
rows (e.g. FedADMM duals) through the jit-resident loop with the same
one-hot psum scatter the flow write-back uses. A newly registered plugin
therefore runs sharded with zero edits to this module.

Padding/masking semantics (DESIGN.md §5.5): padded cohort rows run zero
valid steps (their endpoint is exactly the broadcast x_c), carry mask 0 in
every consensus reduction and LTE max, window T = 0 (excluded from the
pmax'd τ horizon), and are dropped from every per-client-state write-back
by an out-of-bounds scatter index. Because every scalar that steers the
adaptive loop (ε_BE, T_max, Δt) is psum/pmax-replicated, all devices branch
identically through the nested while loops.

Ragged cohorts (clients with |partition| < batch_size) cannot share one
dense minibatch tensor without changing the minibatch-mean arithmetic, so
those rounds fall back to the vectorized backend's per-group local
integration; flow algorithms then re-enter the sharded path at the psum
consensus reduction, while the averaging family — whose endpoints are
already gathered on one device — applies the algorithm's dense aggregate
directly. Diagonal sensitivity gains keep their pytree layout on the host
path and are not supported here (scalar gains only).

Backend equivalence against the sequential oracle — every registered
algorithm, uneven padding, ragged partitions, partial participation,
heterogeneous e_i/lr_i — is fuzzed in tests/test_backend_equiv.py;
histories match at rtol ≈ 1e-6 (psum re-associates the cohort reductions,
so bitwise equality is not expected).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.obs.telemetry import make_record
from repro.sim.engine import (
    CLIENT_AXIS,
    CohortPlan,
    CohortResult,
    ExecutionBackend,
    MeshedBackendMixin,
    StackedPlan,
    stack_plans,
)
from repro.sim.vectorized import VectorizedBackend, cohort_vmap_fn

Pytree = Any

AXIS = CLIENT_AXIS   # the 1-D launch mesh axis (launch/mesh.py)
GROUP_AXIS = "groups"   # outer axis of the hierarchical 2-D mesh (§13)


def _bcast(v: jax.Array, like: jax.Array) -> jax.Array:
    return v.reshape((-1,) + (1,) * (like.ndim - 1))


def _psum_tree(x, axes):
    """Cross-device sum over the client-sharding axes. On the flat 1-D mesh
    this is ONE psum over "clients"; on the hierarchical 2-D mesh it stages
    the reduction — intra-group psum first (cheap, local neighborhood),
    then the inter-group reduce over the partial sums (DESIGN.md §13). The
    staged association order differs from the flat all-reduce, which is why
    hierarchical runs match at rtol rather than bitwise."""
    if isinstance(axes, tuple):
        for ax in reversed(axes):   # innermost (intra-group) first
            x = jax.tree.map(lambda l, ax=ax: jax.lax.psum(l, ax), x)
        return x
    return jax.tree.map(lambda l: jax.lax.psum(l, axes), x)


def _scatter_rows(full, rows_loc, sidx_loc, mask_loc, axes=AXIS):
    """Exact-set write-back of device-local per-client rows into the
    replicated (n, ...) tensor: every real cohort row is owned by exactly
    one device, so psum of the one-hot scatters reassembles the full
    update; padding rows carry an out-of-bounds sidx and are dropped. On
    the hierarchical mesh the one-hot scatters batch per device group:
    each group psums its members' scatters first, then the group partials
    reduce across groups (``_psum_tree``)."""
    n = jax.tree.leaves(full)[0].shape[0]
    hit = _psum_tree(
        jnp.zeros((n,), jnp.float32).at[sidx_loc].add(mask_loc, mode="drop"),
        axes,
    )
    rows = jax.tree.map(
        lambda l, r: _psum_tree(
            jnp.zeros_like(l).at[sidx_loc].add(r * _bcast(mask_loc, r), mode="drop"),
            axes,
        ),
        full, rows_loc,
    )
    return jax.tree.map(
        lambda l, r: jnp.where(_bcast(hit, l) > 0, r, l), full, rows
    )


def _flow_round_core(
    x_c, I, g_inv, dt_last, t,
    x_new_loc, idx_loc, sidx_loc, mask_loc, T_loc, ccfg,
    comm=None, rnd=0, axes=AXIS,
):
    """One flow-consensus round on a device-local cohort shard.

    Runs inside ``shard_map``: (x_c, I, g_inv, dt_*, t) are replicated,
    ``*_loc`` carry this device's A_pad/n_dev cohort rows. The Σ_a
    reductions inside the BE solve psum over AXIS; the flow write-back uses
    the shared one-hot scatter (``_scatter_rows``). Also returns a (6,)
    replicated telemetry row [substeps, backtracks, dt_min, dt_max, dt_sum,
    tau_end] — every LTE scalar is already psum/pmax-replicated, so the row
    is identical on all devices and costs no extra reduction.
    """
    from repro.core.fedecado import consensus_integrate
    from repro.core.flow import broadcast_clients, tree_sum_clients

    J_loc = jax.tree.map(lambda l: l[idx_loc], I)
    # S_frozen = Σ_all I_i − Σ_active J_a; the active sum spans all shards
    # (staged intra-group-then-inter-group on the hierarchical mesh)
    S_all = tree_sum_clients(I)
    S_act = _psum_tree(
        jax.tree.map(
            lambda j: jnp.sum(j * _bcast(mask_loc, j), axis=0), J_loc
        ),
        axes,
    )
    S_frozen = jax.tree.map(jnp.subtract, S_all, S_act)

    A_loc = T_loc.shape[0]
    x_prev_loc = broadcast_clients(x_c, A_loc)
    if comm is not None and not comm.lossless:
        # lossy wire, flow family: compress this shard's endpoints against
        # the replicated dispatch reference x_c before the BE solve consumes
        # them. The round-trip is elementwise per row, so the device-local
        # call IS the sharded variant — padded rows carry a zero delta and
        # compress back to zero (their mask excludes them regardless). EF-
        # free by design, matching the dense flow hook in FedSim._apply_round.
        x_new_loc, _ = comm.compress_endpoints(x_c, x_new_loc, None, rnd)
    g_loc = jnp.take(g_inv, idx_loc, axis=0)

    x_c_f, I_f, tau_f, dt_f, stats = consensus_integrate(
        x_c, J_loc, J_loc, x_prev_loc, x_new_loc, T_loc, g_loc, S_frozen,
        dt_last, ccfg, axis_name=axes, mask=mask_loc,
    )
    n_sub, n_back, _final_dt, _max_eps, dt_mn, dt_mx, dt_sm = stats
    tel = jnp.stack([
        n_sub.astype(jnp.float32), n_back.astype(jnp.float32),
        dt_mn, dt_mx, dt_sm, tau_f,
    ])

    I_new = _scatter_rows(I, I_f, sidx_loc, mask_loc, axes=axes)
    return x_c_f, I_new, dt_f, t + tau_f, tel


def build_flow_segment(mesh, loss_fn: Callable, ccfg,
                       kind: str = "fedecado", mu: float = 0.0,
                       comm=None, axes=AXIS) -> Callable:
    """Jitted R-round flow-dynamics segment, shard_map-ed over ``mesh``.

    ``fn(x_c, I, g_inv, dt_last, t, data, idx, sidx, mask, lrs, ns, Ts,
    sel, ps) -> (x_c, I, dt_last, t, losses, tel)`` where the plan arrays
    are the ``StackedPlan`` fields (R, A_pad, ...) sharded on the cohort
    axis, ``losses`` comes back (R, A_pad) in global plan order and ``tel``
    (R, 6) carries the replicated per-round solver telemetry rows of
    ``_flow_round_core`` — both ride the segment's single host sync.
    """
    cohort = cohort_vmap_fn(loss_fn, kind, mu)

    def body(x_c, I, g_inv, dt_last, t, data, idx, sidx, mask, lrs, ns, Ts,
             sel, ps, rnd0):
        R, A_loc = idx.shape

        def round_step(r, carry):
            x_c, I, dt_last, t, losses, tel = carry
            batches = {k: v[sel[r]] for k, v in data.items()}
            I_rows = jax.tree.map(lambda l: l[idx[r]], I)
            x_new_loc, loss_loc = cohort(x_c, I_rows, batches, lrs[r], ps[r], ns[r])
            x_c, I, dt_last, t, tel_r = _flow_round_core(
                x_c, I, g_inv, dt_last, t,
                x_new_loc, idx[r], sidx[r], mask[r], Ts[r], ccfg,
                comm=comm, rnd=rnd0 + r, axes=axes,
            )
            return (x_c, I, dt_last, t, losses.at[r].set(loss_loc),
                    tel.at[r].set(tel_r))

        losses0 = jnp.zeros((R, A_loc), jnp.float32)
        tel0 = jnp.zeros((R, 6), jnp.float32)
        x_c, I, dt_last, t, losses, tel = jax.lax.fori_loop(
            0, R, round_step, (x_c, I, dt_last, t, losses0, tel0)
        )
        return x_c, I, dt_last, t, losses, tel

    c2 = P(None, axes)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(),
                  c2, c2, c2, c2, c2, c2, c2, c2, P()),
        out_specs=(P(), P(), P(), P(), c2, P()),
        check_rep=False,
    )
    return jax.jit(fn)


def build_avg_segment(mesh, alg, loss_fn: Callable, use_kernel: bool,
                      comm=None, axes=AXIS) -> Callable:
    """Jitted R-round weighted-delta segment for the averaging family.

    ``fn(params, rows, ef, data, idx, sidx, mask, sel, lrs, ns, ps, w,
    scale, rnd0) -> (params, rows, ef, losses)`` — ``w`` (R, A_pad) carries
    the host-precomputed aggregation weights from the algorithm's
    ``agg_weights`` spec with cohort padding already zeroed, ``scale`` (R,)
    the per-round update scale (FedNova's τ_eff; ones otherwise), ``ps``
    (R, A_pad) the per-client objective weights, and ``rows`` the
    algorithm's per-client state (leaves (n+?, ...); an empty pytree when
    ``alg.has_client_state`` is False). The endpoint transform
    (``agg_transform``, e.g. FedADMM's dual update) runs device-local on
    each shard; updated rows re-enter the replicated tensor through the
    same one-hot psum scatter as the flow write-back.

    ``ef`` threads the comm layer's error-feedback residual rows (leaves
    (n, ...); empty pytree when the wire is lossless or EF-free) through
    the fori_loop by exactly the same gather / one-hot-psum-scatter
    machinery as the algorithm rows — the lossy round-trip itself is
    elementwise per cohort row, so the device-local call before the psum
    aggregation IS the sharded variant (DESIGN.md §11). ``rnd0`` (traced
    scalar) stamps the segment's first round into the stochastic-rounding
    key so recompiles don't depend on the round counter.
    """
    from repro.kernels.ops import batch_agg_psum

    cohort = cohort_vmap_fn(loss_fn, alg.client_kind, alg.client_mu())
    takes_rows = bool(alg.has_client_state)
    lossy = comm is not None and not comm.lossless
    takes_ef = lossy and comm.error_feedback

    def body(params, rows, ef, data, idx, sidx, mask, sel, lrs, ns, ps,
             w, scale, rnd0):
        R, A_loc = lrs.shape

        def round_step(r, carry):
            params, rows, ef, losses = carry
            batches = {k: v[sel[r]] for k, v in data.items()}
            rows_loc = (
                jax.tree.map(lambda l: l[idx[r]], rows) if takes_rows else None
            )
            x_new_loc, loss_loc = cohort(
                params, rows_loc, batches, lrs[r], ps[r], ns[r]
            )
            if lossy:
                # padded rows gather a real client's residual but their
                # w/mask are zero and their scatter index is out of bounds,
                # so neither the aggregation nor the EF write-back sees them
                ef_loc = (
                    jax.tree.map(lambda l: l[idx[r]], ef) if takes_ef else None
                )
                x_new_loc, ef_new_loc = comm.compress_endpoints(
                    params, x_new_loc, ef_loc, rnd0 + r
                )
                if takes_ef:
                    ef = _scatter_rows(ef, ef_new_loc, sidx[r], mask[r],
                                       axes=axes)
            y_loc, new_rows_loc = alg.agg_transform(params, x_new_loc, rows_loc)
            delta = batch_agg_psum(
                params, y_loc, w[r], axes, use_kernel=use_kernel
            )
            params = jax.tree.map(
                lambda xc, d: xc + scale[r] * d, params, delta
            )
            if takes_rows:
                rows = _scatter_rows(rows, new_rows_loc, sidx[r], mask[r],
                                     axes=axes)
            return (params, rows, ef, losses.at[r].set(loss_loc))

        losses0 = jnp.zeros((R, A_loc), jnp.float32)
        return jax.lax.fori_loop(
            0, R, round_step, (params, rows, ef, losses0)
        )

    c2 = P(None, axes)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(),
                  c2, c2, c2, c2, c2, c2, c2, c2, P(), P()),
        out_specs=(P(), P(), P(), c2),
        check_rep=False,
    )
    return jax.jit(fn)


def build_flow_apply(mesh, ccfg, axes=AXIS) -> Callable:
    """Consensus-only sharded round (ragged fallback): local integration
    already happened on the gathered cohort; this applies the psum BE solve.
    ``fn(x_c, I, g_inv, dt_last, t, x_new_a, idx, sidx, mask, Ts) ->
    (x_c, I, dt_last, t, tel)`` with ``tel`` the (6,) solver telemetry
    row."""

    def body(x_c, I, g_inv, dt_last, t, x_new_loc, idx, sidx, mask, Ts):
        return _flow_round_core(
            x_c, I, g_inv, dt_last, t, x_new_loc, idx, sidx, mask, Ts, ccfg,
            axes=axes,
        )

    c1 = P(axes)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), c1, c1, c1, c1, c1),
        out_specs=(P(), P(), P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


class ShardedBackend(MeshedBackendMixin, ExecutionBackend):
    """Multi-device cohort execution with on-device multi-round segments.

    Numerically equivalent to SequentialBackend on the same plan stream at
    rtol ≈ 1e-6 (psum re-associates the Σ_a reductions); fuzzed across
    registered algorithms / padding / participation in
    tests/test_backend_equiv.py.

    ``pad_multiple`` forces the cohort padding unit above the device count —
    used by tests to exercise uneven client→device padding even on a
    single-device host.
    """

    name = "sharded"

    # long jit-resident segments are the point, but StackedPlan memory is
    # O(R·A_pad·S_pad·bs) and each distinct R is a compile shape — 32 rounds
    # amortizes the dispatch while bounding both
    max_segment_rounds = 32

    def __init__(self, pad_multiple: Optional[int] = None,
                 max_devices: Optional[int] = None,
                 groups: Optional[int] = None):
        self._init_mesh_infra(pad_multiple, max_devices, groups=groups)
        # hierarchical tree aggregation (DESIGN.md §13): on the 2-D mesh
        # the cohort shards over BOTH axes and every cross-device reduction
        # stages intra-group psum → inter-group reduce
        self._axes = (GROUP_AXIS, AXIS) if groups and groups > 1 else AXIS
        self._vec = VectorizedBackend()
        self.last_segment_stats: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    def _check(self, sim):
        if sim.state is not None and not isinstance(sim.state.g_inv, jax.Array):
            raise NotImplementedError(
                "sharded backend supports scalar sensitivity gains only "
                "(FedSimConfig.sensitivity='scalar'); diagonal gains keep "
                "their pytree layout on the dense path"
            )

    @staticmethod
    def _segmentable(alg) -> bool:
        """Only algorithms that expose a jit-resident aggregation — the
        flow consensus or the weighted-delta (w, scale) spec — can ride the
        multi-round fori_loop segment. A protocol-conformant plugin that
        implements ``aggregate`` directly still runs sharded via the
        per-round path: grouped local integration + its dense aggregate."""
        return bool(alg.has_flow_dynamics) or callable(
            getattr(alg, "agg_weights", None)
        )

    # ------------------------------------------------------------------
    def run_rounds(self, sim, plans: List[CohortPlan]) -> List[Dict[str, Any]]:
        if not plans:
            return []
        self._check(sim)
        if not self._segmentable(sim.alg):
            return [self.run_round(sim, p) for p in plans]
        S_pad = max(
            VectorizedBackend._pad_steps(sim),
            int(max(int(p.n_steps.max()) for p in plans)),
        )
        A_pad = self._a_pad(plans[0].cohort_size)
        sp = stack_plans(plans, sim.state_rows, A_pad, S_pad)
        if sp is None:
            # ragged cohort (|partition| < batch_size somewhere): per-round
            # fallback — grouped local integration + sharded reduction
            return [self.run_round(sim, p) for p in plans]
        return self._run_segment(sim, sp)

    def run_round(self, sim, plan: CohortPlan) -> Dict[str, Any]:
        self._check(sim)
        if self._segmentable(sim.alg):
            S_pad = max(
                VectorizedBackend._pad_steps(sim), int(plan.n_steps.max())
            )
            sp = stack_plans(
                [plan], sim.state_rows, self._a_pad(plan.cohort_size), S_pad
            )
            if sp is not None:
                return self._run_segment(sim, sp)[0]
        result = self._vec.run_cohort(sim, plan)
        return self._apply_gathered(sim, plan, result)

    # ------------------------------------------------------------------
    def _run_segment(self, sim, sp: StackedPlan) -> List[Dict[str, Any]]:
        cfg = sim.cfg
        alg = sim.alg
        R = sp.n_rounds
        data = self._device_data(sim)
        arr = jnp.asarray
        ps = alg.client_weights(sim, sp.idx)

        comm = sim.comm
        if alg.has_flow_dynamics:
            fn = self._fn(
                # keyed on the loss fn too: the built closure captures it,
                # and a backend instance may be reused across sims (the
                # bench warm-up pattern); the comm cache key separates
                # compressor settings (different static closures)
                ("flow_seg", id(sim.loss_fn), alg.client_kind,
                 float(alg.client_mu()), cfg.consensus, comm.cache_key(),
                 self._axes),
                lambda: build_flow_segment(
                    self.mesh, sim.loss_fn, cfg.consensus,
                    kind=alg.client_kind, mu=float(alg.client_mu()),
                    comm=comm, axes=self._axes,
                ),
            )
            st = sim.state
            x_c, I, dt_last, t, losses, tel = fn(
                st.x_c, st.I, st.g_inv, st.dt_last, st.t, data,
                arr(sp.idx), arr(sp.scatter_idx), arr(sp.mask), arr(sp.lrs),
                arr(sp.n_steps), arr(sp.Ts), arr(sp.sel), arr(ps),
                jnp.asarray(sp.rnd0, jnp.int32),
            )
            sim.state = st._replace(
                x_c=x_c, I=I, dt_last=dt_last, t=t, round=st.round + R
            )
            # losses + telemetry ride the segment's ONE host sync
            losses, tel = jax.device_get((losses, tel))
            tel = np.asarray(tel)
        else:
            w, scale = self._avg_weights(sim, sp)
            rows = alg.client_state if alg.has_client_state else {}
            ef = alg.comm_state if alg.comm_state is not None else {}
            fn = self._fn(
                ("avg_seg", id(sim.loss_fn), alg.name,
                 float(alg.client_mu()), bool(cfg.agg_kernels),
                 comm.cache_key(), self._axes),
                lambda: build_avg_segment(
                    self.mesh, alg, sim.loss_fn, bool(cfg.agg_kernels),
                    comm=comm, axes=self._axes,
                ),
            )
            sim.params, rows, ef, losses = fn(
                sim.params, rows, ef, data, arr(sp.idx), arr(sp.scatter_idx),
                arr(sp.mask), arr(sp.sel), arr(sp.lrs), arr(sp.n_steps),
                arr(ps), arr(w), arr(scale), jnp.asarray(sp.rnd0, jnp.int32),
            )
            if alg.has_client_state:
                alg.set_client_state(rows)
            if alg.comm_state is not None:
                alg.set_comm_state(ef)
            tel = None  # no BE solver on the averaging path

        losses = np.asarray(losses)
        self.last_segment_stats = {"rounds": R, "cohort_pad": sp.cohort_pad,
                                   "n_devices": self.n_devices}
        # host-side float64 mean over the real cohort rows, mirroring the
        # sequential backend's np.mean over per-client python floats
        recs = []
        for r in range(R):
            loss_r = float(
                np.mean(losses[r][sp.mask[r] > 0].astype(np.float64))
            )
            cohort_r = int(sp.mask[r].sum())  # mask-summed: padding excluded
            # host-side bytes accounting from the mask-exact cohort — the
            # payload sizes are static per run, so no extra device sync
            byt = dict(bytes_up=cohort_r * comm.payload_up,
                       bytes_down=cohort_r * comm.payload_down)
            if tel is None:
                recs.append(make_record(sp.rnd0 + r, loss=loss_r,
                                        cohort=cohort_r, **byt))
            else:
                recs.append(make_record(
                    sp.rnd0 + r, loss=loss_r, cohort=cohort_r,
                    substeps=tel[r, 0], backtracks=tel[r, 1],
                    dt_min=tel[r, 2], dt_max=tel[r, 3], dt_sum=tel[r, 4],
                    tau_end=tel[r, 5], **byt,
                ))
        return recs

    def _avg_weights(self, sim, sp: StackedPlan):
        """Host-precomputed per-round aggregation weights from the
        algorithm's ``agg_weights`` spec (fp32 numpy, the same lines the
        dense path runs under jnp), cohort padding zeroed via the mask."""
        p_a = (sim.p_hat[sp.idx] * sp.mask).astype(np.float32)
        w, scale = sim.alg.agg_weights(p_a, sp.taus, xp=np)
        return w.astype(np.float32), scale.astype(np.float32)

    # ------------------------------------------------------------------
    def _apply_gathered(self, sim, plan: CohortPlan, result: CohortResult):
        """Ragged fallback: cohort endpoints were produced by the vectorized
        grouped runner. Flow algorithms pad them to the device multiple and
        run the sharded psum consensus; the averaging family — endpoints
        already gathered on one device — applies the algorithm's dense
        aggregate (identical weighted-delta arithmetic, dense reduction)."""
        cfg = sim.cfg
        alg = sim.alg
        if not alg.has_flow_dynamics:
            return sim._apply_round(plan, result)

        from repro.sim.engine import pad_cohort_ids

        A = plan.cohort_size
        A_pad = self._a_pad(A)
        pad = A_pad - A

        x_ref = sim.state.x_c
        if not sim.comm.lossless:
            # same dense flow hook as FedSim._apply_round: compress the
            # gathered endpoints against the dispatch reference before the
            # sharded consensus apply (padding rows are added after, so they
            # stay exactly x_c)
            result.x_new_a, _ = sim.comm.compress_endpoints(
                x_ref, result.x_new_a, None, plan.rnd
            )
        x_new_pad = jax.tree.map(
            lambda l, xc: (
                jnp.concatenate(
                    [l, jnp.broadcast_to(xc[None], (pad,) + xc.shape)]
                ) if pad else l
            ),
            result.x_new_a, x_ref,
        )
        idx, sidx, mask = pad_cohort_ids(plan.idx, A_pad, sim.state_rows)

        Ts = np.concatenate(
            [np.asarray(result.Ts, np.float32), np.zeros(pad, np.float32)]
        )
        fn = self._fn(
            ("flow_apply", cfg.consensus, self._axes),
            lambda: build_flow_apply(self.mesh, cfg.consensus,
                                     axes=self._axes),
        )
        st = sim.state
        x_c, I, dt_last, t, tel = fn(
            st.x_c, st.I, st.g_inv, st.dt_last, st.t, x_new_pad,
            jnp.asarray(idx), jnp.asarray(sidx), jnp.asarray(mask),
            jnp.asarray(Ts),
        )
        sim.state = st._replace(
            x_c=x_c, I=I, dt_last=dt_last, t=t, round=st.round + 1
        )
        tel = np.asarray(tel)
        return make_record(
            plan.rnd, loss=float(np.mean(result.losses)), cohort=A,
            substeps=tel[0], backtracks=tel[1], dt_min=tel[2],
            dt_max=tel[3], dt_sum=tel[4], tau_end=tel[5],
            bytes_up=A * sim.comm.payload_up,
            bytes_down=A * sim.comm.payload_down,
        )

"""Device-resident continuous-time event backend: async arrivals between BE
syncs, executed as jit-resident multi-round segments.

``server_round`` (core/fedecado.py) assumes the whole cohort finishes
together. Real federations are not like that — clients with small windows
T_i return early, stragglers late, some only in the *next* round. This
backend replaces the implicit barrier with the flight-table multi-rate
integrator (core/multirate.py): every dispatched client is a row of a
fixed-capacity ``FlightTable`` (stacked Γ anchors, remaining window,
staleness counter, alive mask), a round absorbs the ``horizon_quantile`` of
in-flight windows in ≤ ``max_waves`` waves of masked adaptive-BE
integration, and stragglers stay queued with their Γ anchor re-based to the
integrated time (exact by Theorem-1 linearity).

Engineering shape (matching the other backends, DESIGN.md §8):

  * ``run_rounds`` consumes whole pre-drawn ``StackedPlan`` segments: local
    cohort integration (the §5.1 vmap-over-scan runner), busy-client
    masking, flight insertion, and the wave/substep loops all execute
    inside ONE jit with a ``lax.fori_loop`` over the rounds — zero host
    syncs per round (the PR-1 scheduler synced on every adaptive substep);
  * a **sharded event mode** (``FedSimConfig.event_sharded``) runs the same
    program under ``shard_map`` on the PR-2 client mesh: the flight table's
    capacity axis and the cohort axis are sharded, wave solves psum-reduce
    through the masked ``be_step``/``lte`` path, and flow write-backs use
    the exact-set one-hot psum scatter;
  * busy clients re-drawn by the participation sampler are masked out
    BEFORE their endpoints enter the table (a client must never hold two
    flights); the per-round ``dropped`` count is reported in
    ``last_round_stats`` and ``round_stats`` rather than silently discarded;
  * an all-busy cohort dispatches no local work: the round still advances
    the server on pending arrivals, and its loss is ``nan`` to mark the gap
    (callers aggregate with the nan-aware helpers in fed/server.py);
  * ragged cohorts (|partition| < batch_size) and uneven cohort sizes
    cannot share a dense plan tensor; those rounds fall back to the grouped
    vectorized local integration and re-enter the jitted event round at the
    insert+integrate step.

With ``horizon_quantile=1.0`` every flight arrives in-round; at
``max_waves=1`` the integration is exactly the synchronous Algorithm-2
round, so the backend is pinned against the sequential oracle at rtol 1e-5
in both dense and sharded modes (tests/test_backend_equiv.py). The
Σ_i I_i = 0 fixed-point invariant is preserved under any wave/staleness
slicing (DESIGN.md §8, tests/test_engine.py, tests/test_multirate.py).

Only algorithms whose plugin declares ``has_flow_dynamics`` (the
fedecado/ecado family) have flow dynamics to schedule; every other
registered algorithm raises.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.flow import broadcast_clients
from repro.core.multirate import (
    DEAD_CID,
    FlightTable,
    flight_insert_checked,
    init_flight_table,
    multirate_integrate,
)
from repro.sim.engine import (
    CLIENT_AXIS,
    CohortPlan,
    ExecutionBackend,
    MeshedBackendMixin,
    StackedPlan,
    pad_cohort_ids,
    stack_plans,
)
from repro.obs.telemetry import (
    N_STALE_BUCKETS,
    TELEMETRY_FIELDS,
    field_index,
    pack_row,
    rows_to_records,
)
from repro.sim.vectorized import VectorizedBackend, cohort_vmap_fn

Pytree = Any

AXIS = CLIENT_AXIS   # the 1-D launch mesh axis (launch/mesh.py)

# a device stat row is the shared telemetry vector plus the staleness
# histogram columns (repro.obs.telemetry; DESIGN.md §9) plus one trailing
# backend-internal column (max_stale — stripped before records are emitted,
# so the shared record schema stays unchanged)
_ROW_W = len(TELEMETRY_FIELDS) + N_STALE_BUCKETS
_XROW_W = _ROW_W + 1
_LOSS, _COHORT, _DROPPED = (
    field_index("loss"), field_index("cohort"), field_index("dropped")
)
_BYTES_DOWN = field_index("bytes_down")


def _event_round(
    x_c, I, g_inv, dt_last, t, tab,
    x_new_rows, idx, Ts, dmask,
    ccfg, hq, max_waves, axis_name=None, offset=0,
    buffer_k=None, stale_gamma=0.0, comm=None, rnd=0,
):
    """One event round given already-integrated cohort endpoints: mask-aware
    flight insertion + the wave integrator. ``x_new_rows``/``idx``/``Ts``/
    ``dmask`` are table-global (dense) or all-gathered-to-global (sharded)
    cohort rows. Returns (x_c, I, dt_last, t, tab, stats (_XROW_W,) f32 —
    the shared telemetry row + staleness-histogram columns + the trailing
    max_stale column; the loss / cohort slots are filled by the caller and
    the dropped slot seeded with the traced insert's busy refusals — the
    jit-safe masked-drop contract — for the caller to ``.add`` its own
    pre-insert drops onto)."""
    A = idx.shape[0]
    x_prev_rows = broadcast_clients(x_c, A)
    if comm is not None and not comm.lossless:
        # lossy wire: the endpoints enter the flight table already
        # compressed against the dispatch reference x_c — stragglers then
        # age and re-base on the COMPRESSED endpoint, exactly what a real
        # buffered server would hold. EF-free (flow family contract).
        x_new_rows, _ = comm.compress_endpoints(x_c, x_new_rows, None, rnd)
    tab, refused = flight_insert_checked(
        tab, idx, x_prev_rows, x_new_rows, Ts, dmask, offset=offset
    )
    if axis_name:
        refused = jax.lax.psum(refused, axis_name)
    x_c, I, dt_last, t, tab, st = multirate_integrate(
        x_c, I, g_inv, dt_last, t, tab, ccfg, hq, max_waves,
        axis_name=axis_name, buffer_k=buffer_k, stale_gamma=stale_gamma,
    )
    # uplink bytes are charged at ABSORPTION (arrived × payload): a flight's
    # endpoint reaches the server when its window closes, not at dispatch —
    # so stragglers' bytes land in the round that drains them. The payload
    # sizes are static python ints, so this stays jit-safe.
    payload_up = 0 if comm is None else comm.payload_up
    row = pack_row(
        substeps=st.substeps, backtracks=st.backtracks,
        dt_min=st.dt_min, dt_max=st.dt_max, dt_sum=st.dt_sum,
        waves=st.waves, arrived=st.arrived, stale=st.stale,
        horizon=st.horizon, tau_end=st.tau_end,
        bytes_up=st.arrived * float(payload_up),
    )
    row = row.at[_DROPPED].set(refused)
    stats = jnp.concatenate(
        [row, st.stale_hist, st.max_stale.astype(jnp.float32)[None]]
    )
    return x_c, I, dt_last, t, tab, stats


def _masked_loss(loss, dmask, axis_name=None):
    """nan-aware cohort loss: mean over dispatched rows, nan when none (the
    all-busy-cohort marker the nan-aware history helpers understand)."""
    s = jnp.sum(loss * dmask)
    c = jnp.sum(dmask)
    if axis_name:
        s = jax.lax.psum(s, axis_name)
        c = jax.lax.psum(c, axis_name)
    return jnp.where(c > 0, s / jnp.maximum(c, 1.0), jnp.nan), c


def build_event_segment(
    loss_fn: Callable, ccfg, kind: str, mu: float, hq: float, max_waves: int,
    buffer_k: Optional[int] = None, stale_gamma: float = 0.0, comm=None,
) -> Callable:
    """Jitted R-round dense event segment.

    ``fn(x_c, I, g_inv, dt_last, t, tab, data, idx, mask, lrs, ns, Ts, sel,
    ps) -> (x_c, I, dt_last, t, tab, stats (R, _XROW_W), part (n,))`` where
    the plan arrays are ``StackedPlan`` fields, ``stats`` rows follow the
    shared telemetry schema (+ staleness-histogram + max_stale columns) and
    ``part`` counts per-client dispatches (busy re-draws excluded).
    ``buffer_k``/``stale_gamma`` select the buffered-server K-trigger and
    staleness weighting (DESIGN.md §10).
    """
    cohort = cohort_vmap_fn(loss_fn, kind, mu)
    payload_down = 0 if comm is None else comm.payload_down

    def body(x_c, I, g_inv, dt_last, t, tab, data, idx, mask, lrs, ns, Ts,
             sel, ps, rnd0):
        R, A = idx.shape
        n = jax.tree.leaves(I)[0].shape[0]

        def round_step(r, carry):
            x_c, I, dt_last, t, tab, out, part = carry
            batches = {k: v[sel[r]] for k, v in data.items()}
            I_rows = jax.tree.map(lambda l: l[idx[r]], I)
            x_new_a, loss_a = cohort(x_c, I_rows, batches, lrs[r], ps[r], ns[r])
            # a client still in flight is busy: re-dispatching it would put
            # one flow row in two flights, so its draw is masked out before
            # the endpoint can enter the table (direct-indexed busy lookup)
            busy = tab.alive[idx[r]]
            dmask = mask[r] * (1.0 - busy)
            x_c, I, dt_last, t, tab, stats = _event_round(
                x_c, I, g_inv, dt_last, t, tab,
                x_new_a, idx[r], Ts[r], dmask,
                ccfg, hq, max_waves,
                buffer_k=buffer_k, stale_gamma=stale_gamma,
                comm=comm, rnd=rnd0 + r,
            )
            loss_r, n_disp = _masked_loss(loss_a, dmask)
            stats = stats.at[_DROPPED].add(jnp.sum(mask[r] * busy))
            stats = stats.at[_LOSS].set(loss_r)
            stats = stats.at[_COHORT].set(n_disp)
            # downlink: the broadcast reference ships to each client actually
            # dispatched this round (busy re-draws receive nothing)
            stats = stats.at[_BYTES_DOWN].set(n_disp * float(payload_down))
            part = part.at[idx[r]].add(dmask, mode="drop")
            return (x_c, I, dt_last, t, tab, out.at[r].set(stats), part)

        out0 = jnp.zeros((R, _XROW_W), jnp.float32)
        part0 = jnp.zeros((n,), jnp.float32)
        return jax.lax.fori_loop(
            0, R, round_step, (x_c, I, dt_last, t, tab, out0, part0)
        )

    return jax.jit(body)


def build_event_segment_sharded(
    mesh, loss_fn: Callable, ccfg, kind: str, mu: float, hq: float,
    max_waves: int, buffer_k: Optional[int] = None, stale_gamma: float = 0.0,
    comm=None,
) -> Callable:
    """The sharded event segment: same contract as ``build_event_segment``
    but shard_map-ed over the client mesh — cohort axis and flight-table
    capacity axis sharded, wave solves psum-reduced, plan arrays (R, A_pad)
    sharded on the cohort axis. Freshly dispatched endpoints are
    all-gathered once per round so each shard can claim its table slots
    (the lossy round-trip runs on the gathered rows inside ``_event_round``
    — replicated per-row compute, one compression site for both modes)."""
    cohort = cohort_vmap_fn(loss_fn, kind, mu)
    payload_down = 0 if comm is None else comm.payload_down

    def body(x_c, I, g_inv, dt_last, t, tab, data, idx, mask, lrs, ns, Ts,
             sel, ps, rnd0):
        R, A_loc = idx.shape
        C_loc = tab.alive.shape[0]
        n = jax.tree.leaves(I)[0].shape[0]
        offset = jax.lax.axis_index(AXIS) * C_loc
        gather = lambda a: jax.lax.all_gather(a, AXIS, tiled=True)

        def round_step(r, carry):
            x_c, I, dt_last, t, tab, out, part = carry
            batches = {k: v[sel[r]] for k, v in data.items()}
            I_rows = jax.tree.map(lambda l: l[idx[r]], I)
            x_new_loc, loss_loc = cohort(x_c, I_rows, batches, lrs[r], ps[r], ns[r])
            alive_all = gather(tab.alive)          # (C_pad,) slot order
            busy_loc = alive_all[idx[r]]
            dmask_loc = mask[r] * (1.0 - busy_loc)
            x_c, I, dt_last, t, tab, stats = _event_round(
                x_c, I, g_inv, dt_last, t, tab,
                jax.tree.map(gather, x_new_loc),
                gather(idx[r]), gather(Ts[r]), gather(dmask_loc),
                ccfg, hq, max_waves, axis_name=AXIS, offset=offset,
                buffer_k=buffer_k, stale_gamma=stale_gamma,
                comm=comm, rnd=rnd0 + r,
            )
            loss_r, n_disp = _masked_loss(loss_loc, dmask_loc, AXIS)
            dropped = jax.lax.psum(jnp.sum(mask[r] * busy_loc), AXIS)
            stats = stats.at[_DROPPED].add(dropped)
            stats = stats.at[_LOSS].set(loss_r)
            stats = stats.at[_COHORT].set(n_disp)
            stats = stats.at[_BYTES_DOWN].set(n_disp * float(payload_down))
            part = part.at[idx[r]].add(dmask_loc, mode="drop")
            return (x_c, I, dt_last, t, tab, out.at[r].set(stats), part)

        out0 = jnp.zeros((R, _XROW_W), jnp.float32)
        part0 = jnp.zeros((n,), jnp.float32)
        x_c, I, dt_last, t, tab, out, part = jax.lax.fori_loop(
            0, R, round_step, (x_c, I, dt_last, t, tab, out0, part0)
        )
        # each shard counted its local cohort rows; reduce to the replicated
        # global participation vector
        part = jax.lax.psum(part, AXIS)
        return x_c, I, dt_last, t, tab, out, part

    c2 = P(None, AXIS)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(AXIS), P(),
                  c2, c2, c2, c2, c2, c2, c2, P()),
        out_specs=(P(), P(), P(), P(), P(AXIS), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)


def build_event_apply(
    ccfg, hq: float, max_waves: int,
    buffer_k: Optional[int] = None, stale_gamma: float = 0.0, comm=None,
) -> Callable:
    """Insert+integrate-only dense event round (the ragged fallback): local
    integration already happened on the gathered cohort."""

    def body(x_c, I, g_inv, dt_last, t, tab, x_new_a, idx, Ts, dmask, rnd):
        return _event_round(
            x_c, I, g_inv, dt_last, t, tab, x_new_a, idx, Ts, dmask,
            ccfg, hq, max_waves,
            buffer_k=buffer_k, stale_gamma=stale_gamma, comm=comm, rnd=rnd,
        )

    return jax.jit(body)


def build_event_apply_sharded(
    mesh, ccfg, hq: float, max_waves: int,
    buffer_k: Optional[int] = None, stale_gamma: float = 0.0, comm=None,
) -> Callable:
    """Sharded ragged fallback: cohort rows arrive device-sharded, the
    table shards claim their slots after an all-gather."""

    def body(x_c, I, g_inv, dt_last, t, tab, x_new_loc, idx_loc, Ts_loc,
             dm_loc, rnd):
        C_loc = tab.alive.shape[0]
        offset = jax.lax.axis_index(AXIS) * C_loc
        gather = lambda a: jax.lax.all_gather(a, AXIS, tiled=True)
        return _event_round(
            x_c, I, g_inv, dt_last, t, tab,
            jax.tree.map(gather, x_new_loc),
            gather(idx_loc), gather(Ts_loc), gather(dm_loc),
            ccfg, hq, max_waves, axis_name=AXIS, offset=offset,
            buffer_k=buffer_k, stale_gamma=stale_gamma, comm=comm, rnd=rnd,
        )

    c1 = P(AXIS)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(AXIS), c1, c1, c1, c1, P()),
        out_specs=(P(), P(), P(), P(), P(AXIS), P()),
        check_rep=False,
    )
    return jax.jit(fn)


class EventBackend(MeshedBackendMixin, ExecutionBackend):
    """Event-driven FedECADO rounds with straggler staleness, device-resident.

    ``sharded=True`` runs the flight table and wave solves over the PR-2
    client mesh (``FedSimConfig.event_sharded``); ``pad_multiple`` forces
    the cohort/capacity padding unit above the device count so tests can
    exercise uneven padding on any host (DESIGN.md §5.5 sentinels).

    ``buffered=True`` (``FedSimConfig.event_buffered``) switches the
    per-round horizon to the fully-asynchronous buffered-server K-trigger
    (DESIGN.md §10): the server drains only when ``buffer_size`` endpoints
    are in flight, aging flights' endpoints are damped by the
    ``stale_gamma`` staleness weight, and arrival-process scenario cohorts
    (uneven sizes across rounds) stay jit-resident through padded
    ``StackedPlan`` stacking instead of the per-round fallback. The
    ``max_stale`` attribute tracks the oldest flight ever left pending —
    the bounded-staleness metric BENCH_engine.json reports.
    """

    name = "event"

    # event segments are jit-resident like the sharded backend's; 16 rounds
    # amortizes dispatch while bounding StackedPlan memory and compile time
    # for the nested wave/substep loops
    max_segment_rounds = 16

    def __init__(self, horizon_quantile: float = 1.0, max_waves: int = 4,
                 sharded: bool = False, pad_multiple: Optional[int] = None,
                 max_devices: Optional[int] = None, buffered: bool = False,
                 buffer_size: int = 0, stale_gamma: float = 0.0):
        assert 0.0 < horizon_quantile <= 1.0, horizon_quantile
        self.horizon_quantile = float(horizon_quantile)
        self.max_waves = max(1, int(max_waves))
        self.sharded = bool(sharded)
        self.buffered = bool(buffered)
        self.buffer_size = int(buffer_size)
        self.stale_gamma = float(stale_gamma)
        if self.buffered and self.buffer_size < 1:
            raise ValueError(
                "buffered event mode needs a positive aggregation buffer: "
                f"got buffer_size={buffer_size!r} (set "
                "FedSimConfig.event_buffer_size >= 1, <= n_clients)"
            )
        if self.stale_gamma < 0.0:
            raise ValueError(
                f"stale_gamma must be >= 0 (got {stale_gamma!r}); 0 disables "
                "staleness weighting"
            )
        self._init_mesh_infra(pad_multiple, max_devices)
        self._vec = VectorizedBackend()
        self._table: Optional[FlightTable] = None
        self._owner = None               # the FedSim the table belongs to
        self.last_round_stats: Dict[str, Any] = {}
        self.round_stats: List[Dict[str, Any]] = []   # one dict per round
        self.total_dropped = 0
        self.max_stale = 0               # oldest flight ever left pending
        self._part = None                # (n,) device-exact dispatch counts

    @property
    def _buffer_k(self) -> Optional[int]:
        return self.buffer_size if self.buffered else None

    def _pad_unit(self) -> int:
        # the dense mode never touches the mesh: capacity = n_clients and
        # cohorts stay unpadded
        return super()._pad_unit() if self.sharded else 1

    # ------------------------------------------------------------------
    def _ensure(self, sim) -> None:
        if not sim.alg.has_flow_dynamics:
            raise ValueError(
                "the event backend schedules flow dynamics and only supports "
                "algorithms whose plugin declares has_flow_dynamics, got "
                f"{sim.cfg.algorithm!r}"
            )
        if self.sharded and not isinstance(sim.state.g_inv, jax.Array):
            raise NotImplementedError(
                "sharded event mode supports scalar sensitivity gains only "
                "(FedSimConfig.sensitivity='scalar'); diagonal gains keep "
                "their pytree layout on the dense path"
            )
        if self.buffered and self.buffer_size > sim.n:
            raise ValueError(
                f"buffer_size={self.buffer_size} exceeds the flight table "
                f"capacity (n_clients={sim.n}): the K-trigger could never "
                "fire and the server would stall forever"
            )
        if self._owner is not sim:
            # a backend instance may be reused across sims (the bench/sweep
            # warm-up pattern keeps jit caches); the flight table is per-sim
            # state and must reset with its owner. Capacity follows the
            # packed state size (== n materialized, cache capacity cached) —
            # plan.idx rows index the table directly in both modes.
            self._owner = sim
            self._table = init_flight_table(
                sim.state.x_c, self._a_pad(sim.state_rows)
            )
            self.round_stats = []
            self.total_dropped = 0
            self.max_stale = 0
            self._part = np.zeros((sim.state_rows,), np.int64)

    def on_cache_repack(self, sim, repack) -> None:
        """Client-state-cache repack (DESIGN.md §13): the flight table is
        slot-indexed in cached mode, so live flights must move with their
        rows. The repack is a pure gather (exact — anchors/endpoints keep
        their bits), the direct-index ``cid`` column is rewritten to the
        new slot ids, and the host-side dispatch counters permute along."""
        if self._owner is not sim or self._table is None:
            return
        from repro.sim.cache import RepackPlan, repack_rows

        C_new = self._a_pad(repack.capacity)
        src = np.full((C_new,), -1, np.int64)
        src[: repack.capacity] = repack.src
        plan2 = RepackPlan(
            src=src, fresh=repack.fresh, fresh_cids=repack.fresh_cids,
            capacity=int(C_new), n_admitted=repack.n_admitted,
        )
        moved = repack_rows(self._table, plan2)
        cid = jnp.where(
            moved.alive > 0,
            jnp.arange(C_new, dtype=jnp.int32),
            jnp.int32(DEAD_CID),
        )
        self._table = moved._replace(cid=cid)
        part = np.zeros((repack.capacity,), np.int64)
        keep = repack.src >= 0
        part[np.flatnonzero(keep)] = self._part[repack.src[keep]]
        self._part = part

    def _ccfg_key(self, sim):
        return (
            sim.cfg.consensus, self.horizon_quantile, self.max_waves,
            self.sharded, self._buffer_k, self.stale_gamma,
            sim.comm.cache_key(),
        )

    # ------------------------------------------------------------------
    def run_rounds(self, sim, plans: List[CohortPlan]) -> List[Dict[str, Any]]:
        if not plans:
            return []
        self._ensure(sim)
        S_pad = max(
            VectorizedBackend._pad_steps(sim),
            int(max(int(p.n_steps.max()) for p in plans)),
        )
        # buffered mode consumes arrival-process cohorts whose sizes vary
        # round to round; pad them into one dense segment so the whole
        # buffered loop stays jit-resident instead of falling back per-round
        A_pad = self._a_pad(max(p.cohort_size for p in plans))
        sp = stack_plans(plans, sim.state_rows, A_pad, S_pad,
                         allow_uneven=self.buffered)
        if sp is None:
            # ragged / uneven cohorts: per-round fallback (grouped local
            # integration + the jitted insert/integrate event round)
            return [self.run_round(sim, p) for p in plans]
        return self._run_segment(sim, sp)

    def run_round(self, sim, plan: CohortPlan) -> Dict[str, Any]:
        self._ensure(sim)
        S_pad = max(VectorizedBackend._pad_steps(sim), int(plan.n_steps.max()))
        sp = stack_plans([plan], sim.state_rows,
                         self._a_pad(plan.cohort_size), S_pad)
        if sp is not None:
            return self._run_segment(sim, sp)[0]
        return self._run_ragged(sim, plan)

    # ------------------------------------------------------------------
    def _run_segment(self, sim, sp: StackedPlan) -> List[Dict[str, Any]]:
        cfg = sim.cfg
        alg = sim.alg
        R = sp.n_rounds
        data = self._device_data(sim)
        arr = jnp.asarray
        ps = alg.client_weights(sim, sp.idx)
        kind, mu = alg.client_kind, float(alg.client_mu())

        if self.sharded:
            builder = lambda: build_event_segment_sharded(
                self.mesh, sim.loss_fn, cfg.consensus, kind, mu,
                self.horizon_quantile, self.max_waves,
                buffer_k=self._buffer_k, stale_gamma=self.stale_gamma,
                comm=sim.comm,
            )
        else:
            builder = lambda: build_event_segment(
                sim.loss_fn, cfg.consensus, kind, mu,
                self.horizon_quantile, self.max_waves,
                buffer_k=self._buffer_k, stale_gamma=self.stale_gamma,
                comm=sim.comm,
            )
        fn = self._fn(
            ("event_seg", id(sim.loss_fn), kind, mu, self._ccfg_key(sim)),
            builder,
        )
        st = sim.state
        x_c, I, dt_last, t, tab, out, part = fn(
            st.x_c, st.I, st.g_inv, st.dt_last, st.t, self._table, data,
            arr(sp.idx), arr(sp.mask), arr(sp.lrs), arr(sp.n_steps),
            arr(sp.Ts), arr(sp.sel), arr(ps), jnp.asarray(sp.rnd0, jnp.int32),
        )
        sim.state = st._replace(
            x_c=x_c, I=I, dt_last=dt_last, t=t, round=st.round + R
        )
        self._table = tab
        out_h, part_h = jax.device_get((out, part))  # ONE sync per segment
        self._part += np.rint(np.asarray(part_h)).astype(np.int64)
        return self._emit_stats(sp.rnd0, np.asarray(out_h))

    # ------------------------------------------------------------------
    def _run_ragged(self, sim, plan: CohortPlan) -> Dict[str, Any]:
        cfg = sim.cfg
        # cohort-sized busy lookup: gather the A alive flags on device and
        # pull only those — the old full-table device_get was an O(n) host
        # transfer per ragged round at million-client n
        busy = np.asarray(jax.device_get(
            jnp.take(self._table.alive, jnp.asarray(plan.idx, jnp.int32))
        )) > 0
        keep = [j for j in range(plan.cohort_size) if not busy[j]]
        dropped = plan.cohort_size - len(keep)

        if keep:
            sub = CohortPlan(
                rnd=plan.rnd, idx=plan.idx[keep], lrs=plan.lrs[keep],
                epochs=plan.epochs[keep], n_steps=plan.n_steps[keep],
                batch_idx=[plan.batch_idx[j] for j in keep],
            )
            result = self._vec.run_cohort(sim, sub)
            x_new_a, idx = result.x_new_a, sub.idx
            Ts = np.asarray(result.Ts, np.float32)
            loss = float(np.mean(result.losses))
        else:
            # all-busy: no local work — the round still advances the server
            # on pending arrivals; a dummy masked row keeps shapes static
            x_new_a = broadcast_clients(sim.state.x_c, 1)
            idx = np.zeros((1,), np.int64)
            Ts = np.zeros((1,), np.float32)
            loss = float("nan")

        A = len(idx)
        A_pad = self._a_pad(A)
        idx_p, _, mask_p = pad_cohort_ids(np.asarray(idx), A_pad,
                                          sim.state_rows)
        if not keep:
            mask_p = np.zeros_like(mask_p)
        pad = A_pad - A
        Ts_p = np.concatenate([Ts, np.zeros(pad, np.float32)])
        x_ref = sim.state.x_c
        x_new_p = jax.tree.map(
            lambda l, xc: (
                jnp.concatenate(
                    [l, jnp.broadcast_to(xc[None], (pad,) + xc.shape)]
                ) if pad else l
            ),
            x_new_a, x_ref,
        )

        if self.sharded:
            builder = lambda: build_event_apply_sharded(
                self.mesh, cfg.consensus, self.horizon_quantile,
                self.max_waves,
                buffer_k=self._buffer_k, stale_gamma=self.stale_gamma,
                comm=sim.comm,
            )
        else:
            builder = lambda: build_event_apply(
                cfg.consensus, self.horizon_quantile, self.max_waves,
                buffer_k=self._buffer_k, stale_gamma=self.stale_gamma,
                comm=sim.comm,
            )
        fn = self._fn(("event_apply", self._ccfg_key(sim)), builder)
        st = sim.state
        x_c, I, dt_last, t, tab, stats = fn(
            st.x_c, st.I, st.g_inv, st.dt_last, st.t, self._table,
            x_new_p, jnp.asarray(idx_p), jnp.asarray(Ts_p),
            jnp.asarray(mask_p), jnp.asarray(plan.rnd, jnp.int32),
        )
        sim.state = st._replace(
            x_c=x_c, I=I, dt_last=dt_last, t=t, round=st.round + 1
        )
        self._table = tab
        if keep:
            np.add.at(self._part, np.asarray(plan.idx)[keep], 1)
        out = np.array(stats, np.float32)[None, :]
        out[0, _DROPPED] += float(dropped)   # on top of traced-insert refusals
        out[0, _LOSS] = loss
        out[0, _COHORT] = float(len(keep))
        # bytes_up is already in the stats row (absorbed × payload, device-
        # side); the downlink is host-known — dispatched clients only
        out[0, _BYTES_DOWN] = float(len(keep) * sim.comm.payload_down)
        return self._emit_stats(plan.rnd, out)[0]

    # ------------------------------------------------------------------
    def pop_participation(self) -> Optional[np.ndarray]:
        """Device-exact per-client dispatch counts accumulated since the
        last pop (busy re-draws excluded — plan-derived counts would
        overcount exactly those)."""
        if self._part is None:
            return None
        part, self._part = self._part, np.zeros_like(self._part)
        cache = getattr(self._owner, "cache", None)
        if cache is not None:
            # slot-indexed counts → the (n,) per-client vector callers expect
            full = np.zeros((cache.n,), np.int64)
            full[cache.cids] = part[: cache.n_admitted]
            return full
        return part

    def _emit_stats(self, rnd0: int, out: np.ndarray) -> List[Dict[str, Any]]:
        """(R, _XROW_W) stat rows -> shared per-round telemetry records +
        the backend's running counters (round_stats / last_round_stats /
        total_dropped keep their pre-telemetry keys, now as a superset).
        The trailing backend-internal max_stale column feeds the
        ``max_stale`` attribute and is stripped before records are built —
        the shared record schema (obs/telemetry.py) is pinned to an exact
        key set and stays unchanged."""
        F = len(TELEMETRY_FIELDS)
        if out.shape[1] > _ROW_W:
            self.max_stale = max(self.max_stale, int(out[:, _ROW_W].max()))
            out = out[:, :_ROW_W]
        recs = rows_to_records(int(rnd0), out[:, :F], out[:, F:])
        for rec in recs:
            self.total_dropped += rec["dropped"]
            self.round_stats.append(rec)
            self.last_round_stats = rec
        return recs

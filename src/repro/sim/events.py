"""Continuous-time event scheduler: asynchronous arrivals between BE syncs.

``server_round`` (core/fedecado.py) assumes the whole cohort finishes
together: the server waits for every endpoint, then integrates the central
ODE over [0, max_i T_i] in one go. Real federations are not like that —
clients with small windows T_i = e_i·lr_i·steps return early, stragglers
late, some only in the *next* round. This module replaces the implicit
barrier with an event queue:

  * every dispatched client is an ``InFlight`` record carrying its Γ
    anchors (round-start state x_prev, endpoint x_new) and its remaining
    window;
  * a round processes arrivals in time order, grouped into at most
    ``max_waves`` waves; between consecutive wave boundaries the server
    runs adaptive Backward-Euler steps (Algorithm 1) with the active set =
    clients arrived *so far* (finished clients keep contributing through Γ
    extrapolation, exactly as in the synchronous round) while the flows of
    everyone else stay frozen in S_frozen;
  * the round horizon is the ``horizon_quantile`` q of the in-flight
    remaining windows. Clients beyond the horizon are STALE: they stay in
    the queue and return mid-round next time, their Γ anchor re-based to
    the centrally integrated time τ_end = max(arrived T_rem) (the line
    through (Γ(τ_end), x_new) over the remaining window is the same line,
    so re-anchoring is exact — Theorem 1's linearity) — no recomputation,
    no dropped work.

With q = 1.0 every client arrives in-round and the trajectory matches the
synchronous semantics up to wave granularity. The Σ_i I_i = 0 fixed-point
invariant of the consensus solve is preserved by construction: each wave's
BE solve sees Σ_active I_a + S_frozen = Σ_all I_i, so a state at the
critical point stays there no matter how arrivals are sliced
(tests/test_engine.py::test_event_staleness_preserves_flow_invariant).

Only algorithms whose plugin declares ``has_flow_dynamics`` (the
fedecado/ecado family) have flow dynamics to schedule; every other
registered algorithm raises.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import adaptive_be_step
from repro.core.flow import gather_active, put_rows
from repro.sim.engine import CohortPlan, ExecutionBackend
from repro.sim.vectorized import VectorizedBackend

Pytree = Any


@dataclasses.dataclass
class InFlight:
    """A dispatched client that has not yet been absorbed by the server."""
    cid: int
    x_prev: Pytree      # Γ anchor at the start of the remaining window
    x_new: Pytree       # local endpoint x_i(T_i)
    T_rem: float        # remaining continuous-time window
    stale_rounds: int = 0


def _stack(trees: List[Pytree]) -> Pytree:
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


class EventBackend(ExecutionBackend):
    """Event-driven FedECADO round with straggler staleness."""

    name = "event"

    def __init__(self, horizon_quantile: float = 1.0, max_waves: int = 4):
        assert 0.0 < horizon_quantile <= 1.0, horizon_quantile
        self.horizon_quantile = horizon_quantile
        self.max_waves = max(1, int(max_waves))
        self.pending: List[InFlight] = []
        self._cohort = VectorizedBackend()
        self._abe = None            # jitted adaptive BE step, built lazily
        self.last_round_stats: dict = {}

    # ------------------------------------------------------------------
    def _be_fn(self, sim):
        if self._abe is None:
            # the fused-kernel BE path assumes Γ anchors equal the current
            # broadcast x_c (how the synchronous round constructs x_prev_a);
            # stale flights here carry re-based anchors, so always use the
            # explicit-anchor path regardless of ConsensusConfig.use_kernels
            ccfg = dataclasses.replace(sim.cfg.consensus, use_kernels=False)
            self._abe = jax.jit(partial(adaptive_be_step, ccfg=ccfg))
        return self._abe

    def _integrate_window(
        self, sim, flights: List[InFlight], tau0: float, tau1: float
    ) -> tuple:
        """Adaptive-BE integrate the central ODE over [tau0, tau1] with the
        given arrived clients active; mutates ``sim.state``. Returns
        (substeps taken, τ actually reached) — the two differ from the
        request when ``max_substeps`` caps a stiff window, and the caller
        must continue from the reached τ, not the nominal boundary."""
        if tau1 <= tau0 + 1e-12:
            return 0, tau0
        state = sim.state
        ccfg = sim.cfg.consensus
        idx = jnp.asarray([f.cid for f in flights], jnp.int32)
        x_prev_a = _stack([f.x_prev for f in flights])
        x_new_a = _stack([f.x_new for f in flights])
        T_a = jnp.asarray([f.T_rem for f in flights], jnp.float32)
        J_a, S_frozen, g_inv_a = gather_active(state, idx)

        be = self._be_fn(sim)
        x_c, I_a = state.x_c, J_a
        tau, dt = float(tau0), float(state.dt_last)
        n_sub = 0
        while tau < tau1 - 1e-9 and n_sub < ccfg.max_substeps:
            dt0 = min(dt, ccfg.dt_max, tau1 - tau)
            res = be(
                x_c, I_a, J_a, x_prev_a, x_new_a, T_a, g_inv_a, S_frozen,
                jnp.asarray(tau, jnp.float32), jnp.asarray(dt0, jnp.float32),
            )
            x_c, I_a = res.x_c, res.I_a
            used = float(res.dt_used)
            tau += used
            grow = 1.5 if float(res.eps) < 0.5 * ccfg.delta else 1.0
            dt = min(used * grow, ccfg.dt_max)
            n_sub += 1

        sim.state = state._replace(
            x_c=x_c,
            I=put_rows(state.I, idx, I_a),
            dt_last=jnp.asarray(dt, jnp.float32),
            t=state.t + jnp.asarray(tau - tau0, jnp.float32),
        )
        return n_sub, tau

    # ------------------------------------------------------------------
    def run_round(self, sim, plan: CohortPlan):
        cfg = sim.cfg
        if not sim.alg.has_flow_dynamics:
            raise ValueError(
                "the event backend schedules flow dynamics and only supports "
                "algorithms whose plugin declares has_flow_dynamics, got "
                f"{cfg.algorithm!r}"
            )

        # 1. local integration for the newly dispatched cohort (batched).
        # A client still in flight from a previous round is busy and cannot
        # be re-dispatched (it would put the same flow row in two scheduler
        # records and double-count it in the S_frozen bookkeeping), so busy
        # draws are dropped from the plan BEFORE any local work runs.
        busy = {f.cid for f in self.pending}
        keep = [j for j in range(plan.cohort_size) if int(plan.idx[j]) not in busy]
        fresh, losses = [], []
        if keep:
            sub = CohortPlan(
                rnd=plan.rnd,
                idx=plan.idx[keep],
                lrs=plan.lrs[keep],
                epochs=plan.epochs[keep],
                n_steps=plan.n_steps[keep],
                batch_idx=[plan.batch_idx[j] for j in keep],
            )
            result = self._cohort.run_cohort(sim, sub)
            x_c_anchor = sim.state.x_c
            fresh = [
                InFlight(
                    cid=int(sub.idx[j]),
                    x_prev=x_c_anchor,
                    x_new=jax.tree.map(lambda l, j=j: l[j], result.x_new_a),
                    T_rem=float(result.Ts[j]),
                )
                for j in range(len(keep))
            ]
            losses = result.losses
        flights = self.pending + fresh

        # 2. round horizon: quantile of remaining windows; always admit at
        # least the earliest arrival so the server makes progress
        rems = np.asarray([f.T_rem for f in flights], np.float64)
        W = float(np.quantile(rems, self.horizon_quantile))
        W = max(W, float(rems.min()))

        arrived = sorted(
            (f for f in flights if f.T_rem <= W + 1e-12), key=lambda f: f.T_rem
        )
        stale = [f for f in flights if f.T_rem > W + 1e-12]

        # 3. waves: at most max_waves sync groups at arrival-time boundaries
        n_waves = min(self.max_waves, len(arrived))
        groups = [list(g) for g in np.array_split(np.arange(len(arrived)), n_waves)]
        tau0, active, n_sub, n_waves_run = 0.0, [], 0, 0
        for g in groups:
            if not g:
                continue
            active = active + [arrived[k] for k in g]
            tau1 = max(f.T_rem for f in active)
            sub, reached = self._integrate_window(sim, active, tau0, tau1)
            n_sub += sub
            # continue from the τ actually integrated: when max_substeps
            # caps a stiff window, restarting at the nominal boundary would
            # silently skip (reached, tau1] of the central ODE
            tau0 = max(tau0, reached)
            n_waves_run += 1

        # 4. stale clients: deduct only the centrally *integrated* window
        # tau_end = max(arrived T_rem) <= W — deducting the full horizon W
        # would skip the segment (tau_end, W] of each straggler's trajectory
        # from every BE solve — and re-anchor Γ there (exact by linearity)
        tau_end = tau0
        frac = lambda f: tau_end / max(f.T_rem, 1e-12)
        self.pending = [
            InFlight(
                cid=f.cid,
                x_prev=jax.tree.map(
                    lambda a, b, fr=frac(f): a + (b - a) * jnp.float32(fr),
                    f.x_prev, f.x_new,
                ),
                x_new=f.x_new,
                T_rem=f.T_rem - tau_end,
                stale_rounds=f.stale_rounds + 1,
            )
            for f in stale
        ]

        sim.state = sim.state._replace(round=sim.state.round + 1)
        self.last_round_stats = {
            "arrived": len(arrived),
            "stale": len(self.pending),
            "waves": n_waves_run,
            "substeps": n_sub,
            "horizon": W,
            "tau_end": tau_end,
        }
        # all-busy cohorts dispatch no local work; nan marks the gap rather
        # than pretending a loss was observed
        loss = float(np.mean(losses)) if losses else float("nan")
        return {"loss": loss, **self.last_round_stats}

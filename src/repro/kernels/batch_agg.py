"""Batched cohort-aggregation kernel (Pallas TPU).

The fedavg/fedprox/fednova server step is a masked weighted reduction over
the cohort axis:

  out[d] = x_c[d] + scale · Σ_a w_a·mask_a·(x_new[a, d] − x_c[d])

The jnp baseline materializes the (A, D) broadcast difference before
reducing; this kernel fuses broadcast, weighting, and the Σ_a reduction in
one read of each (A, TILE_D) tile and one write of the (TILE_D,) output —
the aggregation is purely memory-bound, so the fusion is the whole win.
``scale`` carries FedNova's effective step τ_eff (1.0 for FedAvg); the
caller folds p̂ normalization and any 1/τ_a factors into ``w``.

Blocking mirrors kernels/consensus.py: grid over D tiles, the whole cohort
axis resident per tile. Validated on CPU in interpret mode against
kernels/ref.py::batch_agg_ref (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 1024


def _batch_agg_kernel(scal_ref, w_ref, mask_ref, xc_ref, xnew_ref, out_ref):
    scale = scal_ref[0]
    w = (w_ref[:] * mask_ref[:])[:, None]
    xc = xc_ref[:]
    delta = jnp.sum(w * (xnew_ref[:, :] - xc[None]), axis=0)
    out_ref[:] = xc + scale * delta


def _batch_agg_partial_kernel(w_ref, mask_ref, xc_ref, xnew_ref, out_ref):
    w = (w_ref[:] * mask_ref[:])[:, None]
    out_ref[:] = jnp.sum(w * (xnew_ref[:, :] - xc_ref[:][None]), axis=0)


def batch_agg_partial_call(
    x_c, x_new, w, mask, *, interpret: bool = True, tile_d: int = TILE_D
):
    """Device-local partial of the sharded cohort reduction:

      partial[d] = Σ_a w_a·mask_a·(x_new[a, d] − x_c[d])

    The sharded execution backend (sim/sharded.py) holds one cohort shard
    per device; this kernel produces the shard's weighted-delta partial and
    the caller ``psum``s partials across the client mesh axis before
    applying ``x_c + scale·Σ`` (kernels/ops.py::batch_agg_psum). Same
    blocking as the fused single-device kernel above.
    """
    A, D = x_new.shape
    assert D % tile_d == 0, (D, tile_d)
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    return pl.pallas_call(
        _batch_agg_partial_kernel,
        grid=(D // tile_d,),
        in_specs=[
            full((A,)), full((A,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((A, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(w, mask, x_c, x_new)


def batch_agg_call(
    x_c, x_new, w, mask, scale, *, interpret: bool = True, tile_d: int = TILE_D
):
    """out (D,) = x_c + scale·Σ_a w_a·mask_a·(x_new[a] − x_c).

    x_c (D,); x_new (A, D); w, mask (A,); scale scalar. Caller guarantees
    D % tile_d == 0 (kernels/ops.py pads).
    """
    A, D = x_new.shape
    assert D % tile_d == 0, (D, tile_d)
    scal = jnp.stack([jnp.asarray(scale, jnp.float32), jnp.zeros((), jnp.float32)])
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    return pl.pallas_call(
        _batch_agg_kernel,
        grid=(D // tile_d,),
        in_specs=[
            full((2,)), full((A,)), full((A,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((A, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(scal, w, mask, x_c, x_new)

"""Batched cohort-aggregation kernel (Pallas TPU).

The fedavg/fedprox/fednova server step is a masked weighted reduction over
the cohort axis:

  out[d] = x_c[d] + scale · Σ_a w_a·mask_a·(x_new[a, d] − x_c[d])

The jnp baseline materializes the (A, D) broadcast difference before
reducing; this kernel fuses broadcast, weighting, and the Σ_a reduction in
one read of each (A, TILE_D) tile and one write of the (TILE_D,) output —
the aggregation is purely memory-bound, so the fusion is the whole win.
``scale`` carries FedNova's effective step τ_eff (1.0 for FedAvg); the
caller folds p̂ normalization and any 1/τ_a factors into ``w``.

Blocking mirrors kernels/consensus.py: grid over D tiles, the whole cohort
axis resident per tile. Validated on CPU in interpret mode against
kernels/ref.py::batch_agg_ref (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 1024


def _batch_agg_kernel(scal_ref, w_ref, mask_ref, xc_ref, xnew_ref, out_ref):
    scale = scal_ref[0]
    w = (w_ref[:] * mask_ref[:])[:, None]
    xc = xc_ref[:]
    delta = jnp.sum(w * (xnew_ref[:, :] - xc[None]), axis=0)
    out_ref[:] = xc + scale * delta


def batch_agg_call(
    x_c, x_new, w, mask, scale, *, interpret: bool = True, tile_d: int = TILE_D
):
    """out (D,) = x_c + scale·Σ_a w_a·mask_a·(x_new[a] − x_c).

    x_c (D,); x_new (A, D); w, mask (A,); scale scalar. Caller guarantees
    D % tile_d == 0 (kernels/ops.py pads).
    """
    A, D = x_new.shape
    assert D % tile_d == 0, (D, tile_d)
    scal = jnp.stack([jnp.asarray(scale, jnp.float32), jnp.zeros((), jnp.float32)])
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    return pl.pallas_call(
        _batch_agg_kernel,
        grid=(D // tile_d,),
        in_specs=[
            full((2,)), full((A,)), full((A,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((A, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(scal, w, mask, x_c, x_new)

"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these).

Shapes (flattened parameter dimension D, cohort A):
  x_c, S_frozen:     (D,)
  I, J, x_new:       (A, D)
  T, g_inv, mask:    (A,)   mask: 1.0 = real client row, 0.0 = padding
  dt, tau:           scalars;  L: python float
"""
from __future__ import annotations

import jax.numpy as jnp


def gamma_ref(x_c, x_new, T, tau, mask):
    """Γ with round-start state = broadcast central state: (A, D)."""
    frac = (tau / jnp.maximum(T, 1e-12))[:, None]
    return (x_c[None] + (x_new - x_c[None]) * frac) * mask[:, None]


def consensus_ref(x_c, S_frozen, I, J, x_prev, x_new, T, g_inv, mask, dt, tau, L):
    """Fused Γ + BE arrowhead Schur solve + LTE terms.

    ``x_prev`` (A, D) is each client's explicit Γ anchor (the broadcast
    central state in the synchronous round; a re-based anchor for stale
    event flights). Returns (x_c_new (D,), I_new (A, D), eps_c scalar,
    eps_l scalar) where eps are the *unscaled-by-(dt/2)* raw max-abs terms
    scaled inside, i.e. already multiplied by dt/2 (paper eqs. 29-30).
    """
    r = dt / L
    m = mask[:, None]
    frac_new = ((tau + dt) / jnp.maximum(T, 1e-12))[:, None]
    frac_old = (tau / jnp.maximum(T, 1e-12))[:, None]
    gamma_new = x_prev + (x_new - x_prev) * frac_new
    gamma_old = x_prev + (x_new - x_prev) * frac_old

    gi = g_inv[:, None]
    d = 1.0 + r * gi
    u = (I + r * (gamma_new + J * gi)) / d * m
    w = (r / d) * m
    den = 1.0 + dt * jnp.sum(w)
    num = x_c + dt * (jnp.sum(u, axis=0) + S_frozen)
    x_c_new = num / den
    I_new = (u - w * x_c_new[None]) * m

    rhs_old = (gamma_old - (I - J) * gi - x_c[None]) / L * m
    rhs_new = (gamma_new - (I_new - J) * gi - x_c_new[None]) / L * m
    eps_l = (dt / 2.0) * jnp.max(jnp.abs(rhs_new - rhs_old))
    eps_c = (dt / 2.0) * jnp.max(jnp.abs(jnp.sum((I_new - I) * m, axis=0)))
    return x_c_new, I_new, eps_c, eps_l


def anchor_rebase_ref(x_prev, x_new, frac, mask):
    """Masked Γ anchor rebase: rows with mask=1 move to the point a
    fraction ``frac_a`` along the (x_prev, x_new) line (exact by Theorem-1
    linearity); mask=0 rows pass through bitwise untouched. Shapes:
    x_prev/x_new (A, D); frac/mask (A,)."""
    reb = x_prev + (x_new - x_prev) * frac[:, None]
    return jnp.where(mask[:, None] > 0, reb, x_prev)


def batch_agg_ref(x_c, x_new, w, mask, scale):
    """Masked weighted cohort aggregation: (D,) = x_c + scale·Σ_a w̃_a·Δ_a."""
    wm = (w * mask)[:, None]
    return x_c + scale * jnp.sum(wm * (x_new - x_c[None]), axis=0)


def batch_agg_partial_ref(x_c, x_new, w, mask):
    """Device-local partial of the sharded cohort reduction (no x_c/scale
    application — the caller psums partials first)."""
    wm = (w * mask)[:, None]
    return jnp.sum(wm * (x_new - x_c[None]), axis=0)


def hutchinson_ref(v, hv, acc):
    """Fused probe accumulate: acc += v*hv; partial trace = sum(v*hv)."""
    prod = v * hv
    return acc + prod, jnp.sum(prod)


def ssm_scan_ref(dt, B_t, C_t, u, a_log, d_skip, h0):
    """Selective-scan oracle (lax.scan). Shapes as kernels/ssm_scan.py."""
    import jax

    A = -jnp.exp(a_log)                                    # (inner, N)

    def step(h, xs):
        dt_t, b_t, c_t, u_t = xs                           # (B,inner),(B,N)...
        dA = jnp.exp(dt_t[..., None] * A)
        h = h * dA + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, c_t) + d_skip * u_t
        return h, y

    xs = (
        dt.transpose(1, 0, 2), B_t.transpose(1, 0, 2),
        C_t.transpose(1, 0, 2), u.transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h

"""Pallas TPU kernels for the paper's perf-critical hot spots.

consensus.py  — fused Γ + BE Schur solve + LTE (the FedECADO server step)
gamma.py      — standalone Γ interpolation/extrapolation
batch_agg.py  — masked weighted cohort aggregation (fedavg/fednova step)
hutchinson.py — fused sensitivity probe accumulate (v ⊙ Hv + trace)
ssm_scan.py   — VMEM-resident selective scan (Mamba/jamba hot loop)
ops.py        — jit'd pytree wrappers (kernel ↔ ref dispatch)
ref.py        — pure-jnp oracles (tests assert allclose in interpret mode)
"""
from repro.kernels.ops import (
    batch_agg_psum,
    batched_aggregate,
    fused_consensus_step,
    gamma_op,
    hutchinson_op,
    ravel_stacked,
    ravel_tree,
    unravel_stacked,
    unravel_tree,
)

__all__ = [
    "batch_agg_psum", "batched_aggregate", "fused_consensus_step", "gamma_op",
    "hutchinson_op",
    "ravel_tree", "unravel_tree", "ravel_stacked", "unravel_stacked",
]

"""Pallas TPU kernels for the paper's perf-critical hot spots.

consensus.py  — fused Γ + BE Schur solve + LTE (the FedECADO server step,
                anchored-masked: explicit per-client Γ anchors + row mask)
gamma.py      — Γ interpolation/extrapolation + the event scheduler's
                masked anchor-rebase lerp (core/multirate.py staleness)
batch_agg.py  — masked weighted cohort aggregation (fedavg/fednova step)
hutchinson.py — fused sensitivity probe accumulate (v ⊙ Hv + trace)
ssm_scan.py   — VMEM-resident selective scan (Mamba/jamba hot loop)
ops.py        — jit'd pytree wrappers (kernel ↔ ref dispatch)
ref.py        — pure-jnp oracles (tests assert allclose in interpret mode)
"""
from repro.kernels.ops import (
    anchor_rebase_op,
    batch_agg_psum,
    batched_aggregate,
    fused_consensus_step,
    gamma_op,
    hutchinson_op,
    ravel_stacked,
    ravel_tree,
    unravel_stacked,
    unravel_tree,
)

__all__ = [
    "anchor_rebase_op", "batch_agg_psum", "batched_aggregate",
    "fused_consensus_step", "gamma_op", "hutchinson_op",
    "ravel_tree", "unravel_tree", "ravel_stacked", "unravel_stacked",
]

"""Pallas selective-scan (Mamba SSM) kernel — TPU target.

The XLA lowering of the Mamba recurrence streams the (B, inner, N) state and
per-step dA/dBu intermediates through HBM every token (jamba train_4k memory
term: 225 s, §Roofline). This kernel is the TPU analogue of Mamba's fused
CUDA kernel insight: hold the state block in VMEM for the whole sequence and
write only y back.

Blocking: grid over (batch, inner-tiles). Each instance scans the full
sequence with ``fori_loop``, carrying h (TILE_I, N) in VMEM scratch.
VMEM per instance: dt/u/y (S, TILE_I) + B/C (S, N) fp32 ≈ 3·S·TILE_I·4
(S=4096, TILE_I=128 -> ~6.3 MiB) — fits the ~16 MiB budget.
HBM traffic/layer: read dt,B,C,u + write y ≈ 5·S·inner·4 bytes vs
~2·S·inner·N·4 for the streamed scan: a ~N/2.5 ≈ 6.4x cut for N=16, and the
per-step dA/dBu materializations disappear entirely.

Validated in interpret mode against ref.ssm_scan_ref (the lax.scan oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_I = 128


def _ssm_scan_kernel(dt_ref, b_ref, c_ref, u_ref, a_ref, d_ref, h0_ref,
                     y_ref, hout_ref):
    """One (batch, inner-tile) instance.

    dt/u/y: (S, TILE_I); b/c: (S, N); a: (TILE_I, N); d: (TILE_I,);
    h0/hout: (TILE_I, N).
    """
    S = dt_ref.shape[1]
    A = -jnp.exp(a_ref[:, :])                     # (ti, N)
    d_skip = d_ref[:]

    def step(t, h):
        dt_t = dt_ref[0, t, :]                    # (ti,)
        u_t = u_ref[0, t, :]
        b_t = b_ref[0, t, :]                      # (N,)
        c_t = c_ref[0, t, :]
        dA = jnp.exp(dt_t[:, None] * A)           # (ti, N)
        h = h * dA + (dt_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1) + d_skip * u_t
        return h

    h = jax.lax.fori_loop(0, S, step, h0_ref[0, :, :])
    hout_ref[0, :, :] = h


def ssm_scan_call(dt, B_t, C_t, u, a_log, d_skip, h0, *, interpret: bool = True,
                  tile_i: int = TILE_I):
    """dt, u: (B, S, inner); B_t, C_t: (B, S, N); a_log: (inner, N);
    d_skip: (inner,); h0: (B, inner, N).
    Returns (y (B, S, inner), h_final (B, inner, N)). fp32 throughout.
    """
    Bsz, S, inner = dt.shape
    N = B_t.shape[-1]
    assert inner % tile_i == 0, (inner, tile_i)
    n_tiles = inner // tile_i

    grid = (Bsz, n_tiles)
    y, h_final = pl.pallas_call(
        _ssm_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, tile_i), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, tile_i), lambda b, i: (b, 0, i)),
            pl.BlockSpec((tile_i, N), lambda b, i: (i, 0)),
            pl.BlockSpec((tile_i,), lambda b, i: (i,)),
            pl.BlockSpec((1, tile_i, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, tile_i), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, tile_i, N), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, inner), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, inner, N), jnp.float32),
        ],
        interpret=interpret,
    )(dt, B_t, C_t, u, a_log, d_skip, h0)
    return y, h_final

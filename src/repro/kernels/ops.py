"""jit'd dispatch wrappers: pytree ↔ flat (A, D) raveling, padding to kernel
tiles, and kernel-vs-reference selection.

``interpret`` is chosen from the backend: on CPU the Pallas kernels execute
in interpret mode (Python evaluation of the kernel body — the correctness
target for this container); on TPU they compile for real.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.batch_agg import batch_agg_call, batch_agg_partial_call
from repro.kernels.consensus import TILE_D, consensus_call
from repro.kernels.gamma import anchor_rebase_call, gamma_call
from repro.kernels.hutchinson import hutchinson_call

Pytree = Any


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# pytree raveling
# ---------------------------------------------------------------------------


def ravel_tree(tree: Pytree, tile: int = TILE_D) -> Tuple[jax.Array, Any]:
    """Flatten + concat leaves (fp32) and zero-pad D to a tile multiple.

    Returns (flat (D,), meta) where meta unravels back.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    D = flat.shape[0]
    pad = (-D) % tile
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, (treedef, shapes, sizes, D)


def unravel_tree(flat: jax.Array, meta) -> Pytree:
    treedef, shapes, sizes, D = meta
    flat = flat[:D]
    out, off = [], 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[off : off + size].reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, out)


def ravel_stacked(tree: Pytree, tile: int = TILE_D) -> Tuple[jax.Array, Any]:
    """Leaves (A, ...) -> (A, D) with the same layout as ravel_tree."""
    leaves, treedef = jax.tree.flatten(tree)
    A = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    sizes = [l[0].size for l in leaves]
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(A, -1) for l in leaves], axis=1
    )
    D = flat.shape[1]
    pad = (-D) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, (treedef, shapes, sizes, D)


def unravel_stacked(flat: jax.Array, meta) -> Pytree:
    treedef, shapes, sizes, D = meta
    A = flat.shape[0]
    flat = flat[:, :D]
    out, off = [], 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[:, off : off + size].reshape((A,) + shape))
        off += size
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# fused consensus step over pytrees
# ---------------------------------------------------------------------------


def fused_consensus_step(
    x_c: Pytree,
    S_frozen: Pytree,
    I_a: Pytree,
    J_a: Pytree,
    x_prev_a: Pytree,
    x_new_a: Pytree,
    T: jax.Array,
    g_inv: jax.Array,
    dt: jax.Array,
    tau: jax.Array,
    L: float,
    mask: Optional[jax.Array] = None,
    use_kernel: bool = True,
):
    """Γ + BE Schur + LTE in one fused pass. Scalar gains only (g_inv (A,)).

    ``x_prev_a`` carries each client's explicit Γ anchor (stacked, (A, ...)
    leaves) — the broadcast central state in the synchronous round, re-based
    anchors for the event scheduler's stale flights — and ``mask`` (A,)
    zeroes inactive rows out of the Schur sums and both LTE terms (the
    anchored-masked path that lets the event backend keep
    ``ConsensusConfig.use_kernels`` on; None = all rows active).

    Returns (x_c_new tree, I_new tree, eps scalar = max(eps_c, eps_l)).
    """
    xc_flat, meta = ravel_tree(x_c)
    sf_flat, _ = ravel_tree(S_frozen)
    I_flat, smeta = ravel_stacked(I_a)
    J_flat, _ = ravel_stacked(J_a)
    xp_flat, _ = ravel_stacked(x_prev_a)
    xn_flat, _ = ravel_stacked(x_new_a)
    A = I_flat.shape[0]
    if mask is None:
        mask = jnp.ones((A,), jnp.float32)

    call = consensus_call if use_kernel else _consensus_ref_call
    xc_new, I_new, eps_c, eps_l = call(
        xc_flat, sf_flat, I_flat, J_flat, xp_flat, xn_flat,
        T.astype(jnp.float32), g_inv.astype(jnp.float32),
        mask.astype(jnp.float32),
        jnp.asarray(dt, jnp.float32), jnp.asarray(tau, jnp.float32), float(L),
        interpret=_interpret(),
    )
    return (
        unravel_tree(xc_new, meta),
        unravel_stacked(I_new, smeta),
        jnp.maximum(eps_c, eps_l),
    )


def _consensus_ref_call(xc, sf, I, J, xp, xn, T, g_inv, mask, dt, tau, L, **kw):
    return ref.consensus_ref(xc, sf, I, J, xp, xn, T, g_inv, mask, dt, tau, L)


def anchor_rebase_op(
    x_prev: Pytree,
    x_new: Pytree,
    frac: jax.Array,
    mask: jax.Array,
    use_kernel: bool = True,
) -> Pytree:
    """Masked Γ anchor rebase over stacked pytrees (the event scheduler's
    staleness hot loop, core/multirate.py): rows with ``mask=1`` move to the
    fraction ``frac_a`` point of their (x_prev, x_new) line; other rows pass
    through bitwise untouched. Kernel path fuses the lerp + select into one
    pass over the raveled (A, D) anchors; the jnp path maps the same
    arithmetic per leaf."""
    if use_kernel:
        xp_flat, smeta = ravel_stacked(x_prev)
        xn_flat, _ = ravel_stacked(x_new)
        out = anchor_rebase_call(
            xp_flat, xn_flat, frac.astype(jnp.float32),
            mask.astype(jnp.float32), interpret=_interpret(),
        )
        return unravel_stacked(out, smeta)

    def leaf(a, b):
        fr = frac.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        keep = mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0
        return jnp.where(keep, a + (b - a) * fr, a)

    return jax.tree.map(leaf, x_prev, x_new)


def gamma_op(x_c: Pytree, x_new_a: Pytree, T: jax.Array, tau, use_kernel: bool = True):
    """Γ over pytrees via the kernel: returns stacked tree (A, ...)."""
    xc_flat, _ = ravel_tree(x_c)
    xn_flat, smeta = ravel_stacked(x_new_a)
    A = xn_flat.shape[0]
    mask = jnp.ones((A,), jnp.float32)
    if use_kernel:
        out = gamma_call(
            xc_flat, xn_flat, T.astype(jnp.float32), jnp.asarray(tau, jnp.float32),
            mask, interpret=_interpret(),
        )
    else:
        out = ref.gamma_ref(xc_flat, xn_flat, T, jnp.asarray(tau, jnp.float32), mask)
    return unravel_stacked(out, smeta)


@partial(jax.jit, static_argnames=("use_kernel",))
def _batch_agg_flat(xc_flat, xn_flat, w, mask, scale, use_kernel: bool):
    if use_kernel:
        return batch_agg_call(
            xc_flat, xn_flat, w, mask, scale, interpret=_interpret()
        )
    return ref.batch_agg_ref(xc_flat, xn_flat, w, mask, scale)


def batched_aggregate(
    x_c: Pytree,
    x_new_a: Pytree,
    w: jax.Array,
    scale=1.0,
    use_kernel: bool = True,
) -> Pytree:
    """Cohort aggregation x_c + scale·Σ_a w_a·(x_a − x_c) over pytrees via
    the fused Pallas kernel (fedavg: w = p̂/Σp̂, scale 1; fednova: w = p̃/τ_a,
    scale τ_eff). The zero-padded tail of the raveled parameter vector is
    harmless here (0 + scale·Σ w·0 = 0), so no mask beyond cohort padding is
    needed."""
    xc_flat, meta = ravel_tree(x_c)
    xn_flat, _ = ravel_stacked(x_new_a)
    A = xn_flat.shape[0]
    out = _batch_agg_flat(
        xc_flat,
        xn_flat,
        w.astype(jnp.float32),
        jnp.ones((A,), jnp.float32),
        jnp.asarray(scale, jnp.float32),
        use_kernel,
    )
    return unravel_tree(out, meta)


def batch_agg_psum(
    x_c: Pytree,
    x_new_a: Pytree,
    w: jax.Array,
    axis_name: str,
    use_kernel: bool = False,
) -> Pytree:
    """Sharded cohort weighted-delta reduction: Σ_a w_a·(x_a − x_c) with the
    cohort axis sharded over mesh axis ``axis_name`` (called inside the
    sharded backend's ``shard_map`` program, sim/sharded.py). Each device
    computes its shard's partial — through the Pallas partial kernel when
    ``use_kernel`` (FedSimConfig.agg_kernels), else plain jnp — and the
    partials psum across the mesh. Cohort-padding masks are pre-folded into
    ``w`` by the caller. Returns the delta pytree (caller applies
    ``x_c + scale·delta``)."""
    if use_kernel:
        xc_flat, meta = ravel_tree(x_c)
        xn_flat, _ = ravel_stacked(x_new_a)
        A = xn_flat.shape[0]
        part = batch_agg_partial_call(
            xc_flat, xn_flat, w.astype(jnp.float32),
            jnp.ones((A,), jnp.float32), interpret=_interpret(),
        )
        return unravel_tree(jax.lax.psum(part, axis_name), meta)

    def leaf(xc, xa):
        wb = w.reshape((-1,) + (1,) * (xa.ndim - 1)).astype(jnp.float32)
        part = jnp.sum(
            wb * (xa.astype(jnp.float32) - xc.astype(jnp.float32)[None]), axis=0
        )
        return jax.lax.psum(part, axis_name)

    return jax.tree.map(leaf, x_c, x_new_a)


def hutchinson_op(v: Pytree, hv: Pytree, acc: Pytree, use_kernel: bool = True):
    """Fused diag accumulate + trace. Returns (acc_new tree, trace scalar)."""
    v_flat, meta = ravel_tree(v)
    hv_flat, _ = ravel_tree(hv)
    acc_flat, _ = ravel_tree(acc)
    if use_kernel:
        acc_new, tr = hutchinson_call(v_flat, hv_flat, acc_flat, interpret=_interpret())
        trace = jnp.sum(tr)
    else:
        acc_new, trace = ref.hutchinson_ref(v_flat, hv_flat, acc_flat)
    return unravel_tree(acc_new, meta), trace

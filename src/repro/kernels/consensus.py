"""Fused FedECADO consensus kernel (Pallas TPU).

One pass over the flattened parameter dimension fuses: Γ interpolation at τ
and τ+Δt, the Backward-Euler arrowhead Schur solve, and both LTE terms — the
jnp reference walks the same (A+1)·D state ~6 times; this kernel reads each
input tile once and writes each output tile once (the server step is purely
memory-bound, so traffic ≈ runtime on TPU).

Blocking: grid over D tiles of TILE_D lanes; the whole cohort axis A lives in
VMEM per tile (A ≤ ~64 in practice → (A, TILE_D) fp32 = 64·1024·4 = 256 KiB
per operand, comfortably within the ~16 MiB VMEM budget for the ~6 operands).
The Σ_a reductions happen in-register per tile; eps maxima are written per
tile and reduced by the caller.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 1024


def _consensus_kernel(
    scal_ref,   # (4,)  [dt, tau, L, _pad]
    T_ref,      # (A,)
    ginv_ref,   # (A,)
    mask_ref,   # (A,)
    xc_ref,     # (TILE_D,)
    sf_ref,     # (TILE_D,)
    I_ref,      # (A, TILE_D)
    J_ref,      # (A, TILE_D)
    xprev_ref,  # (A, TILE_D)
    xnew_ref,   # (A, TILE_D)
    xc_out,     # (TILE_D,)
    I_out,      # (A, TILE_D)
    epsc_out,   # (1,)
    epsl_out,   # (1,)
):
    dt = scal_ref[0]
    tau = scal_ref[1]
    L = scal_ref[2]
    r = dt / L

    T = jnp.maximum(T_ref[:], 1e-12)[:, None]
    gi = ginv_ref[:][:, None]
    m = mask_ref[:][:, None]
    xc = xc_ref[:]
    I = I_ref[:, :]
    J = J_ref[:, :]
    xp = xprev_ref[:, :]
    xn = xnew_ref[:, :]

    frac_new = (tau + dt) / T
    frac_old = tau / T
    delta = xn - xp
    g_new = xp + delta * frac_new
    g_old = xp + delta * frac_old

    d = 1.0 + r * gi
    u = (I + r * (g_new + J * gi)) / d * m
    w = (r / d) * m
    den = 1.0 + dt * jnp.sum(w)
    num = xc + dt * (jnp.sum(u, axis=0) + sf_ref[:])
    xc_new = num / den
    I_new = (u - w * xc_new[None]) * m

    xc_out[:] = xc_new
    I_out[:, :] = I_new

    rhs_old = (g_old - (I - J) * gi - xc[None]) / L * m
    rhs_new = (g_new - (I_new - J) * gi - xc_new[None]) / L * m
    epsl_out[0] = (dt / 2.0) * jnp.max(jnp.abs(rhs_new - rhs_old))
    epsc_out[0] = (dt / 2.0) * jnp.max(jnp.abs(jnp.sum((I_new - I) * m, axis=0)))


def consensus_call(
    x_c, S_frozen, I, J, x_prev, x_new, T, g_inv, mask, dt, tau, L: float,
    *, interpret: bool = True, tile_d: int = TILE_D,
):
    """Invoke the fused kernel. Caller guarantees D % tile_d == 0.

    ``x_prev`` (A, D) carries each client's explicit Γ anchor — the
    broadcast central state in the synchronous round, a re-based anchor for
    the event scheduler's stale flights (core/multirate.py).

    Returns (x_c_new (D,), I_new (A, D), eps_c scalar, eps_l scalar).
    """
    A, D = I.shape
    assert D % tile_d == 0, (D, tile_d)
    n_tiles = D // tile_d
    scal = jnp.stack([dt, tau, jnp.asarray(L, jnp.float32), jnp.zeros((), jnp.float32)])

    grid = (n_tiles,)
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    tiled1 = pl.BlockSpec((tile_d,), lambda i: (i,))
    tiled2 = pl.BlockSpec((A, tile_d), lambda i: (0, i))

    out = pl.pallas_call(
        _consensus_kernel,
        grid=grid,
        in_specs=[
            full((4,)), full((A,)), full((A,)), full((A,)),
            tiled1, tiled1, tiled2, tiled2, tiled2, tiled2,
        ],
        out_specs=[
            tiled1, tiled2,
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D,), jnp.float32),
            jax.ShapeDtypeStruct((A, D), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
        ],
        interpret=interpret,
    )(scal, T, g_inv, mask, x_c, S_frozen, I, J, x_prev, x_new)

    x_c_new, I_new, epsc, epsl = out
    return x_c_new, I_new, jnp.max(epsc), jnp.max(epsl)

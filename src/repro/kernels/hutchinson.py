"""Fused Hutchinson probe accumulation kernel (Pallas TPU).

Given a Rademacher probe v and its HVP hv (both flattened), fuses the
diagonal accumulate acc += v⊙hv with the per-tile partial trace Σ v⊙hv in a
single read pass (the jnp version reads v/hv twice: once for the product,
once for the reduction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 1024


def _hutch_kernel(v_ref, hv_ref, acc_ref, acc_out, tr_out):
    prod = v_ref[:] * hv_ref[:]
    acc_out[:] = acc_ref[:] + prod
    tr_out[0] = jnp.sum(prod)


def hutchinson_call(v, hv, acc, *, interpret: bool = True, tile_d: int = TILE_D):
    """Returns (acc + v*hv, trace_partial_sums (n_tiles,))."""
    (D,) = v.shape
    assert D % tile_d == 0, (D, tile_d)
    n_tiles = D // tile_d
    tiled = pl.BlockSpec((tile_d,), lambda i: (i,))
    acc_new, tr = pl.pallas_call(
        _hutch_kernel,
        grid=(n_tiles,),
        in_specs=[tiled, tiled, tiled],
        out_specs=[tiled, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((D,), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
        ],
        interpret=interpret,
    )(v, hv, acc)
    return acc_new, tr

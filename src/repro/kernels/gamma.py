"""Γ interpolation/extrapolation kernels (Pallas TPU).

``gamma_call``: out[a, :] = (x_c + (x_new[a] − x_c)·(τ/T_a)) · mask[a] — one
fused read/write pass per tile (the jnp version materializes the broadcast
difference first). Used when the server evaluates client states at probe
time points outside the BE solve (e.g. diagnostics, Γ-based drift metrics).

``anchor_rebase_call``: the event scheduler's staleness hot loop
(core/multirate.py) — masked Γ anchor rebase along each flight's
(x_prev, x_new) line:

  out[a, :] = mask[a] ? x_prev[a] + (x_new[a] − x_prev[a])·frac[a]
                      : x_prev[a]

One read of each (A, TILE_D) operand tile, one write; mask=0 rows (dead
slots, arrived flights) pass through bitwise untouched so the flight table's
free-slot contents never drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 1024


def _gamma_kernel(scal_ref, T_ref, mask_ref, xc_ref, xnew_ref, out_ref):
    tau = scal_ref[0]
    frac = (tau / jnp.maximum(T_ref[:], 1e-12))[:, None]
    xc = xc_ref[:]
    out_ref[:, :] = (xc[None] + (xnew_ref[:, :] - xc[None]) * frac) * mask_ref[:][:, None]


def gamma_call(x_c, x_new, T, tau, mask, *, interpret: bool = True, tile_d: int = TILE_D):
    A, D = x_new.shape
    assert D % tile_d == 0, (D, tile_d)
    scal = jnp.stack([jnp.asarray(tau, jnp.float32), jnp.zeros((), jnp.float32)])
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    return pl.pallas_call(
        _gamma_kernel,
        grid=(D // tile_d,),
        in_specs=[
            full((2,)), full((A,)), full((A,)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
            pl.BlockSpec((A, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((A, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((A, D), jnp.float32),
        interpret=interpret,
    )(scal, T, mask, x_c, x_new)


def _anchor_rebase_kernel(frac_ref, mask_ref, xprev_ref, xnew_ref, out_ref):
    frac = frac_ref[:][:, None]
    keep = mask_ref[:][:, None] > 0
    xp = xprev_ref[:, :]
    out_ref[:, :] = jnp.where(keep, xp + (xnew_ref[:, :] - xp) * frac, xp)


def anchor_rebase_call(
    x_prev, x_new, frac, mask, *, interpret: bool = True, tile_d: int = TILE_D
):
    """Masked Γ anchor rebase over (A, D) stacked anchors. Caller
    guarantees D % tile_d == 0. Parity oracle: kernels/ref.py::
    anchor_rebase_ref."""
    A, D = x_prev.shape
    assert D % tile_d == 0, (D, tile_d)
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    tiled2 = pl.BlockSpec((A, tile_d), lambda i: (0, i))
    return pl.pallas_call(
        _anchor_rebase_kernel,
        grid=(D // tile_d,),
        in_specs=[full((A,)), full((A,)), tiled2, tiled2],
        out_specs=tiled2,
        out_shape=jax.ShapeDtypeStruct((A, D), jnp.float32),
        interpret=interpret,
    )(frac, mask, x_prev, x_new)

"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family scaled]."""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    d_ff=27392,
    vocab_size=152064,
    attention=AttentionConfig(
        num_heads=40, num_kv_heads=40, head_dim=128, qkv_bias=True
    ),
    source="hf:Qwen/Qwen1.5-0.5B",
    long_context="skip",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

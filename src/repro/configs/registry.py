"""--arch registry: maps arch ids to full configs and smoke configs."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ArchConfig

_MODULES: Dict[str, str] = {
    "chameleon-34b": "repro.configs.chameleon_34b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "xlstm-1.3b": "repro.configs.xlstm_13b",
    "arctic-480b": "repro.configs.arctic_480b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "smollm-360m": "repro.configs.smollm_360m",
    "whisper-base": "repro.configs.whisper_base",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).smoke_config()

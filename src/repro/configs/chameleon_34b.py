"""chameleon-34b [vlm] — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

The vision side is a VQ tokenizer: images become discrete tokens in the SAME
vocabulary as text (early fusion), so the backbone is a standard dense decoder
with a 65536 vocab. The VQ codec is the stubbed modality frontend.
"""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    d_ff=22016,
    vocab_size=65536,
    attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128),
    layer_pattern=("attn",),
    frontend="vq_image",
    source="arXiv:2405.09818",
    long_context="skip",  # pure full attention
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

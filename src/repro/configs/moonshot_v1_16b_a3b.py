"""moonshot-v1-16b-a3b [dense tag, MoE arch] — Moonlight-16B-A3B
[hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style fine-grained MoE: 64 routed experts, top-6, tiny expert
d_ff=1408, MHA with kv=16 (no GQA compression).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, reduced

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="dense",
    num_layers=48,
    d_model=2048,
    d_ff=1408,
    vocab_size=163840,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408),
    moe_pattern="all",
    source="hf:moonshotai/Moonlight-16B-A3B",
    long_context="skip",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

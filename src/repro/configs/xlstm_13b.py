"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

Attention-free recurrent architecture: alternating mLSTM (matrix-memory,
parallelizable linear-attention-like) and sLSTM (scalar-memory, sequential)
blocks. d_ff=0: the xLSTM block carries its own up/down projection.
O(1) decode state -> native long_500k support.
"""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    d_ff=0,
    vocab_size=50304,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=512),
    layer_pattern=(
        "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm",
    ),  # 7:1 mLSTM:sLSTM, period 8 divides 48 layers
    source="arXiv:2405.04517",
    long_context="native",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG, layer_pattern=("mlstm", "slstm"))

"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M family].

15 heads: not divisible by the 16-way model axis -> sharding falls back to
head_dim (64/16=4), exercising the non-divisible-head sharding rule.
"""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49152,
    attention=AttentionConfig(num_heads=15, num_kv_heads=5, head_dim=64),
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
    long_context="skip",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG, attention=AttentionConfig(num_heads=3, num_kv_heads=1, head_dim=64))

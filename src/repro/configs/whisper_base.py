"""whisper-base [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is the stubbed modality frontend:
``input_specs`` provides precomputed frame embeddings (B, T_enc, d_model).
The decoder (self-attn + cross-attn) is implemented in full; decode shapes run
the decoder serve_step with a cached encoder output. Full attention both sides
-> long_500k skipped.
"""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,               # decoder layers
    encoder_layers=6,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=64),
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    frontend="audio_conv",
    source="arXiv:2212.04356",
    long_context="skip",
)

# Whisper encoder operates on 1500 frames (30 s); for the assigned shapes the
# encoder length is capped at this value while the decoder consumes the
# assigned seq_len.
ENCODER_FRAMES = 1500


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

"""The four assigned input shapes.

Decode shapes lower ``serve_step`` (ONE new token against a KV cache of
``seq_len``); train/prefill lower ``train_step``/``prefill_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> InputShape:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in ALL_SHAPES]}")

"""Architecture configuration dataclasses.

Every assigned architecture gets one ``<arch>.py`` module exporting ``CONFIG``
(the exact full-scale spec, citation in ``source``) and ``smoke_config()``
(a reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts)
for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # d_ff of each expert (may differ from the dense d_ff).
    expert_d_ff: int
    # Arctic-style dense residual MLP running in parallel with the experts.
    dense_residual_d_ff: int = 0
    # Router options
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    # Sliding-window size; 0 = full attention.
    sliding_window: int = 0
    # Gemma-2 style: every other layer is local (sliding window) when
    # ``alternate_local_global`` is set; ``sliding_window`` then applies to the
    # local layers only.
    alternate_local_global: bool = False
    logit_softcap: float = 0.0  # 0 = disabled
    rope_theta: float = 10_000.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (for jamba) / xLSTM sizing."""
    state_dim: int = 16       # per-channel SSM state (mamba d_state)
    conv_width: int = 4
    expand: int = 2           # inner dim = expand * d_model
    dt_rank: int = 0          # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int                        # dense FFN width (0 for pure-SSM xLSTM)
    vocab_size: int
    attention: Optional[AttentionConfig]
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Layer pattern, tiled to num_layers. Tokens: "attn" (attn+mlp block),
    # "mamba" (mamba+mlp block), "mlstm", "slstm".
    layer_pattern: Tuple[str, ...] = ("attn",)
    # Which layers get MoE FFN instead of dense: "all", "none", or "every_2"
    moe_pattern: str = "none"
    # Encoder-decoder (whisper): number of encoder layers (decoder = num_layers).
    encoder_layers: int = 0
    # Modality frontend stub: "none" | "vq_image" | "audio_conv"
    frontend: str = "none"
    # Gemma-2 final-logit softcap
    final_logit_softcap: float = 0.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # silu | gelu
    gated_mlp: bool = True           # SwiGLU-style (3 mats) vs classic (2 mats)
    max_seq_len: int = 1 << 20
    source: str = ""                 # citation
    # long_500k support: "native" (ssm / windowed), "windowed" (we cap full
    # attention layers with sliding window for this shape), "skip".
    long_context: str = "skip"

    @property
    def has_moe(self) -> bool:
        return self.moe is not None and self.moe_pattern != "none"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand layer_pattern to exactly num_layers entries."""
        pat = self.layer_pattern
        reps = -(-self.num_layers // len(pat))
        return tuple((pat * reps)[: self.num_layers])

    def moe_layers(self) -> Tuple[bool, ...]:
        """Per-layer flag: does this layer use the MoE FFN?"""
        if self.moe is None or self.moe_pattern == "none":
            return (False,) * self.num_layers
        if self.moe_pattern == "all":
            return (True,) * self.num_layers
        if self.moe_pattern == "every_2":
            return tuple(i % 2 == 1 for i in range(self.num_layers))
        raise ValueError(f"unknown moe_pattern {self.moe_pattern!r}")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for kind, use_moe in zip(self.layer_kinds(), self.moe_layers()):
            n += self._block_params(kind, use_moe)
        if self.encoder_layers:
            # encoder blocks (attn+mlp, never MoE) + decoder cross-attention
            n += self.encoder_layers * self._block_params("attn", False)
            n += self.num_layers * (self._attn_params() + self.d_model)
        return n

    def _attn_params(self) -> int:
        a = self.attention
        d = self.d_model
        qd = a.num_heads * a.head_dim
        kvd = a.num_kv_heads * a.head_dim
        p = d * qd + 2 * d * kvd + qd * d
        if a.qkv_bias:
            p += qd + 2 * kvd
        return p

    def _mlp_params(self, d_ff: int) -> int:
        mats = 3 if self.gated_mlp else 2
        return mats * self.d_model * d_ff

    def _block_params(self, kind: str, use_moe: bool) -> int:
        d = self.d_model
        p = 2 * d  # 2 norms
        if kind == "attn":
            p += self._attn_params()
            p += self._ffn_params(use_moe)
        elif kind == "mamba":
            s = self.ssm or SSMConfig()
            inner = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            p += d * inner * 2            # in_proj (x and z)
            p += inner * s.conv_width     # depthwise conv
            p += inner * (dt_rank + 2 * s.state_dim)  # x -> dt,B,C
            p += dt_rank * inner          # dt proj
            p += inner * s.state_dim      # A
            p += inner                    # D
            p += inner * d                # out proj
            p += self._ffn_params(use_moe)
        elif kind in ("mlstm", "slstm"):
            a = self.attention
            qd = a.num_heads * a.head_dim
            # qkv + i/f/o gates + out proj (xLSTM-style block, simplified)
            p += 3 * d * qd + 3 * d * a.num_heads + qd * d
            # xLSTM uses projected up/down FFN inside block
            p += 2 * d * (2 * d)
        return p

    def _ffn_params(self, use_moe: bool) -> int:
        if use_moe and self.moe is not None:
            m = self.moe
            p = self.d_model * m.num_experts                 # router
            p += m.num_experts * 3 * self.d_model * m.expert_d_ff
            if m.dense_residual_d_ff:
                p += 3 * self.d_model * m.dense_residual_d_ff
            return p
        return self._mlp_params(self.d_ff)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if not self.has_moe:
            return self.param_count()
        m = self.moe
        full_ffn = m.num_experts * 3 * self.d_model * m.expert_d_ff
        act_ffn = m.top_k * 3 * self.d_model * m.expert_d_ff
        n_moe = sum(self.moe_layers())
        return self.param_count() - n_moe * (full_ffn - act_ffn)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Produce a smoke-test-sized variant of the same family."""
    d_model = min(cfg.d_model, 256)
    a = cfg.attention
    attn = None
    if a is not None:
        heads = min(a.num_heads, 4)
        kv = max(1, min(a.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        attn = dataclasses.replace(
            a, num_heads=heads, num_kv_heads=kv, head_dim=max(8, d_model // heads),
            sliding_window=min(a.sliding_window, 64) if a.sliding_window else 0,
        )
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, num_experts=min(moe.num_experts, 4), top_k=min(moe.top_k, 2),
            expert_d_ff=min(moe.expert_d_ff, 128),
            dense_residual_d_ff=min(moe.dense_residual_d_ff, 128),
            # dropless at smoke shapes (C >= worst-case per-expert load):
            # capacity drops depend on batch composition, so prefill-vs-decode
            # logit consistency only holds without them — and the real Mixtral
            # router is dropless anyway. Production capacity_factor is kept in
            # the full config; the drop path has its own test with a tiny cf.
            capacity_factor=max(moe.capacity_factor, float(moe.num_experts)),
        )
    kw = dict(
        num_layers=2,
        d_model=d_model,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        attention=attn,
        moe=moe,
        encoder_layers=2 if cfg.encoder_layers else 0,
        max_seq_len=2048,
    )
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)

"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Jamba block structure: every 8 layers contain 1 attention layer and 7 Mamba
layers; MoE replaces the MLP on every other layer (e=2 in the paper).
For ``long_500k`` decode, the attention layers run with a 4096 sliding window
(deployment configuration — the Mamba state is O(1), attention cache is capped;
recorded in DESIGN.md).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, SSMConfig, reduced

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=14336),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    layer_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe_pattern="every_2",
    source="arXiv:2403.19887",
    long_context="windowed",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG, num_layers=2, layer_pattern=("mamba", "attn"))

"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer has a dense residual MLP running in parallel
with a 128-expert top-2 MoE FFN.
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, reduced

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(
        num_experts=128, top_k=2, expert_d_ff=4864, dense_residual_d_ff=4864
    ),
    moe_pattern="all",
    source="hf:Snowflake/snowflake-arctic-base",
    long_context="skip",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

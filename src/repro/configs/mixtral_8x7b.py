"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]. SWA(4096) -> long_500k decode runs natively with a
windowed KV cache.
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, reduced

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=8, head_dim=128, sliding_window=4096
    ),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336),
    moe_pattern="all",
    source="arXiv:2401.04088",
    long_context="native",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

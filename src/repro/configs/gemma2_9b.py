"""gemma2-9b [dense] — local/global alternating attention, logit softcaps
[arXiv:2408.00118]. Local layers use a 4096 sliding window; global layers are
full attention. long_500k decode runs: local layers carry a windowed cache,
global layers attend the full 512k cache (linear per decoded token).
"""
from repro.configs.base import ArchConfig, AttentionConfig, reduced

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attention=AttentionConfig(
        num_heads=16, num_kv_heads=8, head_dim=256,
        sliding_window=4096, alternate_local_global=True,
        logit_softcap=50.0,
    ),
    # pattern length 2: position 0 = local (sliding window), 1 = global
    layer_pattern=("attn", "attn"),
    final_logit_softcap=30.0,
    activation="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
    long_context="windowed",
)


def smoke_config() -> ArchConfig:
    return reduced(CONFIG)

from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig, SSMConfig, reduced
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import (
    ALL_SHAPES,
    DECODE_32K,
    InputShape,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    get_shape,
)

__all__ = [
    "ArchConfig", "AttentionConfig", "MoEConfig", "SSMConfig", "reduced",
    "ARCH_IDS", "get_config", "get_smoke_config",
    "ALL_SHAPES", "InputShape", "get_shape",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]

"""Thin shim: the roofline model moved to ``repro.tune.roofline``
(the cost-model subsystem, DESIGN.md §12). Old call sites keep working."""
from repro.tune.roofline import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    _COLLECTIVES,
    _DTYPE_BYTES,
    _SHAPE_RE,
    _shape_bytes,
    model_flops,
    parse_collective_bytes,
    roofline_terms,
)

"""Experiment-matrix sweep runner: algorithms × scenarios × seeds.

Crosses the fed/algorithms plugin registry with the repro/scenarios
heterogeneity registry into the paper-style evaluation matrix (§5: FedECADO
vs FedProx/FedNova *across heterogeneous regimes*), prints Table-1-style
comparison tables, and persists a machine-readable ``BENCH_scenarios.json``
(schema pinned by tests/test_bench_scenarios.py, like BENCH_engine.json).

Two grids per run:

* **accuracy matrix** — every (algorithm × scenario × seed) cell on the
  primary ``--backend``: final eval accuracy + last finite loss + wall time
  (``--backend event`` restricts to flow-dynamics algorithms, and each
  cell's round log surfaces the async counters — dropped busy re-draws,
  stale stragglers, absorbed arrivals);
* **equivalence grid** — every algorithm × ``--equiv-scenarios`` ×
  {sequential, vectorized, sharded}: loss histories of the non-sequential
  backends must match the sequential oracle at ``--equiv-rtol`` (1e-6 — the
  engine-wide equivalence bar), extending the backend-equivalence guarantee
  to availability-trace / feature-shift / dropout scenarios. Any violation
  exits non-zero unless ``--allow-equiv-fail``.

The model/problem is the shared synthetic-teacher MLP of benchmarks/run.py
(table-1 hyperparameters, L=0.01); ``loss_fn`` is module-level so the
per-(kind, mu) jit caches of the shared backend instances hit across cells.

  PYTHONPATH=src python -m repro.launch.sweep --rounds 40 --seeds 2
  PYTHONPATH=src python -m repro.launch.sweep \
      --algorithms fedecado,fednova --scenarios dirichlet01,diurnal \
      --rounds 2 --clients 8 --seeds 1        # CI smoke grid
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# v2: rows gain a "telemetry" summary (shared obs schema: substeps/waves/
# staleness/dropped counters aggregated over the cell's rounds)
SCENARIO_BENCH_SCHEMA_VERSION = 2

EQUIV_BACKENDS = ("sequential", "vectorized", "sharded")

# default equivalence scenarios: >= 6 registered regimes spanning every
# axis the acceptance bar names — one availability trace (diurnal), one
# feature shift, plus label/quantity skew and mid-round dropout
DEFAULT_EQUIV_SCENARIOS = (
    "dirichlet01", "label-shard2", "quantity-zipf",
    "feature-shift", "diurnal", "flaky-dropout",
)


def _fwd(p, x):
    return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]


def loss_fn(p, batch):
    """Module-level (closure-free) loss: ONE function object across every
    sweep cell, so backend jit caches keyed on it are shared."""
    lp = jax.nn.log_softmax(_fwd(p, batch["x"]))
    return -jnp.mean(
        jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
    )


def build_problem(seed: int, n_samples: int = 2048, dim: int = 32,
                  classes: int = 10, hidden: int = 48):
    """Per-seed synthetic-teacher problem; params0 is seed-independent so
    every cell starts from the same initialization."""
    from repro.data import make_classification

    data = make_classification(n_samples, dim=dim, n_classes=classes, seed=seed)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    params0 = {
        "w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
        "b0": jnp.zeros((hidden,)),
        "w1": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
        "b1": jnp.zeros((classes,)),
    }

    def eval_fn(p):
        pred = jnp.argmax(_fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    return data, params0, eval_fn


def _make_cfg(algorithm, scenario, seed, backend, *, rounds, clients,
              participation, batch_size, steps_per_epoch, event_horizon=1.0,
              buffer_size=0, stale_gamma=0.25, compress=None,
              compress_level=None):
    from repro.core import ConsensusConfig
    from repro.fed import FedSimConfig

    return FedSimConfig(
        algorithm=algorithm, n_clients=clients, participation=participation,
        rounds=rounds, batch_size=batch_size, steps_per_epoch=steps_per_epoch,
        lr_fixed=1e-2, epochs_fixed=2, hetero=None, seed=1000 + seed,
        eval_every=rounds, backend=backend, scenario=scenario,
        event_horizon=event_horizon,
        event_buffered=buffer_size > 0, event_buffer_size=buffer_size,
        event_stale_gamma=stale_gamma,
        compress=compress, compress_level=compress_level,
        # L tuned on the table-1 config (benchmarks/run.py)
        consensus=ConsensusConfig(L=0.01),
    )


def _shared_backend(cache: Dict[object, object], name: str,
                    event_horizon: float = 1.0, buffer_size: int = 0,
                    stale_gamma: float = 0.25):
    """One backend instance per cache key for the whole sweep — their
    per-(kind, mu) jit caches then amortize compilation across the matrix
    (the engine-bench warm-up pattern). The event backend's flight table is
    per-sim state and resets itself when its owner changes; its key
    includes the horizon/buffer knobs so cells at different settings can
    never silently share one instance."""
    key = (
        (name, float(event_horizon), int(buffer_size), float(stale_gamma))
        if name == "event" else name
    )
    if key not in cache:
        from repro.sim.engine import SequentialBackend
        from repro.sim.events import EventBackend
        from repro.sim.sharded import ShardedBackend
        from repro.sim.vectorized import VectorizedBackend

        cache[key] = {
            "sequential": SequentialBackend,
            "vectorized": VectorizedBackend,
            "sharded": ShardedBackend,
            "event": lambda: EventBackend(
                horizon_quantile=event_horizon,
                buffered=buffer_size > 0, buffer_size=buffer_size,
                stale_gamma=stale_gamma if buffer_size > 0 else 0.0,
            ),
        }[name]()
    return cache[key]


def run_cell(algorithm: str, scenario: str, seed: int, backend: str,
             problem, backends_cache, *, event_horizon: float = 1.0,
             buffer_size: int = 0, stale_gamma: float = 0.25,
             log_dir: Optional[str] = None, **grid) -> Dict[str, object]:
    """One matrix cell: train, eval once at the end, return the row with
    its aggregated telemetry summary (shared obs schema)."""
    from repro.fed import FedSim, last_finite_loss
    from repro.obs import jsonable

    data, params0, eval_fn = problem
    cfg = _make_cfg(algorithm, scenario, seed, backend,
                    event_horizon=event_horizon, buffer_size=buffer_size,
                    stale_gamma=stale_gamma, **grid)
    if log_dir:
        # one structured run log per cell, named after its coordinates —
        # CI uploads the directory as a workflow artifact
        cfg.log_jsonl = os.path.join(
            log_dir, f"{algorithm}-{scenario}-s{seed}-{backend}.jsonl"
        )
    t0 = time.time()
    sim = FedSim(loss_fn, params0, data, None, cfg, eval_fn)
    sim.backend = _shared_backend(backends_cache, backend, event_horizon,
                                  buffer_size, stale_gamma)
    hist = sim.run()
    return {
        "algorithm": algorithm,
        "scenario": scenario,
        "seed": int(seed),
        "backend": backend,
        "acc": float(hist.metrics[-1]["acc"]),
        # nan-aware: event rounds with an all-busy cohort mark the loss
        # gap with nan; the endpoint must skip it, not propagate it
        "final_loss": last_finite_loss(hist.loss),
        "wall_s": float(time.time() - t0),
        "telemetry": jsonable(hist.summary()),
        "_history": [float(l) for l in hist.loss],
    }


def _table(report) -> str:
    """Table-1-style mean±std accuracy matrix (rows scenarios, columns
    algorithms, primary backend only)."""
    algs, scns = report["algorithms"], report["scenarios"]
    cells = {}
    for r in report["results"]:
        cells.setdefault((r["scenario"], r["algorithm"]), []).append(r["acc"])
    w = max(12, max(len(a) for a in algs) + 1)
    lines = [
        "== accuracy (mean±std over seeds, backend="
        f"{report['backend']}, rounds={report['rounds']}) ==",
        f"{'scenario':18s}" + "".join(f"{a:>{w}s}" for a in algs),
    ]
    for s in scns:
        row = f"{s:18s}"
        for a in algs:
            accs = cells.get((s, a), [])
            row += (
                f"{100 * np.mean(accs):7.1f}±{100 * np.std(accs):4.1f}".rjust(w)
                if accs else "n/a".rjust(w)
            )
        lines.append(row)
    return "\n".join(lines)


def run_sweep(
    algorithms: Optional[Sequence[str]] = None,
    scenarios: Optional[Sequence[str]] = None,
    *,
    seeds: int = 2,
    rounds: int = 40,
    clients: int = 25,
    participation: float = 0.2,
    batch_size: int = 32,
    steps_per_epoch: int = 5,
    backend: str = "vectorized",
    event_horizon: float = 1.0,
    buffer_size: int = 0,
    stale_gamma: float = 0.25,
    compress: Optional[str] = None,
    compress_level: Optional[int] = None,
    equiv_scenarios: Sequence[str] = DEFAULT_EQUIV_SCENARIOS,
    equiv_rounds: int = 2,
    equiv_rtol: float = 1e-6,
    json_path: Optional[str] = "BENCH_scenarios.json",
    log_dir: Optional[str] = None,
    table: bool = True,
) -> Dict[str, object]:
    """Run the matrix + equivalence grids and return the report dict
    (persisted to ``json_path`` when set). Names are validated against both
    registries BEFORE any cell runs."""
    from repro.fed.algorithms import available_algorithms, get_algorithm
    from repro.obs import format_counters
    from repro.scenarios import available_scenarios, get_scenario

    if log_dir:
        os.makedirs(log_dir, exist_ok=True)

    algorithms = tuple(algorithms or available_algorithms())
    scenarios = tuple(scenarios or available_scenarios())
    equiv_scenarios = tuple(equiv_scenarios)
    for a in algorithms:
        get_algorithm(a)
    for s in (*scenarios, *equiv_scenarios):
        get_scenario(s)
    if buffer_size and backend != "event":
        raise ValueError(
            f"buffer_size={buffer_size} requires backend='event' (the "
            f"buffered server lives on the event backend's flight table); "
            f"got backend='{backend}'"
        )
    if buffer_size < 0 or buffer_size > clients:
        raise ValueError(
            f"buffer_size must be in [0, clients={clients}] (0 disables "
            f"buffered mode); got {buffer_size}"
        )
    if stale_gamma < 0:
        raise ValueError(f"stale_gamma must be >= 0; got {stale_gamma}")
    if compress_level is not None and compress is None:
        raise ValueError(
            f"compress_level={compress_level} requires a compressor name; "
            "pass compress= as well"
        )
    if compress is not None:
        # validate the name, the level AND every compressor × algorithm
        # combo against the comm registry before any cell runs
        from repro.comm import check_algorithm, get_compressor

        get_compressor(compress)(compress_level)
        for a in algorithms:
            check_algorithm(compress, get_algorithm(a))
    if backend == "event":
        # the event scheduler is flow-only; fail before any cell runs
        bad = [a for a in algorithms if not get_algorithm(a).has_flow_dynamics]
        if bad:
            flow = [
                a for a in available_algorithms()
                if get_algorithm(a).has_flow_dynamics
            ]
            raise ValueError(
                f"--backend event only supports flow-dynamics algorithms "
                f"(got {', '.join(bad)}; eligible: {', '.join(flow)})"
            )

    grid = dict(rounds=rounds, clients=clients, participation=participation,
                batch_size=batch_size, steps_per_epoch=steps_per_epoch,
                compress=compress, compress_level=compress_level)
    report: Dict[str, object] = {
        "schema_version": SCENARIO_BENCH_SCHEMA_VERSION,
        "benchmark": "scenarios",
        "rounds": int(rounds),
        "clients": int(clients),
        "participation": float(participation),
        "seeds": list(range(seeds)),
        "algorithms": list(algorithms),
        "scenarios": list(scenarios),
        "backend": backend,
        "config": {
            "batch_size": int(batch_size),
            "steps_per_epoch": int(steps_per_epoch),
            "lr_fixed": 1e-2,
            "epochs_fixed": 2,
            "consensus_L": 0.01,
        },
        "equivalence_config": {
            "backends": list(EQUIV_BACKENDS),
            "scenarios": list(equiv_scenarios),
            "rounds": int(equiv_rounds),
            "rtol": float(equiv_rtol),
        },
        "results": [],
        "equivalence": [],
    }
    if buffer_size:
        report["buffered"] = {
            "buffer_size": int(buffer_size),
            "stale_gamma": float(stale_gamma),
        }
    if compress:
        # record the wire model so compressed matrices are self-describing
        # (telemetry bytes_up/bytes_down columns carry the measured totals)
        report["compression"] = {
            "compress": compress,
            "level": None if compress_level is None else int(compress_level),
        }

    backends_cache: Dict[str, object] = {}

    # ---- accuracy matrix -------------------------------------------------
    for seed in range(seeds):
        problem = build_problem(seed)
        for scenario in scenarios:
            for algorithm in algorithms:
                row = run_cell(algorithm, scenario, seed, backend,
                               problem, backends_cache,
                               event_horizon=event_horizon,
                               buffer_size=buffer_size,
                               stale_gamma=stale_gamma,
                               log_dir=log_dir, **grid)
                row.pop("_history")
                report["results"].append(row)
                # shared-formatter counter suffix: surfaces solver effort
                # and (event backend) async behaviour — dropped busy
                # re-draws would otherwise be silent cohort shrinkage
                print(
                    f"seed {seed} {scenario:16s} {algorithm:10s} "
                    f"acc={row['acc']:.4f} ({row['wall_s']:.1f}s)  "
                    + format_counters(row["telemetry"]),
                    flush=True,
                )

    # ---- buffered-vs-synchronous comparison pin --------------------------
    # when the matrix runs the buffered server, pin a synchronous FedADMM
    # baseline cell (vectorized backend, same problem/grid) per scenario so
    # the report always carries the paper-style async-vs-ADMM comparison
    if buffer_size:
        report["buffered_comparison"] = []
        problem = build_problem(0)
        for scenario in scenarios:
            base = run_cell("fedadmm", scenario, 0, "vectorized",
                            problem, backends_cache, log_dir=log_dir, **grid)
            buffered_accs = {
                r["algorithm"]: r["acc"] for r in report["results"]
                if r["scenario"] == scenario and r["seed"] == 0
            }
            report["buffered_comparison"].append({
                "scenario": scenario,
                "baseline_algorithm": "fedadmm",
                "baseline_backend": "vectorized",
                "baseline_acc": base["acc"],
                "baseline_final_loss": base["final_loss"],
                "buffered_acc": buffered_accs,
            })
            gaps = ", ".join(
                f"{a}={100 * (acc - base['acc']):+.1f}pp"
                for a, acc in sorted(buffered_accs.items())
            )
            print(
                f"buffered-vs-fedadmm {scenario:16s} "
                f"baseline acc={base['acc']:.4f}  {gaps}",
                flush=True,
            )

    # ---- backend-equivalence grid ---------------------------------------
    if equiv_scenarios:
        problem = build_problem(0)
        # the equivalence grid always runs the lossless wire: its contract
        # is backend-vs-oracle bitwise-level agreement, and stochastic
        # quantization draws its noise in backend-specific shapes (the
        # identity==off equivalence is pinned separately in
        # tests/test_backend_equiv.py)
        egrid = dict(grid, rounds=equiv_rounds,
                     compress=None, compress_level=None)
        for scenario in equiv_scenarios:
            for algorithm in algorithms:
                hists = {}
                for b in EQUIV_BACKENDS:
                    hists[b] = run_cell(
                        algorithm, scenario, 0, b, problem, backends_cache,
                        **egrid,
                    )["_history"]
                ref = np.asarray(hists["sequential"], np.float64)
                for b in EQUIV_BACKENDS[1:]:
                    got = np.asarray(hists[b], np.float64)
                    err = float(np.max(np.abs(got - ref)))
                    ok = bool(
                        np.allclose(got, ref, rtol=equiv_rtol, atol=1e-7)
                    )
                    report["equivalence"].append({
                        "algorithm": algorithm,
                        "scenario": scenario,
                        "backend": b,
                        "max_abs_err": err,
                        "ok": ok,
                    })
                    print(
                        f"equiv {scenario:16s} {algorithm:10s} {b:10s} "
                        f"max|Δloss|={err:.2e} {'ok' if ok else 'FAIL'}",
                        flush=True,
                    )

    if table:
        print("\n" + _table(report), flush=True)
    if json_path:
        from repro.tune.bench_io import write_bench_report

        write_bench_report(report, json_path)
        print(f"# wrote {json_path}", flush=True)
    return report


def main() -> None:
    from repro.fed.algorithms import available_algorithms
    from repro.scenarios import available_scenarios

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "--algorithms", default=",".join(available_algorithms()),
        help="comma-separated fed/algorithms registry names "
        f"(registered: {', '.join(available_algorithms())})",
    )
    ap.add_argument(
        "--scenarios", default=",".join(available_scenarios()),
        help="comma-separated scenario registry names "
        f"(registered: {', '.join(available_scenarios())})",
    )
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of repetition seeds (0..N-1)")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=25)
    ap.add_argument("--participation", type=float, default=0.2)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--steps-per-epoch", type=int, default=5)
    ap.add_argument(
        "--backend", default="vectorized",
        choices=("sequential", "vectorized", "event", "sharded"),
        help="primary backend of the accuracy matrix (event: flow-dynamics "
        "algorithms only; round logs gain dropped/stale/arrived counters)",
    )
    ap.add_argument(
        "--event-horizon", type=float, default=1.0,
        help="event backend: quantile of in-flight windows absorbed per "
        "round (< 1.0 exercises staleness/busy-drop in the sweep)",
    )
    ap.add_argument(
        "--buffer-size", type=int, default=0,
        help="event backend: fully-asynchronous buffered server — apply a "
        "staleness-weighted aggregation whenever K endpoints land (no round "
        "barrier); 0 keeps the synchronous cohort semantics",
    )
    ap.add_argument(
        "--stale-gamma", type=float, default=0.25,
        help="buffered mode: staleness damping w = 1/(1 + gamma*rounds) "
        "applied to endpoints that waited in the buffer",
    )
    from repro.comm import available_compressors

    ap.add_argument(
        "--compress", choices=available_compressors(), default=None,
        help="lossy uplink compressor (repro/comm registry) applied to "
        "every accuracy-matrix cell; the equivalence grid always runs "
        "lossless. Compressor × algorithm combos are validated before any "
        "cell runs (e.g. topk is refused for flow-dynamics algorithms)",
    )
    ap.add_argument(
        "--compress-level", type=int, default=None,
        help="compressor-specific level; omit for the compressor's default "
        "— invalid levels are rejected with the valid set listed",
    )
    ap.add_argument(
        "--equiv-scenarios", default=",".join(DEFAULT_EQUIV_SCENARIOS),
        help="scenarios for the sequential/vectorized/sharded equivalence "
        "grid ('' disables it)",
    )
    ap.add_argument("--equiv-rounds", type=int, default=2)
    ap.add_argument("--equiv-rtol", type=float, default=1e-6)
    ap.add_argument("--json", default="BENCH_scenarios.json",
                    help="report path ('' disables persisting)")
    ap.add_argument(
        "--log-dir", default=None,
        help="directory for per-cell structured JSONL run logs (repro/obs "
        "schema; one file per matrix cell, named by its coordinates)",
    )
    ap.add_argument("--allow-equiv-fail", action="store_true",
                    help="do not exit non-zero on equivalence violations")
    args = ap.parse_args()

    if not 0.0 < args.event_horizon <= 1.0:
        ap.error(f"--event-horizon must be in (0, 1]; got {args.event_horizon}")
    if args.buffer_size < 0 or args.buffer_size > args.clients:
        ap.error(
            f"--buffer-size must be in [0, --clients={args.clients}] "
            f"(0 disables buffered mode); got {args.buffer_size}"
        )
    if args.buffer_size and args.backend != "event":
        ap.error(
            f"--buffer-size requires --backend event (the buffered server "
            f"lives on the event backend's flight table); got "
            f"--backend {args.backend}"
        )
    if args.stale_gamma < 0:
        ap.error(f"--stale-gamma must be >= 0; got {args.stale_gamma}")
    if args.compress_level is not None and args.compress is None:
        ap.error(
            f"--compress-level requires --compress (pick one of: "
            f"{', '.join(available_compressors())})"
        )
    if args.compress:
        from repro.comm import check_algorithm, get_compressor
        from repro.fed.algorithms import get_algorithm

        try:
            get_compressor(args.compress)(args.compress_level)
            for a in args.algorithms.split(","):
                if a:
                    check_algorithm(args.compress, get_algorithm(a))
        except ValueError as e:
            ap.error(str(e))

    report = run_sweep(
        [a for a in args.algorithms.split(",") if a],
        [s for s in args.scenarios.split(",") if s],
        seeds=args.seeds, rounds=args.rounds, clients=args.clients,
        participation=args.participation, batch_size=args.batch_size,
        steps_per_epoch=args.steps_per_epoch, backend=args.backend,
        event_horizon=args.event_horizon,
        buffer_size=args.buffer_size, stale_gamma=args.stale_gamma,
        compress=args.compress, compress_level=args.compress_level,
        equiv_scenarios=[s for s in args.equiv_scenarios.split(",") if s],
        equiv_rounds=args.equiv_rounds, equiv_rtol=args.equiv_rtol,
        json_path=args.json or None, log_dir=args.log_dir,
    )
    bad = [r for r in report["equivalence"] if not r["ok"]]
    if bad and not args.allow_equiv_fail:
        raise SystemExit(
            f"backend equivalence FAILED for {len(bad)} cells: "
            + ", ".join(f"{r['scenario']}/{r['algorithm']}/{r['backend']}"
                        for r in bad[:8])
        )


if __name__ == "__main__":
    main()

"""Step functions lowered by the dry-run and used by train/serve drivers.

``client_train_step`` is the FedECADO client Forward-Euler step (paper eq. 9):
one fwd+bwd plus the flow-variable term — the training workload every client
executes per local step. ``prefill_step``/``decode_step`` are the serving
workloads. ``consensus_step`` is the paper's server update (lowered separately
in the dry-run's --consensus mode).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import ConsensusConfig, server_round
from repro.models import decode_step as _decode
from repro.models import loss_fn as _loss
from repro.models.transformer import prefill_step as _prefill

Pytree = Any


def make_client_train_step(cfg: ArchConfig):
    """(params, I_i, batch, lr) -> (loss, new_params).

    Flow variables are carried in the parameter dtype (bf16 on TPU) on the
    client; the server consensus keeps its fp32 master copies (DESIGN.md).
    """

    def step(params, I_i, batch, lr):
        loss, grads = jax.value_and_grad(partial(_loss, cfg=cfg))(params, batch)

        def upd(p, g, i):
            return (
                p.astype(jnp.float32)
                - lr * (g.astype(jnp.float32) + i.astype(jnp.float32))
            ).astype(p.dtype)

        new_params = jax.tree.map(upd, params, grads, I_i)
        return loss, new_params

    return step


def make_prefill_step(cfg: ArchConfig, max_len: int, long_mode: bool = False):
    def step(params, batch):
        return _prefill(params, batch, cfg, max_len=max_len, long_mode=long_mode)

    return step


def make_decode_step(cfg: ArchConfig, max_len: int):
    def step(params, cache, token, pos):
        return _decode(params, cache, token, pos, cfg, max_len=max_len)

    return step


def make_consensus_step(ccfg: ConsensusConfig):
    """(state, x_new_a, T_a, active_idx) -> (state, stats): the FedECADO
    server round (multi-rate BE integration over the synchronous window)."""

    def step(state, x_new_a, T_a, active_idx):
        return server_round(state, x_new_a, T_a, active_idx, ccfg)

    return step

import os


def _with_forced_device_count(flags: str, n: int) -> str:
    """Merge ``--xla_force_host_platform_device_count=n`` into an existing
    XLA_FLAGS value: every OTHER user/CI flag is preserved, any previous
    device-count flag is replaced (last one wins in XLA, but dropping the
    stale one keeps the env readable)."""
    kept = [
        t for t in flags.split()
        if not t.startswith("--xla_force_host_platform_device_count")
    ]
    kept.append(f"--xla_force_host_platform_device_count={n}")
    return " ".join(kept)


if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = _with_forced_device_count(
        os.environ.get("XLA_FLAGS", ""), 512
    )
# ^ MUST precede every other import: jax locks the device count on first init.
# The dry-run (and only the dry-run) needs 512 placeholder host devices to
# build the production meshes — but ONLY when dryrun is the program
# (``python -m repro.launch.dryrun``). A plain import (tests, tooling
# reusing the helpers) must not poison the process: forcing 512 host
# devices onto however many cores the host has makes every later psum
# rendezvous thrash, and it leaks into any subprocess via the env.

import argparse
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, get_shape
from repro.core import ConsensusConfig, init_server_state
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.roofline import (
    model_flops,
    parse_collective_bytes,
    roofline_terms,
)
from repro.launch.shardings import (
    batch_specs,
    cache_specs,
    fsdp_batch_axes,
    fsdp_param_specs,
    param_specs,
    stacked_specs,
    use_fsdp,
)
from repro.launch.steps import (
    make_client_train_step,
    make_consensus_step,
    make_decode_step,
    make_prefill_step,
)
from repro.models import batch_spec, init_cache, init_params

DTYPE = jnp.bfloat16


def _sds(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
        out["peak_bytes_per_device_est"] = int(live)
    return out


def build_specs(arch: str, shape_name: str, mesh):
    """(step_fn, arg ShapeDtypeStructs, in_shardings, donate) for a combo."""
    from repro.models import policy as policy_mod

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(partial(init_params, cfg=cfg, dtype=DTYPE), key)
    fsdp = use_fsdp(cfg, shape.global_batch, shape.kind, mesh)
    if fsdp:
        p_specs = fsdp_param_specs(params_shape, mesh)
        b_axes = fsdp_batch_axes(mesh)
    else:
        p_specs = param_specs(params_shape, mesh)
        b_axes = None

    # sharding-policy context BEFORE any tracing of the step:
    # pin the residual stream's batch sharding at block boundaries, and
    # select the expert-local shard_map MoE dispatch on TP meshes (H2)
    policy_name = "fsdp" if fsdp else "tp"
    if shape.kind in ("train", "prefill"):
        axes = tuple(mesh.axis_names) if fsdp else batch_axes(mesh)
        if shape.global_batch % int(np.prod([mesh.shape[a] for a in axes])) == 0:
            policy_mod.set_activation_spec(P(axes, None, None))
        else:
            policy_mod.set_activation_spec(None)
    else:
        policy_mod.set_activation_spec(None)
    if cfg.has_moe and not fsdp:
        policy_mod.set_moe_shard((mesh, "model"))
    else:
        policy_mod.set_moe_shard(None)
    # H4: zero-padded attention heads for awkward MHA head counts on TP
    # full-sequence shapes (qwen 40H -> 48): shards the O(S^2) attention
    a = cfg.attention
    M = mesh.shape["model"]
    if (
        not fsdp and a is not None and shape.kind in ("train", "prefill")
        and a.num_heads == a.num_kv_heads and a.num_heads % M != 0
        and -(-a.num_heads // M) * M <= a.num_heads * 1.25
    ):
        vH = -(-a.num_heads // M) * M
        ba_attn = batch_axes(mesh)
        policy_mod.set_head_pad((vH, P(ba_attn, None, "model", None)))
    else:
        policy_mod.set_head_pad(None)

    if shape.kind == "train":
        bspec = batch_spec(cfg, shape.global_batch, shape.seq_len, DTYPE)
        b_specs = batch_specs(cfg, bspec, mesh, axes=b_axes)
        step = make_client_train_step(cfg)
        args = (
            params_shape,
            params_shape,                      # I_i, flow variables
            bspec,
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        in_sh = (
            _named(p_specs, mesh), _named(p_specs, mesh),
            _named(b_specs, mesh), NamedSharding(mesh, P()),
        )
        out_sh = (NamedSharding(mesh, P()), _named(p_specs, mesh))
        donate = (0, 1)
    elif shape.kind == "prefill":
        bspec = batch_spec(cfg, shape.global_batch, shape.seq_len, DTYPE)
        b_specs = batch_specs(cfg, bspec, mesh, axes=b_axes)
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        # derive the cache structure from the step itself (whisper prefill
        # includes the cross-attention K/V in its output cache); trace under
        # the mesh so sharding constraints in the model resolve
        with mesh:
            cache_shape = jax.eval_shape(step, params_shape, bspec)[1]
        c_specs = cache_specs(cache_shape, cfg, mesh)
        args = (params_shape, bspec)
        in_sh = (_named(p_specs, mesh), _named(b_specs, mesh))
        out_sh = (
            NamedSharding(mesh, P(batch_axes(mesh), None)),
            _named(c_specs, mesh),
        )
        donate = ()
    else:  # decode shapes always use the tensor-parallel policy
        fsdp = False
        long_mode = shape.name == "long_500k"
        cache_builder = partial(
            init_cache, cfg, shape.global_batch, shape.seq_len, DTYPE,
            long_mode=long_mode,
        )
        if cfg.encoder_layers:
            cache_builder = partial(
                init_cache, cfg, shape.global_batch, shape.seq_len, DTYPE,
                enc_len=1536, long_mode=long_mode,
            )
        cache_shape = jax.eval_shape(cache_builder)
        c_specs = cache_specs(cache_shape, cfg, mesh)
        step = make_decode_step(cfg, max_len=shape.seq_len)
        ba = batch_axes(mesh)
        bsz = shape.global_batch
        tok_spec = P(ba) if bsz % np.prod([mesh.shape[a] for a in ba]) == 0 else P(None)
        args = (
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
        )
        in_sh = (
            _named(p_specs, mesh), _named(c_specs, mesh),
            NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
        )
        out_sh = (
            NamedSharding(mesh, tok_spec),
            _named(c_specs, mesh),
        )
        donate = (1,)
    return step, args, in_sh, out_sh, donate, ("fsdp" if fsdp else "tp")


def build_consensus_specs(
    arch: str, mesh, n_clients: int = 64, cohort: int = 16, flat: bool = False
):
    """Dry-run of the FedECADO server round itself (the paper's technique).

    ``flat``: use the beyond-paper collective-free layout (shard the
    parameter dim over all axes, client axis local) — EXPERIMENTS §Perf H3.
    """
    cfg = get_config(arch)
    key = jax.random.PRNGKey(0)
    # fp32 server master copies
    params_shape = jax.eval_shape(
        partial(init_params, cfg=cfg, dtype=jnp.float32), key
    )
    state_shape = jax.eval_shape(
        partial(init_server_state, n_clients=n_clients), params_shape
    )
    if flat:
        from repro.launch.shardings import consensus_flat_specs

        p_specs = consensus_flat_specs(params_shape, mesh, stacked=False)
        st_specs = consensus_flat_specs(params_shape, mesh, stacked=True)
    else:
        p_specs = param_specs(params_shape, mesh)
        st_specs = stacked_specs(params_shape, mesh, count=n_clients)
    ba = batch_axes(mesh)

    state_specs = type(state_shape)(
        x_c=p_specs,
        I=st_specs,
        g_inv=P(None),
        t=P(), dt_last=P(), round=P(),
    )
    x_new_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((cohort,) + l.shape, jnp.float32),
        params_shape,
    )
    ccfg = ConsensusConfig(max_substeps=8, max_backtracks=2)
    step = make_consensus_step(ccfg)
    args = (
        state_shape,
        x_new_shape,
        jax.ShapeDtypeStruct((cohort,), jnp.float32),
        jax.ShapeDtypeStruct((cohort,), jnp.int32),
    )
    in_sh = (
        _named(state_specs, mesh),
        _named(st_specs if flat else stacked_specs(params_shape, mesh, count=cohort), mesh),
        NamedSharding(mesh, P(None)),
        NamedSharding(mesh, P(None)),
    )
    from repro.core.fedecado import RoundStats

    scalar_sh = NamedSharding(mesh, P())
    out_sh = (
        _named(state_specs, mesh),
        RoundStats(*([scalar_sh] * len(RoundStats._fields))),
    )
    donate = (0,)
    return step, args, in_sh, out_sh, donate


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    consensus: bool = False,
    out_dir: Optional[str] = None,
    hlo_dir: Optional[str] = None,
    consensus_flat: bool = False,
) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        "__consensus_flat" if (consensus and consensus_flat)
        else "__consensus" if consensus else ""
    )
    cfg = get_config(arch)
    shape = get_shape(shape_name)

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "consensus": consensus, "status": "ok",
    }
    if shape_name == "long_500k" and cfg.long_context == "skip" and not consensus:
        rec["status"] = "skipped"
        rec["reason"] = "pure full-attention architecture (DESIGN.md §5)"
        _save(rec, tag, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        from repro.models import policy as policy_mod

        if consensus:
            step, args, in_sh, out_sh, donate = build_consensus_specs(
                arch, mesh, flat=consensus_flat
            )
            rec["policy"] = "flat" if consensus_flat else "tp"
            policy_mod.set_activation_spec(None)
            policy_mod.set_moe_shard(None)
            policy_mod.set_head_pad(None)
        else:
            step, args, in_sh, out_sh, donate, policy = build_specs(arch, shape_name, mesh)
            rec["policy"] = policy
        with mesh:
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # trip-count-aware per-device costs (launch/hlocost.py); XLA's own
        # cost_analysis counts while bodies once and is kept for reference
        from repro.launch import hlocost

        hc = hlocost.analyze(hlo)
        flops = hc["flops"]
        nbytes = hc["bytes"]
        coll_total = hc["collective_bytes"]
        mem = _mem_analysis(compiled)

        rec.update(
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=flops,
            bytes_per_device=nbytes,
            collective_bytes={
                k.replace("coll_", ""): v
                for k, v in hc.items() if k.startswith("coll_")
            } | {"total": coll_total},
            unknown_trip_counts=hc.get("unknown_trip_counts", 0),
            xla_once_counted={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            memory=mem,
            roofline=roofline_terms(flops, nbytes, coll_total),
        )
        if not consensus:
            n_chips = int(np.prod(list(mesh.shape.values())))
            mf = model_flops(cfg, shape)
            rec["model_flops_global"] = mf
            rec["model_flops_per_device"] = mf / n_chips
            rec["useful_flops_ratio"] = (
                (mf / n_chips) / flops if flops else None
            )
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _save(rec, tag, out_dir)
    return rec


def _save(rec, tag, out_dir):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["all"], default="all")
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES] + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--consensus", action="store_true",
                    help="lower the FedECADO server round instead of the model step")
    ap.add_argument("--consensus-flat", action="store_true",
                    help="beyond-paper collective-free consensus layout (H3)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch}__{shape}__{mesh_name}" + (
                    "__consensus_flat" if (args.consensus and args.consensus_flat)
                    else "__consensus" if args.consensus else ""
                )
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                t0 = time.time()
                rec = run_one(
                    arch, shape, mp, consensus=args.consensus,
                    out_dir=args.out, hlo_dir=args.hlo_dir,
                    consensus_flat=args.consensus_flat,
                )
                dt = time.time() - t0
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"dom={r['dominant']} bound={r['bound_s']:.4f}s "
                        f"flops={rec['flops_per_device']:.3g}"
                    )
                elif status == "error":
                    extra = rec["error"][:160]
                print(f"[{status}] {tag} ({dt:.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()

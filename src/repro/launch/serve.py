"""Serving driver: prefill a batch of prompts, then decode tokens.

Runs a reduced ``--arch`` config on CPU; the decode step is the same
``serve_step`` the dry-run lowers for decode_32k / long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_cross_cache, decode_step, init_params, make_batch
from repro.models.transformer import _encode, prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    batch = make_batch(key, cfg, args.batch, args.prompt_len)

    t0 = time.time()
    logits, cache = prefill_step(params, batch, cfg, max_len=args.max_len)
    if cfg.encoder_layers:
        cache["cross"] = build_cross_cache(
            params, _encode(params, batch["frames"], cfg), cfg
        )
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    step = jax.jit(
        lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, max_len=args.max_len)
    )
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    toks = jnp.stack(out_tokens, axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s total)")
    print("sample:", toks[0].tolist())


if __name__ == "__main__":
    main()

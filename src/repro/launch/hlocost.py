"""Thin shim: the trip-count-aware HLO analyzer moved to ``repro.tune.hlocost``
(the cost-model subsystem, DESIGN.md §12). Old call sites keep working."""
from repro.tune.hlocost import (  # noqa: F401
    COLLECTIVE_KINDS,
    Instr,
    _DTYPE_BYTES,
    _SHAPE_RE,
    _called_comps,
    _dot_flops,
    _shape_bytes,
    _shape_dims,
    analyze,
    parse_module,
)

"""Two-process ``jax.distributed`` multi-host smoke (DESIGN.md §13).

Proves the distributed runtime plumbing end-to-end on plain CPU hosts
(gloo collectives — no accelerator fabric needed): every process
initializes ``jax.distributed``, runs its own replica of a jit-resident
sharded segment (n=10^4 population, client-state cache on, cohort-sized
state) over its process-LOCAL devices, writes a §9 run log that must pass
the pinned ``validate_jsonl`` schema, and then the replicas cross-check:
the final loss history and central params are allgathered over the gloo
mesh and must agree **bitwise** across processes — same program + same
seed + the §13 deterministic draw means replica divergence is a bug, not
noise.

Launcher mode (the default; used by the CI ``multihost`` job)::

    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --clients 10000 --rounds 4 --log-dir obs-logs

spawns the worker processes (``REPRO_MH_RANK`` set, XLA_FLAGS forcing 2
host devices each so the sharded backend has a real local axis), waits,
and fails unless every worker printed its ``MULTIHOST_OK`` witness.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_RANK_ENV = "REPRO_MH_RANK"
_OK = "MULTIHOST_OK"


def _parse(argv=None):
    ap = argparse.ArgumentParser(description="jax.distributed multi-host smoke")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--coordinator", default="localhost:12355")
    ap.add_argument("--clients", type=int, default=10_000)
    ap.add_argument("--participation", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--devices-per-process", type=int, default=2)
    ap.add_argument("--log-dir", default="obs-logs")
    return ap.parse_args(argv)


def _worker(args, rank: int) -> int:
    import jax

    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        args.coordinator, num_processes=args.processes, process_id=rank
    )
    import numpy as np
    from jax.experimental import multihost_utils

    from repro.data import make_classification
    from repro.fed import FedSim, FedSimConfig, iid_partition
    from repro.obs import validate_jsonl

    n = args.clients
    data = make_classification(n * args.batch_size, dim=6, n_classes=3, seed=0)
    parts = iid_partition(len(data["y"]), n, seed=0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params0 = {
        "w0": jax.random.normal(k1, (6, 8)) / 3.0,
        "b0": jax.numpy.zeros((8,)),
        "w1": jax.random.normal(k2, (8, 3)) / np.sqrt(8),
        "b1": jax.numpy.zeros((3,)),
    }

    def loss_fn(p, batch):
        h = (
            jax.numpy.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"]
            + p["b1"]
        )
        lp = jax.nn.log_softmax(h)
        return -jax.numpy.mean(
            jax.numpy.take_along_axis(
                lp, batch["y"][:, None].astype(jax.numpy.int32), -1
            )
        )

    os.makedirs(args.log_dir, exist_ok=True)
    log_path = os.path.join(args.log_dir, f"multihost_rank{rank}.jsonl")
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=n, participation=args.participation,
        rounds=args.rounds, batch_size=args.batch_size, steps_per_epoch=1,
        hetero=None, seed=0, eval_every=1 << 30, backend="sharded",
        client_cache=True, log_jsonl=log_path,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    hist = sim.run()

    # run log through the §9 validator — schema drift fails the smoke
    recs = validate_jsonl(log_path)
    rounds = [r for r in recs if r["kind"] == "round"]
    assert len(rounds) == args.rounds, (len(rounds), args.rounds)

    # replica agreement over the gloo mesh: bitwise, not rtol — both
    # processes ran the same deterministic program. float32 on both sides:
    # the gather stages through device arrays, which are f32 under the
    # default (x64-off) config, and the underlying values are f32 anyway.
    loss = np.asarray(hist.loss, np.float32)
    all_loss = multihost_utils.process_allgather(loss)
    for r in range(args.processes):
        np.testing.assert_array_equal(
            all_loss[r], loss,
            err_msg=f"rank {rank}: loss history diverged from rank {r}",
        )
    flat = np.concatenate([
        np.ravel(np.asarray(l, np.float32))
        for l in jax.tree.leaves(jax.device_get(sim.current_params()))
    ])
    all_params = multihost_utils.process_allgather(flat)
    for r in range(args.processes):
        np.testing.assert_array_equal(
            all_params[r], flat,
            err_msg=f"rank {rank}: final params diverged from rank {r}",
        )
    print(
        f"{_OK} rank={rank} processes={jax.process_count()} "
        f"local_devices={len(jax.local_devices())} "
        f"global_devices={len(jax.devices())} "
        f"state_rows={sim.state_rows} n={n} "
        f"final_loss={float(loss[-1]):.6f}",
        flush=True,
    )
    return 0


def _launch(args) -> int:
    procs = []
    for rank in range(args.processes):
        env = dict(os.environ)
        env[_RANK_ENV] = str(rank)
        # a real local device axis for the sharded backend; must precede
        # the child's jax import, hence env, not code
        env.setdefault(
            "XLA_FLAGS",
            "--xla_force_host_platform_device_count="
            f"{args.devices_per_process}",
        )
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.multihost", *sys.argv[1:]],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        ))
    status = 0
    for rank, p in enumerate(procs):
        out, _ = p.communicate(timeout=1200)
        sys.stdout.write(out)
        if p.returncode != 0 or f"{_OK} rank={rank}" not in out:
            print(f"# multihost: rank {rank} FAILED "
                  f"(exit {p.returncode})", flush=True)
            status = 1
    if status == 0:
        print(f"# multihost: all {args.processes} ranks agreed bitwise",
              flush=True)
    return status


def main(argv=None) -> int:
    args = _parse(argv)
    rank = os.environ.get(_RANK_ENV)
    if rank is None:
        return _launch(args)
    return _worker(args, int(rank))


if __name__ == "__main__":
    sys.exit(main())

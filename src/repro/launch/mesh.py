"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — dryrun.py must set XLA_FLAGS before the
first jax device query.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod" axis
carries data parallelism across pods (and, in the federated runtime, the
client cohort axis spans ("pod", "data")).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under launch/dryrun.py (it sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_client_mesh(max_devices: int | None = None,
                     groups: int | None = None):
    """Mesh over the local devices for the sharded execution backend.

    Default: a 1-D mesh with a single ``"clients"`` axis — the cohort axis
    is shard_map-ed over it and the Schur-arrowhead consensus reductions
    run as psum along it. The federated engine's smoke models are small
    enough that model dims stay replicated, so every device goes to client
    parallelism (contrast the training meshes above, which reserve a
    "model" axis). Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this yields an
    N-way CPU mesh for tests/benchmarks.

    ``groups`` (hierarchical tree aggregation, DESIGN.md §13) splits the
    same devices into a 2-D ``("groups", "clients")`` mesh of ``groups``
    device groups — cohort arrays shard over BOTH axes (same shard count as
    the 1-D mesh) and cross-device reductions run intra-group first, then
    across groups. ``groups`` must divide the usable device count.

    Uses the process-LOCAL devices: the sharded sim backend is a
    single-controller component, and under ``jax.distributed`` (the
    multi-host smoke, repro/launch/multihost.py) every process runs its
    own replica of the sim over its own devices — global meshes would
    pull in non-addressable devices the host-side data feed cannot
    populate. Single-process runs see the identical device list.
    """
    devices = jax.local_devices()
    n = len(devices) if max_devices is None else max(1, min(max_devices, len(devices)))
    if groups and groups > 1:
        if n % groups:
            raise ValueError(
                f"sharded_groups={groups} must divide the usable device "
                f"count ({n})"
            )
        return jax.make_mesh(
            (groups, n // groups), ("groups", "clients"),
            devices=devices[:n],
        )
    return jax.make_mesh((n,), ("clients",), devices=devices[:n])


def batch_axes(mesh) -> tuple:
    """The axes a global batch (or client cohort) is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n

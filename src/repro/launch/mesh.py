"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — dryrun.py must set XLA_FLAGS before the
first jax device query.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod" axis
carries data parallelism across pods (and, in the federated runtime, the
client cohort axis spans ("pod", "data")).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "run under launch/dryrun.py (it sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def batch_axes(mesh) -> tuple:
    """The axes a global batch (or client cohort) is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n

from repro.launch.mesh import batch_axes, data_axis_size, make_production_mesh, model_axis_size

__all__ = [
    "make_production_mesh", "batch_axes", "model_axis_size", "data_axis_size",
]

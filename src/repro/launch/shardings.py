"""Sharding rules: map every parameter / batch / cache leaf to a
PartitionSpec on the production mesh.

Rules (DESIGN.md §5):
  * block params carry a leading scan-period axis — never sharded
  * attention projections: shard heads over "model" if divisible, else
    head_dim, else replicate (smollm 15H -> head_dim; GQA kv=8 < 16 -> kv dh)
  * FFN: d_ff over "model" (column-parallel up / row-parallel down)
  * MoE: experts over "model" if divisible (jamba 16, moonshot 64,
    arctic 128), else expert d_ff (mixtral 8e)
  * embeddings / lm head: vocab over "model"
  * batch (and the federated client cohort axis): over ("pod","data")
  * FedECADO flow variables: client axis over ("pod","data"), inner dims
    inherit the parameter spec
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import batch_axes, model_axis_size

Pytree = Any

# leaf-name -> candidate shard dims (negative, from the right), tried in order
_RULES: Dict[Tuple[str, int], Tuple[int, ...]] = {
    # (name, ndim-after-stripping-period-axis): candidate dims
    # NEVER shard head_dim: it contracts inside the attention einsums and
    # forces an all-reduce of (B,H,cq,ck) logits per chunk pair (measured:
    # 27s collective term on smollm train_4k — EXPERIMENTS.md §Perf it.2).
    ("embed", 2): (-2, -1),        # (V, d): vocab first
    ("lm_head", 2): (-1,),         # (d, V)
    ("wq", 3): (-2, -3),           # (d, H, dh): heads, else d (row-parallel)
    ("wk", 3): (-2, -3),
    ("wv", 3): (-2, -3),
    ("bq", 2): (-2,),              # replicate when heads don't divide
    ("bk", 2): (-2,),
    ("bv", 2): (-2,),
    ("wo", 3): (-3,),              # (H, dh, d): heads, else replicate
    ("w_gate", 2): (-1,),          # mlp (d, f)
    ("w_up", 2): (-1,),
    ("w_down", 2): (-2,),          # (f, d)
    ("w_gate", 3): (-3, -1),       # moe (E, d, f)
    ("w_up", 3): (-3, -1),
    ("w_down", 3): (-3, -2),       # (E, f, d)
    ("router", 2): (),
    # mamba
    ("w_in", 2): (-1,),            # (d, 2*inner)
    ("conv_w", 2): (-1,),
    ("conv_b", 1): (-1,),
    ("w_x_dbc", 2): (-2,),         # (inner, k) row-parallel
    ("w_dt", 2): (-1,),
    ("dt_bias", 1): (-1,),
    ("a_log", 2): (-2,),
    ("d_skip", 1): (-1,),
    ("w_out", 2): (-2,),           # (inner, d)
    # xlstm
    ("w_in", 4): (-2,),            # slstm (d, H, dh, 4)
    ("b_in", 3): (-2,),
    ("r", 3): (-2,),
    ("w_if", 3): (),
    ("b_if", 2): (),
    ("scale", 1): (),
    ("bias", 1): (),
}

_PERIOD_STACKED_ROOTS = ("blocks", "enc_blocks")


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        out.append(str(k))
    return tuple(out)


def leaf_spec(path, leaf, mesh) -> P:
    names = _path_names(path)
    name = names[-1]
    M = model_axis_size(mesh)
    stacked = names[0] in _PERIOD_STACKED_ROOTS
    shape = leaf.shape
    eff_shape = shape[1:] if stacked else shape
    ndim = len(eff_shape)

    cands = _RULES.get((name, ndim))
    if cands is None:
        # fallback: replicate small leaves; shard largest divisible dim
        if leaf.size < (1 << 17):
            cands = ()
        else:
            order = sorted(range(ndim), key=lambda i: -eff_shape[i])
            cands = tuple(i - ndim for i in order)

    spec = [None] * len(shape)
    for c in cands:
        if eff_shape[c] % M == 0:
            spec[len(shape) + c] = "model"
            break
    return P(*spec)


def param_specs(params_shape: Pytree, mesh) -> Pytree:
    """PartitionSpec pytree for a parameter (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf_spec(path, leaf, mesh), params_shape
    )


# ---------------------------------------------------------------------------
# FSDP-style policy: batch over BOTH mesh axes, params sharded for storage
# only (XLA inserts per-layer all-gathers). Used when tensor parallelism is
# structurally awkward (attention heads % model-axis != 0) and the model is
# small enough to re-gather per step (DESIGN.md §5 / EXPERIMENTS §Perf it.3).
# ---------------------------------------------------------------------------


def use_fsdp(cfg: ArchConfig, global_batch: int, kind: str, mesh) -> bool:
    a = cfg.attention
    if a is None:
        return False
    M = model_axis_size(mesh)
    awkward = (a.num_heads % M != 0)
    total_chips = 1
    for ax in mesh.axis_names:
        total_chips *= mesh.shape[ax]
    fits = cfg.param_count() * 2 <= 80e9          # <=80 GB bf16 re-gather
    return (
        awkward and fits and kind in ("train", "prefill")
        and global_batch % total_chips == 0
    )


def fsdp_param_specs(params_shape: Pytree, mesh) -> Pytree:
    """Storage sharding: largest mesh-divisible dim of each leaf."""
    M = model_axis_size(mesh)

    def spec(path, leaf):
        shape = leaf.shape
        if leaf.size < (1 << 14):
            return P(*([None] * len(shape)))
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        s = [None] * len(shape)
        for i in order:
            if shape[i] % M == 0:
                s[i] = "model"
                break
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def fsdp_batch_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)  # ("pod","data","model") / ("data","model")


def consensus_flat_specs(params_shape: Pytree, mesh, stacked: bool = False) -> Pytree:
    """Beyond-paper consensus layout (EXPERIMENTS §Perf H3): the FedECADO
    server step is elementwise over parameters, so shard the largest
    parameter dim over ALL mesh axes jointly and keep the client axis LOCAL.
    Every Γ/BE/Schur op then runs collective-free; only the scalar LTE maxima
    are reduced. (The paper's LU view hides this: the arrowhead system is
    D independent (A+1)-systems, so D is the natural parallel axis.)"""
    all_axes = tuple(mesh.axis_names)
    n_all = _axes_size(mesh, all_axes)

    def spec(path, leaf):
        # leaf is a PARAM-shaped ShapeDtypeStruct; ``stacked`` prepends the
        # (local) client axis of the stacked state trees
        dims = leaf.shape
        s = [None] * len(dims)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] % n_all == 0:
                s[i] = all_axes
                break
        else:
            # fall back to the model axis for small/odd leaves
            for i in order:
                if dims[i] % model_axis_size(mesh) == 0:
                    s[i] = "model"
                    break
        if stacked:
            s = [None] + s  # client axis local
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(params_shape: Pytree, mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh)
    )


def stacked_specs(params_shape: Pytree, mesh, count: Optional[int] = None) -> Pytree:
    """Specs for client-stacked trees (FedECADO I, x_new): leading client
    axis over the batch axes (falling back to "data" then replicated when
    ``count`` doesn't divide), inner dims per the parameter rule."""
    ba = batch_axes(mesh)
    if count is not None:
        for cand in (ba, ("data",), ()):
            if cand and count % _axes_size(mesh, cand) == 0:
                ba = cand
                break
        else:
            ba = None
        if ba == ():
            ba = None
    base = param_specs(params_shape, mesh)
    return jax.tree.map(lambda s: P(ba, *s), base)


def batch_specs(
    cfg: ArchConfig, batch_shape: Dict[str, Any], mesh, axes: Optional[tuple] = None
) -> Dict[str, P]:
    ba = axes if axes is not None else batch_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        nb = getattr(v, "ndim", None) or len(v.shape)
        bsz = v.shape[0]
        axis0 = ba if bsz % _axes_size(mesh, ba) == 0 else None
        out[k] = P(axis0, *([None] * (nb - 1)))
    return out


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def cache_specs(cache_shape: Pytree, cfg: ArchConfig, mesh) -> Pytree:
    """Specs for the decode cache: batch over ("pod","data") when divisible
    (decode_32k), else shard the cache width (long_500k, batch=1); heads /
    head_dim / inner dims over "model" when divisible."""
    ba = batch_axes(mesh)
    D = _axes_size(mesh, ba)
    M = model_axis_size(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape  # leading period axis at 0, batch at 1
        s: list = [None] * len(shape)
        batch_ok = shape[1] % D == 0
        if batch_ok:
            s[1] = ba
        if name in ("k", "v"):           # (per, B, W, Hkv, dh)
            if shape[3] % M == 0:
                s[3] = "model"
            elif shape[4] % M == 0:
                s[4] = "model"
            if not batch_ok and shape[2] % D == 0:
                s[2] = ba                # long_500k: shard the window
        elif name == "conv":             # (per, B, cw-1, inner)
            if shape[3] % M == 0:
                s[3] = "model"
        elif name == "ssm":              # (per, B, inner, N)
            if shape[2] % M == 0:
                s[2] = "model"
        elif name == "C":                # (per, B, H, dk, dv)
            if shape[3] % M == 0:
                s[3] = "model"
            elif shape[4] % M == 0:
                s[4] = "model"
        elif name in ("n", "h", "c"):    # (per, B, H, dk)
            if len(shape) > 3 and shape[3] % M == 0:
                s[3] = "model"
        elif name == "m":                # (per, B, H)
            pass
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)

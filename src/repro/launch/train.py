"""Training driver.

Two modes:
  * ``--mode central``: plain (non-federated) LM training of a reduced
    ``--arch`` config on synthetic token streams — the "does the substrate
    train" driver (runs on CPU; on TPU the same step is pjit-ed onto the
    production mesh via --mesh).
  * ``--mode fed``: federated training over n clients with Dirichlet
    non-IID partitions and heterogeneous (lr_i, e_i) — the paper's workflow
    (Algorithm 2) end to end. ``--algorithm`` choices are enumerated from
    the fed/algorithms plugin registry, so a newly registered algorithm is
    immediately selectable (and an unknown name dies at argparse time with
    the registered names listed, not deep inside FedSim).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50
  PYTHONPATH=src python -m repro.launch.train --mode fed --algorithm fedecado
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import ConsensusConfig
from repro.data import lm_batches, make_classification, make_lm_stream
from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition
from repro.models import init_params, loss_fn
from repro.optim import adam, apply_updates


def run_central(args) -> None:
    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    opt = adam(args.lr)
    opt_state = opt.init(params)
    stream = make_lm_stream(1 << 15, vocab=cfg.vocab_size, seed=args.seed)
    batches = lm_batches(stream, args.batch_size, args.seq_len, seed=args.seed)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(partial(loss_fn, cfg=cfg))(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, loss = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)", flush=True)
    print("done")


def run_fed(args) -> None:
    data = make_classification(
        n_samples=args.n_samples, dim=32, n_classes=10, seed=args.seed
    )
    # a scenario owns partitioning AND the heterogeneity axes
    # (repro/scenarios); without one, keep the historical explicit
    # Dirichlet(alpha) split + uniform HeteroConfig envelope
    parts = (
        None if args.scenario
        else dirichlet_partition(
            data["y"], args.clients, alpha=args.alpha, seed=args.seed
        )
    )

    def init_mlp(key, dims=(32, 64, 10)):
        ks = jax.random.split(key, 2)
        return {
            "w0": jax.random.normal(ks[0], (dims[0], dims[1])) / np.sqrt(dims[0]),
            "b0": jnp.zeros((dims[1],)),
            "w1": jax.random.normal(ks[1], (dims[1], dims[2])) / np.sqrt(dims[1]),
            "b1": jnp.zeros((dims[2],)),
        }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def mlp_loss(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), axis=-1))

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    cfg = FedSimConfig(
        algorithm=args.algorithm,
        n_clients=args.clients,
        participation=args.participation,
        rounds=args.rounds,
        batch_size=32,
        steps_per_epoch=3,
        hetero=(
            HeteroConfig(1e-3, 1e-2, 1, 5)
            if args.hetero and not args.scenario else None
        ),
        consensus=ConsensusConfig(use_kernels=args.kernels),
        seed=args.seed,
        eval_every=max(args.rounds // 10, 1),
        scenario=args.scenario,
    )
    sim = FedSim(mlp_loss, init_mlp(jax.random.PRNGKey(0)), data, parts, cfg, eval_fn)
    hist = sim.run()
    for rnd, m in zip(hist.eval_rounds, hist.metrics):
        print(f"round {rnd:4d}  acc {m['acc']:.4f}")
    print(f"final train-loss {hist.loss[-1]:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["central", "fed"], default="central")
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    # fed mode — choices come from the plugin registry (fed/algorithms)
    from repro.fed.algorithms import available_algorithms

    ap.add_argument(
        "--algorithm", default="fedecado", choices=list(available_algorithms()),
        help="federated algorithm (registered plugins: %(choices)s)",
    )
    from repro.scenarios import available_scenarios

    ap.add_argument(
        "--scenario", default=None, choices=list(available_scenarios()),
        help="heterogeneity scenario (repro/scenarios registry); overrides "
        "--alpha/--hetero with the scenario's own axes",
    )
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--participation", type=float, default=0.25)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--n-samples", type=int, default=4096)
    ap.add_argument("--hetero", action="store_true", default=True)
    ap.add_argument("--no-hetero", dest="hetero", action="store_false")
    ap.add_argument("--kernels", action="store_true",
                    help="use the fused Pallas consensus kernel path")
    args = ap.parse_args()
    (run_fed if args.mode == "fed" else run_central)(args)


if __name__ == "__main__":
    main()

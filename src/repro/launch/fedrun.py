import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# ^ demo mesh of 8 host devices (data=4, model=2); must precede jax import.

"""Distributed federated training ON the mesh — the full FedECADO pipeline
pjit-ed, not just dry-run lowered:

  * the active cohort's local training runs as ONE vmapped+pjit-ed
    computation: client axis sharded over "data", model dims over "model";
  * the consensus round (Γ + BE arrowhead solve) runs sharded with the
    client-flow state on the same mesh;
  * everything except participation sampling and data feeding is on-device.

  PYTHONPATH=src python -m repro.launch.fedrun --arch smollm-360m --rounds 5

``--backend sharded`` switches to the multi-device execution backend's
machinery (sim/sharded.py, DESIGN.md §5.5): cohort local training is
``shard_map``-ed over a 1-D "clients" mesh spanning every host device,
with uneven cohort→device padding, and the BE Schur-arrowhead consensus
reductions run as psum along that axis instead of a gathered dense solve.

``--backend event`` drives the device-resident multi-rate event engine
(core/multirate.py, DESIGN.md §8) directly: each round's cohort endpoints
are inserted into the flight table and a jitted insert+integrate event
round absorbs the ``--event-horizon`` quantile of in-flight windows,
carrying stragglers across rounds via Γ re-anchoring — per-round
arrived/stale/wave/substep stats are printed so the async behaviour is
observable.

This is the cross-silo deployment shape described in DESIGN.md §2, scaled
down to host devices so it executes on CPU.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import ConsensusConfig, init_server_state, server_round, set_gains
from repro.data import make_lm_stream
from repro.models import init_params, loss_fn
from repro.obs import (
    RunLog,
    TraceRecorder,
    format_round_line,
    make_record,
    span,
    summarize_records,
)
from repro.sim.vectorized import build_cohort_runner, cohort_vmap_fn


class _Obs:
    """Optional run-log + trace wiring shared by the three driver loops:
    one header, one shared-schema record per round (also the printed round
    line via the shared formatter), one summary."""

    def __init__(self, args, backend: str):
        self.records = []
        self.runlog = RunLog(args.log_jsonl) if args.log_jsonl else None
        if self.runlog is not None:
            self.runlog.start(
                config=vars(args), backend=backend,
                n_clients=args.clients, rounds=args.rounds,
            )
        self.recorder = (
            TraceRecorder(args.trace_json) if args.trace_json else None
        )
        if self.recorder is not None:
            self.recorder.install()

    def round(self, rec, t0, extra=None) -> None:
        self.records.append(rec)
        if self.runlog is not None:
            self.runlog.round(rec)
        print(format_round_line(rec, wall_s=time.time() - t0, extra=extra),
              flush=True)

    def close(self) -> None:
        if self.runlog is not None:
            self.runlog.summary(summarize_records(self.records))
            self.runlog.close()
        if self.recorder is not None:
            self.recorder.uninstall()
            self.recorder.save()


def main() -> None:
    from repro.comm import available_compressors, make_comm_spec
    from repro.fed.algorithms import available_algorithms, get_algorithm

    # this driver runs the consensus machinery directly, so only registered
    # algorithms with flow dynamics are eligible; argparse rejects the rest
    # with the eligible names listed
    flow_algs = [
        n for n in available_algorithms() if get_algorithm(n).has_flow_dynamics
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument(
        "--algorithm", choices=flow_algs, default="fedecado",
        help="flow-dynamics algorithm from the plugin registry; picks the "
        "registered client kind for the cohort runner (on this demo's "
        "equal-sized synthetic streams p̂_i ≡ 1, so fedecado and ecado "
        "coincide numerically)",
    )
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backend", choices=("vectorized", "event", "sharded"),
        default="vectorized",
        help="vectorized = vmapped cohort pjit over (data, model); event = "
        "device-resident flight-table scheduler (async arrivals, staleness); "
        "sharded = shard_map over a 1-D clients mesh with psum consensus "
        "reductions",
    )
    ap.add_argument(
        "--event-horizon", type=float, default=0.7,
        help="event backend: quantile of in-flight windows absorbed per "
        "round, in (0, 1] (< 1.0 leaves stragglers pending across rounds)",
    )
    ap.add_argument(
        "--event-max-waves", type=int, default=2,
        help="event backend: BE sync groups per round (>= 1)",
    )
    ap.add_argument(
        "--buffer-size", type=int, default=0,
        help="event backend: fully-asynchronous buffered server (DESIGN.md "
        "§10) — aggregate whenever this many endpoints are in flight "
        "instead of draining a per-round horizon quantile; 0 disables, "
        "otherwise must be in [1, --clients]",
    )
    ap.add_argument(
        "--stale-gamma", type=float, default=0.25,
        help="buffered event mode: staleness-weight decay — an endpoint "
        "that waited s rounds is absorbed with weight 1/(1 + gamma*s); "
        "0 disables the damping (>= 0)",
    )
    ap.add_argument(
        "--compress", choices=available_compressors(), default=None,
        help="lossy uplink compressor from the repro/comm registry applied "
        "to each cohort endpoint before it reaches the server (identity = "
        "full-precision accounting only); this driver is flow-only, so "
        "compressors whose plugin declares supports_flow=False are "
        "rejected with the eligible names listed",
    )
    ap.add_argument(
        "--compress-level", type=int, default=None,
        help="compressor-specific level (e.g. topk keep-fraction tier); "
        "omit for the compressor's default — invalid levels are rejected "
        "with the valid set listed",
    )
    ap.add_argument(
        "--log-jsonl", default=None,
        help="write a structured JSONL run log (header + one shared-schema "
        "record per round + summary; repro/obs, DESIGN.md §9)",
    )
    ap.add_argument(
        "--trace-json", default=None,
        help="write Chrome-trace JSON of host-side spans (open in "
        "chrome://tracing or ui.perfetto.dev)",
    )
    args = ap.parse_args()

    # reject bad event-path knobs HERE with actionable messages — a horizon
    # outside (0, 1] or an unsatisfiable buffer size would otherwise surface
    # rounds later as NaN losses or a stalled server
    if not (0.0 < args.event_horizon <= 1.0):
        ap.error(
            f"--event-horizon must be in (0, 1], got {args.event_horizon} "
            "(1.0 = absorb every in-flight window each round)"
        )
    if args.event_max_waves < 1:
        ap.error(
            f"--event-max-waves must be >= 1, got {args.event_max_waves}"
        )
    if args.buffer_size < 0 or args.buffer_size > args.clients:
        ap.error(
            f"--buffer-size must be in [1, --clients={args.clients}] "
            f"(0 disables buffered mode), got {args.buffer_size} — a buffer "
            "larger than the client population can never fill, so the "
            "server would stall forever"
        )
    if args.stale_gamma < 0.0:
        ap.error(f"--stale-gamma must be >= 0, got {args.stale_gamma}")
    if args.buffer_size and args.backend != "event":
        ap.error(
            "--buffer-size is an event-backend knob; add --backend event"
        )

    if args.compress_level is not None and args.compress is None:
        ap.error("--compress-level requires --compress (pick a compressor "
                 f"from: {', '.join(available_compressors())})")

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    lf = lambda p, b: loss_fn(p, b, cfg)
    client_kind = get_algorithm(args.algorithm).client_kind

    # wire model: always built (identity when --compress is absent) so
    # bytes_up/bytes_down accounting is unconditional; level and the
    # compressor × flow-algorithm combo are validated here, before any
    # training work
    try:
        comm = make_comm_spec(
            args.compress, args.compress_level, params,
            seed=args.seed, alg_cls=get_algorithm(args.algorithm),
        )
    except ValueError as e:
        ap.error(str(e))

    ccfg = ConsensusConfig(L=0.05, delta=1e-3, dt_init=0.05, max_substeps=16)
    state = init_server_state(params, args.clients, ccfg.dt_init)
    state = set_gains(state, jnp.full((args.clients,), 0.05))

    streams = [
        make_lm_stream(1 << 13, vocab=cfg.vocab_size, seed=100 + i)
        for i in range(args.clients)
    ]
    rng = np.random.RandomState(args.seed)

    def batches_for(i, n_steps):
        s = streams[i]
        starts = rng.randint(0, len(s) - args.seq_len - 1, (n_steps, args.batch_size))
        return np.stack([[s[a:a + args.seq_len] for a in row] for row in starts])

    if args.backend == "sharded":
        _run_sharded(args, lf, ccfg, state, batches_for, rng, client_kind,
                     comm)
        return
    if args.backend == "event":
        _run_event(args, lf, ccfg, state, batches_for, rng, client_kind,
                   comm)
        return

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # shardings: client axis -> "data"; everything else replicated (smoke
    # configs are small; full-scale runs use launch/shardings.py rules)
    cax = NamedSharding(mesh, P("data"))

    # --- cohort local training: the multi-rate engine's vectorized runner
    # (vmap over the client axis), pjit over the mesh — the same code path
    # FedSim's "vectorized" backend uses, so launch/ and fed/ share one
    # local-integration implementation (DESIGN.md §5.1)
    cohort_train = build_cohort_runner(lf, kind=client_kind)
    ones_cohort = jnp.ones((args.cohort,), jnp.float32)
    full_steps = jnp.full((args.cohort,), args.steps, jnp.int32)

    round_fn = jax.jit(lambda s, x, T, i: server_round(s, x, T, i, ccfg))

    obs = _Obs(args, backend="vectorized")
    with mesh:
        t0 = time.time()
        for rnd in range(args.rounds):
            with span("round", round=rnd):
                idx = np.sort(rng.choice(args.clients, args.cohort, replace=False))
                lrs = rng.uniform(5e-3, 2e-2, args.cohort).astype(np.float32)
                toks = np.stack([batches_for(int(i), args.steps) for i in idx])
                batches_a = {"tokens": jax.device_put(jnp.asarray(toks), cax)}
                I_a = jax.tree.map(lambda l: l[jnp.asarray(idx)], state.I)
                x_new_a, losses = cohort_train(
                    state.x_c, I_a, batches_a, jnp.asarray(lrs), ones_cohort, full_steps
                )
                if not comm.lossless:
                    # lossy wire: the server only ever sees the compressed
                    # endpoints (flow family — no error feedback)
                    x_new_a, _ = comm.compress_endpoints(
                        state.x_c, x_new_a, None, rnd
                    )
                T_a = jnp.asarray(lrs * args.steps, jnp.float32)
                state, stats = round_fn(
                    state, x_new_a, T_a, jnp.asarray(idx, jnp.int32)
                )
                s = jax.device_get(stats)
            obs.round(make_record(
                rnd, loss=float(jnp.mean(losses)), cohort=args.cohort,
                substeps=s.n_substeps, backtracks=s.n_backtracks,
                dt_min=s.dt_min, dt_max=s.dt_max, dt_sum=s.dt_sum,
                tau_end=s.tau_end,
                bytes_up=args.cohort * comm.payload_up,
                bytes_down=args.cohort * comm.payload_down,
            ), t0)
    obs.close()
    print("done — cohort training and consensus both executed on the mesh")


def _run_event(args, lf, ccfg, state, batches_for, rng, client_kind,
               comm) -> None:
    """Cohort training + the flight-table event round on device: busy draws
    are masked before dispatch, stragglers carry across rounds, and the
    per-round multi-rate stats are printed. ``--buffer-size K`` switches
    the horizon to the buffered-server K-trigger with ``--stale-gamma``
    staleness weighting (DESIGN.md §10)."""
    from functools import partial

    from repro.core.flow import broadcast_clients
    from repro.core.multirate import (
        flight_insert_checked,
        init_flight_table,
        multirate_integrate,
    )

    cohort_train = build_cohort_runner(lf, kind=client_kind)
    table = init_flight_table(state.x_c, args.clients)
    ones_cohort = jnp.ones((args.cohort,), jnp.float32)
    full_steps = jnp.full((args.cohort,), args.steps, jnp.int32)
    buffer_k = args.buffer_size or None
    stale_gamma = args.stale_gamma if buffer_k else 0.0

    @partial(jax.jit, static_argnums=())
    def event_round(state_tup, tab, x_new_a, idx, Ts, dmask, rnd):
        x_c, I, g_inv, dt_last, t = state_tup
        A = idx.shape[0]
        if not comm.lossless:
            # lossy wire: endpoints enter the flight table compressed, so
            # stragglers age and re-base on exactly what the wire carried
            x_new_a, _ = comm.compress_endpoints(x_c, x_new_a, None, rnd)
        tab, refused = flight_insert_checked(
            tab, idx, broadcast_clients(x_c, A), x_new_a, Ts, dmask
        )
        out = multirate_integrate(
            x_c, I, g_inv, dt_last, t, tab, ccfg,
            args.event_horizon, args.event_max_waves,
            buffer_k=buffer_k, stale_gamma=stale_gamma,
        )
        return out + (refused,)

    obs = _Obs(args, backend="event")
    t0 = time.time()
    for rnd in range(args.rounds):
        with span("round", round=rnd):
            idx = np.sort(rng.choice(args.clients, args.cohort, replace=False))
            lrs = rng.uniform(5e-3, 2e-2, args.cohort).astype(np.float32)
            toks = np.stack([batches_for(int(i), args.steps) for i in idx])
            I_a = jax.tree.map(lambda l: l[jnp.asarray(idx)], state.I)
            x_new_a, losses = cohort_train(
                state.x_c, I_a, {"tokens": jnp.asarray(toks)},
                jnp.asarray(lrs), ones_cohort, full_steps,
            )
            busy = np.asarray(table.alive)[idx]
            dmask = jnp.asarray(1.0 - busy, jnp.float32)
            Ts = jnp.asarray(lrs * args.steps, jnp.float32)
            x_c, I, dt_last, t, table, st, refused = event_round(
                (state.x_c, state.I, state.g_inv, state.dt_last, state.t),
                table, x_new_a, jnp.asarray(idx, jnp.int32), Ts, dmask,
                jnp.asarray(rnd, jnp.int32),
            )
            state = state._replace(
                x_c=x_c, I=I, dt_last=dt_last, t=t, round=state.round + 1
            )
            st, refused = jax.device_get((st, refused))
        kept = float(np.sum(1.0 - busy))
        loss = (
            float(np.sum(np.asarray(losses) * (1.0 - busy)) / kept)
            if kept else float("nan")
        )
        obs.round(make_record(
            rnd, loss=loss, cohort=int(kept),
            dropped=int(busy.sum()) + int(refused),
            substeps=st.substeps, backtracks=st.backtracks,
            dt_min=st.dt_min, dt_max=st.dt_max, dt_sum=st.dt_sum,
            waves=st.waves, arrived=st.arrived, stale=st.stale,
            horizon=st.horizon, tau_end=st.tau_end,
            stale_hist=np.asarray(st.stale_hist),
            # uplink charged at absorption, downlink at dispatch — busy
            # re-draws were never dispatched, so they cost nothing
            bytes_up=int(st.arrived) * comm.payload_up,
            bytes_down=int(kept) * comm.payload_down,
        ), t0, extra=(
            {"max_stale": int(st.max_stale)} if buffer_k else None
        ))
    obs.close()
    print("done — flight-table event rounds executed on device")


def _run_sharded(args, lf, ccfg, state, batches_for, rng, client_kind,
                 comm) -> None:
    """Cohort training + consensus through the sharded backend's building
    blocks: shard_map local integration over the 1-D clients mesh and the
    psum Schur-arrowhead solve, with the cohort padded to the device count."""
    from repro.launch.mesh import make_client_mesh
    from repro.sim.engine import pad_cohort_ids
    from repro.sim.sharded import AXIS, build_flow_apply

    mesh = make_client_mesh()
    n_dev = mesh.shape[AXIS]
    A = args.cohort
    A_pad = -(-A // n_dev) * n_dev

    c1 = P(AXIS)
    cohort_train = jax.jit(shard_map(
        cohort_vmap_fn(lf, client_kind), mesh=mesh,
        in_specs=(P(), c1, c1, c1, c1, c1), out_specs=(c1, c1),
        check_rep=False,
    ))
    apply_fn = build_flow_apply(mesh, ccfg)

    obs = _Obs(args, backend="sharded")
    t0 = time.time()
    for rnd in range(args.rounds):
        with span("round", round=rnd):
            idx = np.sort(rng.choice(args.clients, A, replace=False))
            lrs = rng.uniform(5e-3, 2e-2, A).astype(np.float32)
            toks = np.stack([batches_for(int(i), args.steps) for i in idx])

            pad = A_pad - A
            idx_p, sidx, mask = pad_cohort_ids(idx, A_pad, args.clients)
            lrs_p = np.concatenate([lrs, np.zeros(pad, np.float32)])
            toks_p = np.pad(toks, ((0, pad),) + ((0, 0),) * (toks.ndim - 1), mode="edge")
            n_valid = (mask * args.steps).astype(np.int32)
            Ts = (lrs_p * n_valid).astype(np.float32)

            I_a = jax.tree.map(lambda l: l[jnp.asarray(idx_p)], state.I)
            x_new_a, losses = cohort_train(
                state.x_c, I_a, {"tokens": jnp.asarray(toks_p)},
                jnp.asarray(lrs_p), jnp.ones((A_pad,), jnp.float32),
                jnp.asarray(n_valid),
            )
            if not comm.lossless:
                # padded rows ride along (their masked weights discard the
                # result); real rows enter the psum consensus compressed
                x_new_a, _ = comm.compress_endpoints(
                    state.x_c, x_new_a, None, rnd
                )
            x_c, I, dt_last, t, tel = apply_fn(
                state.x_c, state.I, state.g_inv, state.dt_last, state.t,
                x_new_a, jnp.asarray(idx_p), jnp.asarray(sidx), jnp.asarray(mask),
                jnp.asarray(Ts),
            )
            state = state._replace(
                x_c=x_c, I=I, dt_last=dt_last, t=t, round=state.round + 1
            )
            losses, tel = jax.device_get((losses, tel))
            tel = np.asarray(tel)
        loss = float(np.mean(np.asarray(losses)[mask > 0]))
        obs.round(make_record(
            rnd, loss=loss, cohort=A,
            substeps=tel[0], backtracks=tel[1],
            dt_min=tel[2], dt_max=tel[3], dt_sum=tel[4], tau_end=tel[5],
            bytes_up=A * comm.payload_up, bytes_down=A * comm.payload_down,
        ), t0, extra={"devices": n_dev, "padded": A_pad})
    obs.close()
    print("done — sharded cohort training + psum consensus on the clients mesh")


if __name__ == "__main__":
    main()

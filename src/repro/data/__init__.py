from repro.data.pipeline import ClientDataLoader, shard_batch
from repro.data.synthetic import (
    lm_batches,
    make_classification,
    make_lm_stream,
    rotate_scale,
)

__all__ = [
    "make_classification", "make_lm_stream", "lm_batches", "rotate_scale",
    "ClientDataLoader", "shard_batch",
]

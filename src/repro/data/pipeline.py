"""Sharded batching helpers for the distributed runtime."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def shard_batch(batch: Dict[str, np.ndarray], sharding) -> Dict[str, jax.Array]:
    """Device-put a host batch with the given NamedSharding (batch axis)."""
    return {k: jax.device_put(jnp.asarray(v), sharding) for k, v in batch.items()}


class ClientDataLoader:
    """Per-client minibatch iterator over a partition of a host dataset."""

    def __init__(self, data: Dict[str, np.ndarray], idx: np.ndarray, batch_size: int, seed: int = 0):
        self.data = data
        self.idx = idx
        self.bs = batch_size
        self.rng = np.random.RandomState(seed)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            sel = self.rng.choice(self.idx, self.bs, replace=len(self.idx) < self.bs)
            yield {k: jnp.asarray(v[sel]) for k, v in self.data.items()}

    def stacked(self, n_steps: int) -> Dict[str, jnp.ndarray]:
        it = iter(self)
        batches = [next(it) for _ in range(n_steps)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

"""Synthetic, learnable datasets (repro band 2/5: CIFAR is simulated).

``make_classification`` builds a CIFAR-like multi-class problem from a random
teacher MLP: inputs x ~ N(0, I_d); labels = argmax(teacher(x)). A trained
student can reach high accuracy, so federated-method *orderings* (the paper's
claim) are measurable; absolute CIFAR numbers are out of scope on CPU.

``make_lm_stream`` builds deterministic token streams (Zipf unigrams with a
planted bigram structure) for the transformer examples.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def make_classification(
    n_samples: int = 4096,
    dim: int = 32,
    n_classes: int = 10,
    teacher_hidden: int = 64,
    seed: int = 0,
    label_noise: float = 0.0,
) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(seed)
    W1 = rng.normal(size=(dim, teacher_hidden)) / np.sqrt(dim)
    W2 = rng.normal(size=(teacher_hidden, n_classes)) / np.sqrt(teacher_hidden)
    x = rng.normal(size=(n_samples, dim)).astype(np.float32)
    h = np.tanh(x @ W1)
    logits = h @ W2
    y = np.argmax(logits, axis=-1).astype(np.int32)
    if label_noise > 0:
        flip = rng.rand(n_samples) < label_noise
        y[flip] = rng.randint(0, n_classes, flip.sum())
    return {"x": x, "y": y}


def rotate_scale(x: np.ndarray, theta: float, scale: float) -> np.ndarray:
    """s·R(θ)·x on a (m, d) batch: R(θ) rotates each consecutive coordinate
    pair by θ (block-diagonal, orthogonal; an odd final coordinate passes
    through). The per-client covariate-shift primitive of the scenario
    subsystem (repro/scenarios::FeatureShiftSpec) — orthogonality keeps the
    synthetic teacher's decision structure recoverable, so the shift is a
    distribution mismatch rather than label destruction."""
    out = x.copy()
    c, s = np.cos(theta), np.sin(theta)
    d2 = (x.shape[1] // 2) * 2
    a, b = x[:, 0:d2:2], x[:, 1:d2:2]
    out[:, 0:d2:2] = c * a - s * b
    out[:, 1:d2:2] = s * a + c * b
    return (scale * out).astype(x.dtype)


def make_lm_stream(
    n_tokens: int = 1 << 16,
    vocab: int = 512,
    seed: int = 0,
    zipf_a: float = 1.2,
) -> np.ndarray:
    """Zipf unigrams + deterministic planted bigram successor table."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    successor = rng.permutation(vocab)  # planted structure: 70% t -> succ[t]
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.choice(vocab, p=probs)
    u = rng.rand(n_tokens)
    draws = rng.choice(vocab, size=n_tokens, p=probs)
    for t in range(1, n_tokens):
        toks[t] = successor[toks[t - 1]] if u[t] < 0.7 else draws[t]
    return toks


def lm_batches(stream: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Yield {"tokens": (B, S)} windows forever."""
    rng = np.random.RandomState(seed)
    n = len(stream) - seq - 1
    while True:
        starts = rng.randint(0, n, size=batch)
        yield {"tokens": np.stack([stream[s : s + seq] for s in starts])}

from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    apply_updates,
    cosine_schedule,
    momentum,
    sgd,
)

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "adamw",
    "apply_updates", "cosine_schedule",
]

"""Minimal functional optimizer library (no optax in this container).

Optimizer = (init(params) -> state, update(grads, state, params) ->
(updates, state)). ``apply_updates`` adds updates to params. Used by client
local training and by the centralized train driver in launch/train.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _tree_zeros(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return _tree_zeros(params)

    def update(grads, state, params=None):
        m = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(
                lambda v, g: -lr * (beta * v + g.astype(jnp.float32)), m, grads
            )
        else:
            upd = jax.tree.map(lambda v: -lr * v, m)
        return upd, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return AdamState(_tree_zeros(params), _tree_zeros(params), jnp.zeros((), jnp.int32))

    def update(grads, state: AdamState, params=None):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def u(m, v, p):
            upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd - lr * weight_decay * p.astype(jnp.float32)
            return upd

        if weight_decay:
            upds = jax.tree.map(u, mu, nu, params)
        else:
            upds = jax.tree.map(lambda m, v: u(m, v, None), mu, nu)
        return upds, AdamState(mu, nu, count)

    return Optimizer(init, update)


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr

"""Stochastic quantization compressors as registry plugins: int8, int4.

Wire format per client: one fp32 per-row scale + one b-bit signed integer
per parameter, so payload = ⌈d·b/8⌉ + 4 bytes — 8-bit lands just above a
quarter of the fp32 wire (d + 4 vs 4d), 4-bit at an eighth. The round-trip
q(x) = clip(⌊x/s + u⌋, ±Q)·s is unbiased (E[q] = x) under the U[0,1)
stochastic-rounding noise, and the error-feedback residual rows absorb the
per-round variance (comm/base.py), which is what keeps the accuracy-vs-
bytes frontier flat down to int4 in BENCH_comm.json.

Both levels of aggressiveness are separate registry entries (not levels of
one plugin) because they are separate wire formats; the in-plugin ``levels``
ladder is the top-k sparsifier's (comm/topk.py).
"""
from __future__ import annotations

import jax

from repro.comm.base import FP32_BYTES, Compressor
from repro.comm.kernels.quantize import (
    quant_scale,
    stoch_quant_call,
    stoch_quant_ref,
)


class StochasticQuantizer(Compressor):
    """Shared round-trip for the fixed-point family; subclasses pin the
    bit-width. ``supports_flow`` stays True: quantization perturbs every
    coordinate a little instead of zeroing most of them, so the Γ-windowed
    consensus endpoints tolerate it (unlike top-k sparsification)."""

    bits: int = 8

    @property
    def q_max(self) -> float:
        # symmetric signed range: b bits hold [−(2^(b−1)−1), 2^(b−1)−1]
        return float(2 ** (self.bits - 1) - 1)

    def payload_bytes(self, d: int) -> int:
        return -(-int(d) * self.bits // 8) + FP32_BYTES  # ceil + row scale

    def roundtrip(self, rows, key):
        from repro.kernels.ops import _interpret

        u = jax.random.uniform(key, rows.shape, rows.dtype)
        return stoch_quant_call(
            rows, u, quant_scale(rows, self.q_max), self.q_max,
            interpret=_interpret(),
        )

    def ref_roundtrip(self, rows, key):
        """The numpy oracle on the same noise draw (tests/test_comm.py)."""
        import numpy as np

        u = np.asarray(jax.random.uniform(key, rows.shape))
        scale = np.max(np.abs(np.asarray(rows)), axis=-1) / self.q_max
        return stoch_quant_ref(rows, u, scale, self.q_max)


class Int8Stochastic(StochasticQuantizer):
    name = "int8"
    bits = 8


class Int4Stochastic(StochasticQuantizer):
    name = "int4"
    bits = 4

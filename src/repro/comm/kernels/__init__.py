"""Pallas kernels for the client→server wire (repro.comm).

quantize.py — int8/int4 stochastic quantize-dequantize round-trip
topk.py     — top-k magnitude sparsification mask

Both follow the kernels/batch_agg.py idiom (grid over D tiles, cohort axis
resident per tile, CPU interpret mode as the correctness target) and are
elementwise per client row — the property that makes them psum-compatible
device-local calls under the sharded backends (DESIGN.md §11).
"""
from repro.comm.kernels.quantize import (
    quant_scale,
    stoch_quant_call,
    stoch_quant_ref,
)
from repro.comm.kernels.topk import (
    topk_mask_call,
    topk_mask_ref,
    topk_threshold,
)

__all__ = [
    "quant_scale", "stoch_quant_call", "stoch_quant_ref",
    "topk_mask_call", "topk_mask_ref", "topk_threshold",
]

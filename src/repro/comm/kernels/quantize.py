"""Stochastic fixed-point quantization kernels (Pallas TPU).

The client→server wire for a quantizing compressor carries, per client, one
fp32 scale plus one b-bit integer per parameter; the server immediately
dequantizes before aggregating. This module implements the *simulated
round-trip* q(x) = clip(⌊x/s + u⌋, −Q, Q)·s with per-client-row absmax
scales s = max|x|/Q and u ~ U[0,1) stochastic-rounding noise (E[q(x)] = x,
the unbiasedness error-feedback relies on).

Engineering shape mirrors kernels/batch_agg.py: grid over D tiles with the
whole cohort axis resident per tile, full-array BlockSpecs for the (A,)
scale vector, CPU interpret mode as the correctness target. The uniform
noise is drawn OUTSIDE the kernel with ``jax.random`` and passed in as an
(A, D) operand — the TPU-native in-kernel PRNG (pltpu.prng_random_bits) has
no interpret-mode contract on this container, and an explicit operand keeps
the kernel bitwise reproducible against the numpy reference below.

The round-trip is elementwise per client row, which is exactly what makes
it psum-compatible: each shard of the sharded backends quantizes its local
cohort rows device-side and the existing psum reductions aggregate the
dequantized values unchanged (DESIGN.md §11).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_D = 1024

# guard for all-zero rows: scale 0 would divide out to inf; the clamped
# scale sends them through q = ⌊u⌋ = 0 → out 0 (bitwise what the raw row was)
_EPS = 1e-12


def _stoch_quant_kernel(scale_ref, x_ref, u_ref, out_ref, *, q_max: float):
    s = jnp.maximum(scale_ref[:], _EPS)[:, None]
    q = jnp.clip(jnp.floor(x_ref[:, :] / s + u_ref[:, :]), -q_max, q_max)
    out_ref[:, :] = q * s


def stoch_quant_call(
    x, u, scale, q_max: float, *, interpret: bool = True, tile_d: int = TILE_D
):
    """Quantize-dequantize round-trip: out (A, D) = clip(⌊x/s + u⌋, ±Q)·s.

    x, u (A, D); scale (A,) per-row absmax/Q. Caller guarantees
    D % tile_d == 0 (comm/base.py ravels through kernels/ops.py padding).
    """
    A, D = x.shape
    assert D % tile_d == 0, (D, tile_d)
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    tile = pl.BlockSpec((A, tile_d), lambda i: (0, i))
    return pl.pallas_call(
        partial(_stoch_quant_kernel, q_max=float(q_max)),
        grid=(D // tile_d,),
        in_specs=[full((A,)), tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((A, D), jnp.float32),
        interpret=interpret,
    )(scale, x, u)


def quant_scale(x, q_max: float):
    """Per-row quantization scale s_a = max_d |x[a, d]| / Q, shape (A,)."""
    return jnp.max(jnp.abs(x), axis=-1) / float(q_max)


def stoch_quant_ref(x, u, scale, q_max: float) -> np.ndarray:
    """Numpy oracle for ``stoch_quant_call`` (same clamped-scale formula, so
    tests assert bitwise-level agreement in interpret mode)."""
    x = np.asarray(x, np.float32)
    s = np.maximum(np.asarray(scale, np.float32), np.float32(_EPS))[:, None]
    q = np.clip(
        np.floor(x / s + np.asarray(u, np.float32)),
        -np.float32(q_max), np.float32(q_max),
    )
    return (q * s).astype(np.float32)

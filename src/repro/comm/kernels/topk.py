"""Top-k sparsification kernel (Pallas TPU).

The top-k wire carries, per client, k (value, index) pairs — the k
largest-magnitude entries of the update delta; everything else is dropped
on the client and reconstructed as zero on the server. The simulated
round-trip is a per-row magnitude threshold mask: out = x·1[|x| ≥ t_a]
with t_a the k-th largest |x[a, :]| (computed outside the kernel with
``jax.lax.top_k`` — a D-length sort per row is host-of-kernel work, the
masked select is the bandwidth-bound part the kernel fuses).

Ties at the threshold all survive (the mask is ≥, not a strict count), so
the kept set can exceed k by the tie multiplicity; the bytes accounting
(comm/base.py) charges the nominal k. Deterministic — no rounding noise —
so the sharded device-local call matches the dense call exactly.

Blocking mirrors kernels/batch_agg.py: grid over D tiles, cohort axis
resident, (A,) threshold vector as a full-array operand, interpret mode on
CPU validated against the numpy reference in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_D = 1024


def _topk_mask_kernel(thr_ref, x_ref, out_ref):
    t = thr_ref[:][:, None]
    x = x_ref[:, :]
    out_ref[:, :] = jnp.where(jnp.abs(x) >= t, x, 0.0)


def topk_mask_call(x, thr, *, interpret: bool = True, tile_d: int = TILE_D):
    """out (A, D) = x masked to entries with |x| >= thr_a (per-row).

    Caller guarantees D % tile_d == 0 (comm/base.py ravels through the
    kernels/ops.py padding helpers).
    """
    A, D = x.shape
    assert D % tile_d == 0, (D, tile_d)
    full = lambda s: pl.BlockSpec(s, lambda i: (0,) * len(s))
    tile = pl.BlockSpec((A, tile_d), lambda i: (0, i))
    return pl.pallas_call(
        _topk_mask_kernel,
        grid=(D // tile_d,),
        in_specs=[full((A,)), tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((A, D), jnp.float32),
        interpret=interpret,
    )(thr, x)


def topk_threshold(x, k: int):
    """(A,) k-th largest |x[a, :]| per row. ``k`` is a static python int
    clamped to [1, D]; an all-zero row yields threshold 0 (every entry
    survives the ≥ mask bitwise — they are all zeros anyway)."""
    D = x.shape[-1]
    k = int(min(max(1, k), D))
    vals = jax.lax.top_k(jnp.abs(x), k)[0]
    return vals[..., -1]


def topk_mask_ref(x, thr) -> np.ndarray:
    """Numpy oracle for ``topk_mask_call``."""
    x = np.asarray(x, np.float32)
    t = np.asarray(thr, np.float32)[:, None]
    return np.where(np.abs(x) >= t, x, np.float32(0.0)).astype(np.float32)

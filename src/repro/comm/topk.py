"""Top-k sparsification compressor as a registry plugin.

Wire format per client: k (fp32 value, int32 index) pairs — 8 bytes per
kept coordinate, nothing for the dropped ones. The ``levels`` ladder maps
to kept fractions (level 1 = 25% … level 4 = 1%), ordered so higher level
⇒ strictly fewer bytes (the BENCH_comm.json monotonicity witness).

Error feedback is what makes aggressive sparsification converge at all:
a dropped coordinate's value moves into the residual row and re-enters the
next round's delta, so every coordinate is eventually transmitted.

``supports_flow`` is False: a FedECADO consensus endpoint is a point on a
client's continuous trajectory, and zeroing 75–99% of its delta hands the
BE solve a Γ window that no longer interpolates that trajectory — the
config layer refuses the combo with an actionable error instead of
producing quietly wrong dynamics (comm/__init__.py::check_algorithm).
"""
from __future__ import annotations

from typing import ClassVar, Dict

from repro.comm.base import Compressor
from repro.comm.kernels.topk import (
    topk_mask_call,
    topk_mask_ref,
    topk_threshold,
)

# level -> kept fraction of coordinates (ordered: higher level, fewer bytes)
TOPK_FRACTIONS: Dict[int, float] = {1: 0.25, 2: 0.10, 3: 0.05, 4: 0.01}


class TopK(Compressor):
    name = "topk"
    supports_flow: ClassVar[bool] = False
    levels = tuple(sorted(TOPK_FRACTIONS))
    default_level = 2

    @property
    def fraction(self) -> float:
        return TOPK_FRACTIONS[self.level]

    def _k(self, d: int) -> int:
        return max(1, -(-int(d) * int(self.fraction * 10_000) // 10_000))

    def payload_bytes(self, d: int) -> int:
        return 8 * self._k(d)  # fp32 value + int32 index per kept coord

    def roundtrip(self, rows, key):
        from repro.kernels.ops import _interpret

        # ``rows`` arrives zero-padded to the kernel tile, so k here is
        # quoted against the padded width (marginally ≥ the nominal k the
        # bytes accounting charges); padded columns can never displace a
        # real coordinate from the top-k (|0| wins no contest)
        thr = topk_threshold(rows, self._k(rows.shape[-1]))
        return topk_mask_call(rows, thr, interpret=_interpret())

    def ref_roundtrip(self, rows, key):
        """Numpy oracle on the same threshold rule (tests/test_comm.py)."""
        import numpy as np

        x = np.asarray(rows, np.float32)
        k = self._k(x.shape[-1])
        thr = np.sort(np.abs(x), axis=-1)[:, -k]
        return topk_mask_ref(x, thr)

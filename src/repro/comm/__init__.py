"""Compressor plugin registry — the comm mirror of fed/algorithms.

``@register`` a ``Compressor`` subclass and it is immediately reachable
from ``FedSimConfig.compress``, the ``--compress``/``--compress-level``
CLI flags (launch/fedrun.py, launch/sweep.py), the comm bench
(benchmarks/run.py --only comm) and the kernel/equivalence test
parametrizations — with zero edits anywhere else.

``make_comm_spec`` is the one construction path every entry point shares:
it resolves the name (None ⇒ the lossless identity wire, so bytes
accounting is ALWAYS on), validates the level against the plugin's
ladder, sizes the payloads from the model, and refuses
compressor × algorithm combos the capability flags forbid.
"""
from __future__ import annotations

from typing import Optional, Tuple, Type

from repro.comm.base import (
    FP32_BYTES,
    Compressor,
    CommSpec,
    Identity,
    tree_dim,
)

_REGISTRY = {}


def register(cls: Type[Compressor]) -> Type[Compressor]:
    """Class decorator: add a ``Compressor`` subclass to the registry."""
    name = getattr(cls, "name", None)
    if not name or name == "base":
        raise ValueError(
            f"{cls.__name__} must define a unique class-level `name` "
            "(got {name!r})"
        )
    if name in _REGISTRY and _REGISTRY[name] is not cls:
        raise ValueError(
            f"compressor name {name!r} already registered by "
            f"{_REGISTRY[name].__name__}"
        )
    _REGISTRY[name] = cls
    return cls


def available_compressors() -> Tuple[str, ...]:
    """Registered compressor names, registration order."""
    return tuple(_REGISTRY)


def get_compressor(name: str) -> Type[Compressor]:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown compressor {name!r}; registered: {list(_REGISTRY)}"
        )
    return _REGISTRY[name]


def check_algorithm(comp_name: str, alg_cls) -> None:
    """Refuse compressor × algorithm combos the capability flags forbid —
    the registry-level guard behind the CLI ``choices=`` validation."""
    cls = get_compressor(comp_name)
    if alg_cls.has_flow_dynamics and not cls.supports_flow:
        raise ValueError(
            f"compressor {comp_name!r} does not support flow-dynamics "
            f"algorithms (algorithm {alg_cls.name!r} declares "
            "has_flow_dynamics): sparsifying a Backward-Euler consensus "
            "endpoint breaks its Γ-window semantics. Use a quantizer "
            "(int8/int4) or identity, or an averaging-family algorithm."
        )


def make_comm_spec(
    compress: Optional[str],
    level: Optional[int],
    params,
    *,
    seed: int = 0,
    alg_cls=None,
) -> CommSpec:
    """The shared CommSpec construction path. ``compress=None`` means the
    plain uncompressed wire — modeled as the lossless identity compressor
    so every run gets exact bytes accounting."""
    name = compress or "identity"
    if alg_cls is not None:
        check_algorithm(name, alg_cls)
    comp = get_compressor(name)(level)
    return CommSpec(comp=comp, d_model=tree_dim(params), seed=int(seed))


# --- built-ins -------------------------------------------------------------
from repro.comm.quantize import Int4Stochastic, Int8Stochastic  # noqa: E402
from repro.comm.topk import TopK  # noqa: E402

register(Identity)
register(Int8Stochastic)
register(Int4Stochastic)
register(TopK)

__all__ = [
    "FP32_BYTES", "CommSpec", "Compressor", "Identity",
    "available_compressors", "check_algorithm", "get_compressor",
    "make_comm_spec", "register", "tree_dim",
]

"""``Compressor`` protocol + ``CommSpec``: the model of the client→server wire.

A compressor, to this codebase, is three things:

  1. a **lossy round-trip** ``roundtrip(rows, key) -> rows`` on raveled
     stacked update deltas (A, D) — compress-then-decompress fused, because
     the server decompresses immediately before aggregating. It MUST be
     elementwise per client row: the sharded backends call it device-local
     on their cohort shard before the existing psum reductions
     (``batch_agg_psum`` / the BE Schur sums), so a row's compressed value
     may depend only on that row;
  2. **bytes accounting** — ``payload_bytes(d)``, the exact bytes one
     client ships for a d-parameter update (values + scales/indices), the
     basis of the ``bytes_up`` telemetry column;
  3. **capability flags** the config layer queries instead of
     string-matching names: ``lossless`` (the identity/no-compression
     contract — endpoints pass through BITWISE untouched, no arithmetic),
     ``uses_error_feedback`` (per-client residual rows accumulate the
     compression error, averaging family only) and ``supports_flow``
     (whether the round-trip is safe for the flow family's Γ-windowed
     consensus endpoints — top-k is not: zeroing most of a BE endpoint
     delta breaks the window semantics, so the combo is refused loudly).

``CommSpec`` binds a compressor instance to a concrete model (d_model raw
fp32 parameters) and seed, precomputes the per-client payload sizes, and
owns the one composition every backend shares::

    raw  = (x_new − x_ref) + e          # e: error-feedback residual rows
    c    = roundtrip(raw, key(round))
    e'   = raw − c                      # what the wire dropped, kept local
    x'   = x_ref + c                    # the server's reconstructed endpoint

Registration mirrors fed/algorithms/__init__.py (same decorator/registry
pattern); built-ins live in comm/quantize.py and comm/topk.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

FP32_BYTES = 4


class Compressor:
    """Base protocol. Subclass, set ``name`` + flags + ``levels``, implement
    ``roundtrip``/``payload_bytes``, and decorate with ``@register``
    (comm/__init__.py). ``level`` indexes the compressor's own ordered
    aggressiveness ladder — higher level, fewer bytes (the monotonicity
    witness BENCH_comm.json pins)."""

    name: ClassVar[str] = "base"
    lossless: ClassVar[bool] = False
    uses_error_feedback: ClassVar[bool] = True
    supports_flow: ClassVar[bool] = True
    levels: ClassVar[Tuple[int, ...]] = (0,)
    default_level: ClassVar[int] = 0

    def __init__(self, level: Optional[int] = None):
        self.level = self.default_level if level is None else int(level)
        if self.level not in self.levels:
            raise ValueError(
                f"compressor {self.name!r} has no level {level!r}; "
                f"valid levels: {list(self.levels)}"
            )

    # ------------------------------------------------------------------
    def payload_bytes(self, d: int) -> int:
        """Exact bytes one client uploads for a d-parameter update."""
        raise NotImplementedError

    def roundtrip(self, rows: jax.Array, key: jax.Array) -> jax.Array:
        """Lossy compress-decompress of raveled stacked deltas (A, D),
        elementwise per row; ``key`` drives any stochastic rounding."""
        raise NotImplementedError


class Identity(Compressor):
    """The uncompressed fp32 wire: full byte accounting, zero arithmetic.

    ``lossless`` is the contract the equivalence pins rely on
    (tests/test_backend_equiv.py): the comm layer short-circuits BEFORE any
    delta/rebase arithmetic, so ``--compress identity`` is bitwise
    identical to no ``--compress`` at all on every backend — a floating
    point round-trip ``x_ref + (x − x_ref)`` would NOT be."""

    name = "identity"
    lossless = True
    uses_error_feedback = False

    def payload_bytes(self, d: int) -> int:
        return FP32_BYTES * int(d)

    def roundtrip(self, rows, key):
        return rows


def tree_dim(tree: Pytree) -> int:
    """Raw fp32 parameter count of a model pytree (padding excluded) — the
    d every bytes formula is quoted against."""
    return int(sum(int(jnp.size(l)) for l in jax.tree.leaves(tree)))


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """A compressor bound to a model: the object the backends close over.

    Frozen + hashable via ``cache_key`` so the jit-cache keys of the
    segment builders (sim/sharded.py, sim/events.py) can include it."""

    comp: Compressor
    d_model: int
    seed: int = 0

    @property
    def lossless(self) -> bool:
        return bool(self.comp.lossless)

    @property
    def error_feedback(self) -> bool:
        return bool(self.comp.uses_error_feedback) and not self.lossless

    @property
    def payload_up(self) -> int:
        """Bytes one client ships per absorbed endpoint (compressed)."""
        return int(self.comp.payload_bytes(self.d_model))

    @property
    def payload_down(self) -> int:
        """Bytes the server broadcasts per dispatched client: the full
        fp32 model (compression is an uplink affair — the broadcast anchor
        must be exact for Γ and the proximal pulls)."""
        return FP32_BYTES * int(self.d_model)

    def cache_key(self) -> Tuple:
        return (self.comp.name, self.comp.level, self.d_model, self.seed)

    # ------------------------------------------------------------------
    def roundtrip(self, tree: Pytree, rnd) -> Pytree:
        """Lossy round-trip of a stacked delta pytree (leaves (A, ...)),
        raveled through the shared (A, D)+tile-padding helpers. ``rnd``
        (python int or traced int scalar) folds into the stochastic-
        rounding key so every round draws fresh noise deterministically."""
        from repro.kernels.ops import ravel_stacked, unravel_stacked

        flat, meta = ravel_stacked(tree)
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed),
            jnp.asarray(rnd, jnp.uint32),
        )
        return unravel_stacked(self.comp.roundtrip(flat, key), meta)

    def compress_endpoints(
        self,
        x_ref: Pytree,
        x_new_a: Pytree,
        ef_rows: Optional[Pytree],
        rnd,
    ) -> Tuple[Pytree, Optional[Pytree]]:
        """THE shared composition: compress cohort endpoints against the
        broadcast reference, with optional error-feedback residual rows.

        Returns ``(x_new_a', ef_rows')`` — the server-reconstructed
        endpoints and the updated residuals (None in, None out). Lossless
        compressors return both inputs untouched (bitwise, no arithmetic).
        Elementwise per cohort row, so it runs identically in the dense
        per-round paths and device-local inside shard_map segments."""
        if self.lossless:
            return x_new_a, ef_rows
        raw = jax.tree.map(
            lambda xa, xc: xa.astype(jnp.float32)
            - xc.astype(jnp.float32)[None],
            x_new_a, x_ref,
        )
        if ef_rows is not None:
            raw = jax.tree.map(jnp.add, raw, ef_rows)
        c = self.roundtrip(raw, rnd)
        ef_new = (
            jax.tree.map(jnp.subtract, raw, c)
            if ef_rows is not None else None
        )
        x_new = jax.tree.map(
            lambda xc, d: xc.astype(jnp.float32)[None] + d, x_ref, c
        )
        return x_new, ef_new

    # -- error-feedback residual state (algorithm-owned rows) --------------
    def init_ef_state(self, params: Pytree, n: int) -> Pytree:
        """Fresh per-client residual rows, leaves (n, ...): zeros — the
        same layout as WeightedDeltaAlgorithm.init_client_state, and
        threaded through the backends by the same gather/one-hot-scatter
        machinery (DESIGN.md §11)."""
        return jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params
        )

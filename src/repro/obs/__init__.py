"""repro.obs — the telemetry layer: one per-round counter schema shared by
all four execution backends (device half), plus JSONL run logs, Chrome
trace spans, a structured run history and the shared round-line formatter
(host half). See DESIGN.md §9."""
from .format import format_bytes, format_counters, format_round_line
from .history import RunHistory
from .runlog import (
    RUNLOG_SCHEMA_VERSION,
    RunLog,
    environment_stamp,
    jsonable,
    validate_jsonl,
    validate_record,
)
from .telemetry import (
    N_STALE_BUCKETS,
    RECORD_FIELDS,
    STALE_BUCKET_EDGES,
    TELEMETRY_FIELDS,
    field_index,
    make_record,
    pack_row,
    rows_to_records,
    stale_histogram,
    summarize_records,
)
from .trace import TraceRecorder, span, validate_trace

__all__ = [
    "N_STALE_BUCKETS",
    "RECORD_FIELDS",
    "RUNLOG_SCHEMA_VERSION",
    "RunHistory",
    "RunLog",
    "STALE_BUCKET_EDGES",
    "TELEMETRY_FIELDS",
    "TraceRecorder",
    "environment_stamp",
    "field_index",
    "format_bytes",
    "format_counters",
    "format_round_line",
    "jsonable",
    "make_record",
    "pack_row",
    "rows_to_records",
    "span",
    "stale_histogram",
    "summarize_records",
    "validate_jsonl",
    "validate_record",
    "validate_trace",
]

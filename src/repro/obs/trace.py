"""Chrome-trace span emitter — ``span()`` wraps host-side phases of a run
(plan drawing, segment dispatch, gain refresh, eval) and ``TraceRecorder``
writes the collected spans as Chrome-trace / Perfetto JSON
(``{"traceEvents": [...]}`` with ``ph: "X"`` complete events, microsecond
``ts``/``dur``). Load the file in ``chrome://tracing`` or ui.perfetto.dev.

When no recorder is installed, ``span()`` is a cheap no-op so telemetry
call sites never pay for tracing they didn't ask for. Spans also wrap
``jax.profiler.TraceAnnotation`` so they show up inside a device profile
when one is being captured.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

_state = threading.local()


def _current() -> Optional["TraceRecorder"]:
    return getattr(_state, "recorder", None)


class TraceRecorder:
    """Collects spans in memory; ``save()`` (or context-manager exit)
    writes the Chrome-trace JSON. Install as the ambient recorder with
    ``recorder.install()`` / ``recorder.uninstall()`` or by using it as a
    context manager — ``span()`` calls anywhere on the thread then record
    into it."""

    def __init__(self, path: str):
        self.path = str(path)
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()

    def add_event(
        self, name: str, start_s: float, dur_s: float,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        ev: Dict[str, Any] = {
            "name": name,
            "ph": "X",
            "ts": round((start_s - self._t0) * 1e6, 3),
            "dur": round(dur_s * 1e6, 3),
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFF,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def save(self) -> None:
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"traceEvents": self.events}, f)

    def install(self) -> None:
        _state.recorder = self

    def uninstall(self) -> None:
        if _current() is self:
            _state.recorder = None

    def __enter__(self) -> "TraceRecorder":
        self.install()
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()
        self.save()


@contextmanager
def span(name: str, **args: Any):
    """Trace the enclosed block. No-op (micro-cheap) when no recorder is
    installed; otherwise records a complete event and nests inside an
    active jax profiler capture via ``TraceAnnotation``."""
    rec = _current()
    if rec is None:
        yield
        return
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:  # pragma: no cover - ancient jax
        TraceAnnotation = None
    t0 = time.perf_counter()
    try:
        if TraceAnnotation is not None:
            with TraceAnnotation(name):
                yield
        else:
            yield
    finally:
        rec.add_event(name, t0, time.perf_counter() - t0,
                      args=args or None)


def validate_trace(path: str) -> List[Dict[str, Any]]:
    """Parse + validate a Chrome-trace file; returns the events."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: missing traceEvents list")
    for ev in events:
        for key in ("name", "ph", "ts", "dur", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"{path}: event missing {key!r}: {ev}")
        if ev["ph"] != "X":
            raise ValueError(f"{path}: only complete events expected")
        if ev["dur"] < 0 or ev["ts"] < 0:
            raise ValueError(f"{path}: negative ts/dur in {ev}")
    return events

"""Structured run history returned by ``FedSim.run``.

Replaces the old loosely-shaped dict (``{"round": [...], "loss": [...],
"metrics": [(round, dict), ...]}``) whose ``metrics`` entries were tuples
while ``loss`` was a flat list. ``RunHistory`` keeps the aligned per-round
series flat (``rounds``/``loss``/``telemetry``), splits eval results into
two aligned lists (``eval_rounds``/``metrics``), and carries the exact
per-client participation counts accumulated by the backends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from .telemetry import summarize_records


@dataclass
class RunHistory:
    """Per-round series are index-aligned: ``loss[i]`` and ``telemetry[i]``
    belong to ``rounds[i]``. ``metrics[j]`` belongs to ``eval_rounds[j]``.
    ``participation[c]`` counts how many rounds client ``c`` was actually
    dispatched (exact — padding and dropped/busy re-draws never count)."""

    rounds: List[int] = field(default_factory=list)
    loss: List[float] = field(default_factory=list)
    eval_rounds: List[int] = field(default_factory=list)
    metrics: List[Dict[str, Any]] = field(default_factory=list)
    telemetry: List[Dict[str, Any]] = field(default_factory=list)
    participation: Optional[np.ndarray] = None

    def summary(self) -> Dict[str, Any]:
        """Run-level telemetry aggregate (see ``summarize_records``), plus
        the participation spread when the backend reported it."""
        out = summarize_records(self.telemetry)
        if self.participation is not None:
            p = np.asarray(self.participation)
            out["participation"] = {
                "min": int(p.min()), "max": int(p.max()),
                "mean": float(p.mean()),
            }
        return out

    def __len__(self) -> int:
        return len(self.rounds)

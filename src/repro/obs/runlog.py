"""Structured JSONL run logs — the host half of ``repro.obs``.

A run log is a JSON-Lines file with three record kinds, discriminated by
``"kind"``:

  * one ``"run"`` header — schema version, run config, git SHA, jax
    version, device topology, wall-clock timestamp;
  * one ``"round"`` record per round — the shared telemetry record
    (telemetry.RECORD_FIELDS) plus optional eval ``"metrics"``;
  * one ``"summary"`` trailer — ``summarize_records`` over the round
    records (plus participation spread).

``validate_record``/``validate_jsonl`` pin the schema: tests/test_obs.py
and the CI smoke cell both call them, and CI uploads the emitted files as
workflow artifacts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, Iterable, List, Optional

from .telemetry import N_STALE_BUCKETS, RECORD_FIELDS

RUNLOG_SCHEMA_VERSION = 1

_KINDS = ("run", "round", "summary")


def jsonable(obj: Any) -> Any:
    """Best-effort conversion of run configs (nested dataclasses, numpy
    scalars/arrays, tuples) into plain JSON values; unknown objects fall
    back to ``str()`` so a log header can never fail to serialize."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {
            f.name: jsonable(getattr(obj, f.name))
            for f in dataclasses.fields(obj)
        }
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [jsonable(v) for v in obj]
    if hasattr(obj, "item") and callable(obj.item):
        try:
            return obj.item()
        except (TypeError, ValueError):
            pass
    if hasattr(obj, "tolist") and callable(obj.tolist):
        try:
            return jsonable(obj.tolist())
        except (TypeError, ValueError):
            pass
    return str(obj)


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def environment_stamp() -> Dict[str, Any]:
    """git SHA + jax version + device topology, for the run header."""
    stamp: Dict[str, Any] = {"git_sha": _git_sha()}
    try:
        import jax

        stamp["jax_version"] = jax.__version__
        devs = jax.devices()
        stamp["n_devices"] = len(devs)
        stamp["platform"] = devs[0].platform if devs else "unknown"
    except Exception:  # pragma: no cover - jax import failure
        stamp["jax_version"] = "unavailable"
        stamp["n_devices"] = 0
        stamp["platform"] = "unknown"
    return stamp


class RunLog:
    """Append-oriented JSONL sink. Construct with a path (parent dirs are
    created), write the header once via ``start``, then one ``round`` per
    round and a final ``summary``; ``close`` flushes and releases the file
    handle. Usable as a context manager."""

    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "w")
        self._started = False

    def _emit(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def start(self, config: Any = None, **extra: Any) -> None:
        header = {
            "kind": "run",
            "schema_version": RUNLOG_SCHEMA_VERSION,
            "timestamp": time.time(),
            **environment_stamp(),
            "config": jsonable(config),
        }
        header.update({k: jsonable(v) for k, v in extra.items()})
        self._started = True
        self._emit(header)

    def round(
        self,
        record: Dict[str, Any],
        metrics: Optional[Dict[str, Any]] = None,
    ) -> None:
        rec = {"kind": "round", **jsonable(record)}
        if metrics is not None:
            rec["metrics"] = jsonable(metrics)
        self._emit(rec)

    def summary(self, summary: Dict[str, Any]) -> None:
        self._emit({"kind": "summary", **jsonable(summary)})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def validate_record(rec: Dict[str, Any]) -> None:
    """Raise ValueError unless ``rec`` is a schema-valid run-log record."""
    kind = rec.get("kind")
    if kind not in _KINDS:
        raise ValueError(f"unknown record kind {kind!r}")
    if kind == "run":
        for key in ("schema_version", "git_sha", "jax_version",
                    "n_devices", "platform", "timestamp", "config"):
            if key not in rec:
                raise ValueError(f"run header missing {key!r}")
        if rec["schema_version"] != RUNLOG_SCHEMA_VERSION:
            raise ValueError(
                f"schema_version {rec['schema_version']} != "
                f"{RUNLOG_SCHEMA_VERSION}"
            )
    elif kind == "round":
        missing = [k for k in RECORD_FIELDS if k not in rec]
        if missing:
            raise ValueError(f"round record missing {missing}")
        if not isinstance(rec["round"], int):
            raise ValueError("round stamp must be an int")
        hist = rec["stale_hist"]
        if not (isinstance(hist, list) and len(hist) == N_STALE_BUCKETS):
            raise ValueError(
                f"stale_hist must be a {N_STALE_BUCKETS}-list, got {hist!r}"
            )
        for key in ("cohort", "dropped", "substeps", "backtracks",
                    "waves", "arrived", "stale", "bytes_up", "bytes_down"):
            if not isinstance(rec[key], int):
                raise ValueError(f"counter {key!r} must be an int")
    else:  # summary
        if "rounds" not in rec:
            raise ValueError("summary record missing 'rounds'")


def validate_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse + validate a run-log file. Requires exactly one ``run`` header
    (first line) and at least one ``round`` record; returns the records."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: invalid JSON: {e}")
            validate_record(rec)
            records.append(rec)
    if not records or records[0]["kind"] != "run":
        raise ValueError(f"{path}: first record must be the run header")
    if sum(1 for r in records if r["kind"] == "run") != 1:
        raise ValueError(f"{path}: exactly one run header expected")
    if not any(r["kind"] == "round" for r in records):
        raise ValueError(f"{path}: no round records")
    return records

"""The shared per-round telemetry schema — device half of ``repro.obs``.

FedECADO's claims are dynamical-system claims (adaptive Δt, LTE-driven BE
iteration counts, wave activation, straggler staleness), so every execution
backend reports the SAME typed per-round counters instead of the historical
split (event backend: an opaque ``(R, 8)`` sync; everything else: loss
only). The schema has two representations:

  * a **device row** — a ``(len(TELEMETRY_FIELDS),)`` float32 vector packed
    by ``pack_row`` inside a backend's jit segment (fori_loop carries an
    ``(R, F)`` output it fills one row per round, optionally extended with
    ``N_STALE_BUCKETS`` staleness-histogram columns), synced to the host
    together with the segment's existing single transfer — telemetry never
    adds a sync point to a jit-resident segment;
  * a **host record** — the per-round dict produced by ``make_record`` /
    ``rows_to_records`` with integral counters as python ints, ``dt_mean``
    derived from ``dt_sum``/``substeps``, and the staleness histogram as a
    ``N_STALE_BUCKETS``-list. ``RECORD_FIELDS`` pins the dict's key set
    (tests/test_obs.py); the JSONL run log (runlog.py), ``FedSim`` history,
    the sweep/bench summaries and the shared round-line formatter all
    consume records.

Counter semantics (exact-vs-padded rules in DESIGN.md §9): ``cohort`` is
the number of clients actually dispatched (mask-summed under padding, so
padding rows never count), ``dropped`` the busy re-draws masked out by the
event backend, ``substeps``/``backtracks`` the Algorithm-1 adaptive-BE
solver iterations / LTE rejections, ``dt_*`` the accepted step sizes,
``waves``/``arrived``/``stale``/``horizon``/``tau_end`` the multi-rate
event counters (zero / cohort-sized on synchronous backends).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

TELEMETRY_FIELDS = (
    "loss",        # per-round cohort loss (nan on all-busy event rounds)
    "cohort",      # clients dispatched this round (mask-summed: exact)
    "dropped",     # busy re-draws masked out of the plan (event backend)
    "substeps",    # adaptive-BE solver iterations (Algorithm 1)
    "backtracks",  # LTE step rejections inside those iterations
    "dt_min",      # smallest accepted BE step (0 when substeps == 0)
    "dt_max",      # largest accepted BE step
    "dt_sum",      # Σ accepted steps (host derives dt_mean; internal field)
    "waves",       # event waves that integrated > 0 time
    "arrived",     # flights absorbed (== cohort on synchronous backends)
    "stale",       # flights left pending past the round horizon
    "horizon",     # event round horizon W (quantile of in-flight windows)
    "tau_end",     # centrally integrated time this round
    "bytes_up",    # client→server bytes this round (Σ absorbed payloads)
    "bytes_down",  # server→client bytes this round (full fp32 broadcast)
)

# staleness histogram: bucket b counts pending flights whose stale_rounds
# lies in [edge_b, next_edge) — [1], [2,3], [4,7], [8+). A fresh flight has
# stale_rounds >= 1 by the time the histogram is taken (post-increment).
STALE_BUCKET_EDGES = (1, 2, 4, 8)
N_STALE_BUCKETS = len(STALE_BUCKET_EDGES)

_F = {name: i for i, name in enumerate(TELEMETRY_FIELDS)}

# integral counters (host records carry them as python ints)
_INT_FIELDS = frozenset(
    ("cohort", "dropped", "substeps", "backtracks", "waves", "arrived",
     "stale", "bytes_up", "bytes_down")
)

# the pinned key set of a host record: every TELEMETRY_FIELDS entry except
# the internal dt_sum, plus the round stamp, the derived dt_mean and the
# staleness histogram
RECORD_FIELDS = tuple(
    ["round"]
    + [f for f in TELEMETRY_FIELDS if f != "dt_sum"]
    + ["dt_mean", "stale_hist"]
)


def field_index(name: str) -> int:
    """Column of ``name`` in a device row (jit-safe: a python int)."""
    return _F[name]


def pack_row(**fields):
    """Pack named telemetry scalars into the canonical device row.

    Used inside jit segments (sim/events.py, sim/sharded.py): every value
    may be a traced scalar; unset fields are zero (``loss`` defaults to
    nan so a backend that fills loss host-side cannot silently report 0).
    Returns a ``(len(TELEMETRY_FIELDS),)`` float32 array.
    """
    import jax.numpy as jnp

    unknown = set(fields) - set(TELEMETRY_FIELDS)
    if unknown:
        raise ValueError(f"unknown telemetry fields {sorted(unknown)}")
    cols = []
    for name in TELEMETRY_FIELDS:
        v = fields.get(name, jnp.nan if name == "loss" else 0.0)
        cols.append(jnp.asarray(v, jnp.float32).reshape(()))
    return jnp.stack(cols)


def stale_histogram(stale_rounds, alive, axis_name: Optional[str] = None):
    """(N_STALE_BUCKETS,) float32 histogram of pending-flight staleness.

    ``stale_rounds`` (C,) int32 post-increment queue ages, ``alive`` (C,)
    the pending mask; psum-reduced over ``axis_name`` when the capacity
    axis is sharded (each shard owns disjoint slots, so the sum is exact).
    """
    import jax.numpy as jnp

    s = stale_rounds.astype(jnp.float32)
    edges = STALE_BUCKET_EDGES + (float("inf"),)
    buckets = [
        jnp.sum(alive * (s >= edges[b]) * (s < edges[b + 1]))
        for b in range(N_STALE_BUCKETS)
    ]
    hist = jnp.stack(buckets)
    if axis_name:
        import jax

        hist = jax.lax.psum(hist, axis_name)
    return hist


def _clean(name: str, v: float):
    if name in _INT_FIELDS:
        return int(v)
    return float(v)


def make_record(
    rnd: int,
    *,
    loss: float,
    cohort: int,
    dropped: int = 0,
    substeps: int = 0,
    backtracks: int = 0,
    dt_min: float = 0.0,
    dt_max: float = 0.0,
    dt_sum: float = 0.0,
    waves: int = 0,
    arrived: Optional[int] = None,
    stale: int = 0,
    horizon: float = 0.0,
    tau_end: float = 0.0,
    bytes_up: int = 0,
    bytes_down: int = 0,
    stale_hist: Optional[Sequence[int]] = None,
) -> Dict[str, Any]:
    """Host-side record constructor (the dense per-round backends and the
    averaging segment build records directly; jit segments go through
    ``rows_to_records``). ``arrived`` defaults to ``cohort`` — on a
    synchronous backend every dispatched client is absorbed in-round."""
    n_sub = int(substeps)
    rec: Dict[str, Any] = {"round": int(rnd), "loss": float(loss)}
    vals = dict(
        cohort=cohort, dropped=dropped, substeps=n_sub,
        backtracks=backtracks,
        dt_min=dt_min if n_sub else 0.0, dt_max=dt_max,
        waves=waves,
        arrived=cohort if arrived is None else arrived,
        stale=stale, horizon=horizon, tau_end=tau_end,
        bytes_up=bytes_up, bytes_down=bytes_down,
    )
    for name, v in vals.items():
        rec[name] = _clean(name, v)
    rec["dt_mean"] = float(dt_sum) / n_sub if n_sub else 0.0
    rec["stale_hist"] = (
        [0] * N_STALE_BUCKETS if stale_hist is None
        else [int(b) for b in stale_hist]
    )
    assert set(rec) == set(RECORD_FIELDS)
    return rec


def rows_to_records(rnd0: int, rows, hists=None) -> List[Dict[str, Any]]:
    """Synced ``(R, F)`` device rows (+ optional ``(R, B)`` staleness
    histograms) -> per-round host records, stamped ``rnd0 + r``."""
    recs = []
    for r, row in enumerate(rows):
        kw = {name: row[_F[name]] for name in TELEMETRY_FIELDS}
        recs.append(make_record(
            rnd0 + r,
            stale_hist=None if hists is None else hists[r],
            **kw,
        ))
    return recs


def summarize_records(records: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Run-level aggregation of per-round records: per-round means for the
    rate-like counters, totals for the event counters, and the accepted-Δt
    envelope. Consumed by ``RunHistory.summary()``, the sweep's per-cell
    telemetry block and the engine-bench columns."""
    n = len(records)
    if n == 0:
        return {"rounds": 0}

    def mean(key):
        return float(sum(r[key] for r in records)) / n

    finite = [r["loss"] for r in records if math.isfinite(r["loss"])]
    dt_mins = [r["dt_min"] for r in records if r["substeps"]]
    subs = sum(r["substeps"] for r in records)
    dt_sum = sum(r["dt_mean"] * r["substeps"] for r in records)
    hist = [0] * N_STALE_BUCKETS
    for r in records:
        for b, v in enumerate(r["stale_hist"]):
            hist[b] += int(v)
    return {
        "rounds": n,
        "mean_loss": float(sum(finite)) / len(finite) if finite else float("nan"),
        "substeps_per_round": mean("substeps"),
        "backtracks_per_round": mean("backtracks"),
        "waves_per_round": mean("waves"),
        "cohort_per_round": mean("cohort"),
        "dropped": int(sum(r["dropped"] for r in records)),
        "arrived": int(sum(r["arrived"] for r in records)),
        "stale": int(sum(r["stale"] for r in records)),
        "bytes_up": int(sum(r["bytes_up"] for r in records)),
        "bytes_down": int(sum(r["bytes_down"] for r in records)),
        "dt_min": float(min(dt_mins)) if dt_mins else 0.0,
        "dt_max": float(max(r["dt_max"] for r in records)),
        "dt_mean": float(dt_sum) / subs if subs else 0.0,
        "stale_hist": hist,
    }

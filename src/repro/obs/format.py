"""The single source of truth for human-readable round lines.

``launch/fedrun.py`` (all three backends), ``launch/sweep.py`` and
``examples/heterogeneous_clients.py`` previously each hand-rolled their
own per-round f-string; they now all render telemetry records through
``format_round_line`` so the field set and formatting cannot diverge.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional


def format_bytes(n: int) -> str:
    """Human-scale byte count: 812B, 14.2KB, 3.1MB, 1.2GB."""
    n = float(n)
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024.0 or unit == "GB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024.0
    return f"{n:.1f}GB"  # pragma: no cover - unreachable


def _num(v: float) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    if not math.isfinite(v):
        return "nan"
    if v == 0:
        return "0"
    if abs(v) >= 100:
        return f"{v:.1f}"
    if abs(v) >= 0.01:
        return f"{v:.4f}"
    return f"{v:.2e}"


def format_round_line(
    rec: Dict[str, Any],
    wall_s: Optional[float] = None,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """One per-round status line from a shared telemetry record.

    Always shows ``round``/``loss``/``substeps``; adds the cohort size,
    the async counter group (arrived/stale/waves/dropped) whenever the
    round was asynchronous (waves active, flights pending, or busy drops),
    the ``extra`` dict as trailing ``key value`` pairs, and the wall time.
    """
    parts = [
        f"round {rec['round']:>3d}",
        f"loss {_num(rec['loss'])}",
        f"substeps {rec.get('substeps', 0)}",
    ]
    if rec.get("backtracks"):
        parts.append(f"backtracks {rec['backtracks']}")
    if rec.get("cohort"):
        parts.append(f"cohort {rec['cohort']}")
    if rec.get("waves") or rec.get("stale") or rec.get("dropped"):
        parts.append(
            f"arrived {rec.get('arrived', 0)} stale {rec.get('stale', 0)} "
            f"waves {rec.get('waves', 0)} dropped {rec.get('dropped', 0)}"
        )
    if rec.get("bytes_up"):
        parts.append(
            f"up {format_bytes(rec['bytes_up'])} "
            f"down {format_bytes(rec.get('bytes_down', 0))}"
        )
    for key, v in (extra or {}).items():
        parts.append(f"{key} {_num(v) if isinstance(v, (int, float)) else v}")
    line = "  ".join(parts)
    if wall_s is not None:
        line += f"  ({wall_s:.2f}s)"
    return line


def format_counters(summary: Dict[str, Any]) -> str:
    """Compact ``k=v`` suffix from a run-level telemetry summary — used by
    the sweep runner's per-cell progress lines."""
    if not summary or not summary.get("rounds"):
        return ""
    parts = [f"substeps/r={summary['substeps_per_round']:.1f}"]
    if summary.get("waves_per_round"):
        parts.append(f"waves/r={summary['waves_per_round']:.1f}")
    if summary.get("stale"):
        parts.append(f"stale={summary['stale']}")
    if summary.get("dropped"):
        parts.append(f"dropped={summary['dropped']}")
    if summary.get("bytes_up"):
        parts.append(f"up={format_bytes(summary['bytes_up'])}")
    return " ".join(parts)

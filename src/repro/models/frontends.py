"""Stub modality frontends — the ONE sanctioned carve-out (see task spec).

For [vlm] and [audio] architectures the modality encoder (VQ image tokenizer /
mel+conv feature extractor) is NOT implemented; instead these helpers produce
the embeddings it would emit, with the right shapes/dtypes, so the language
backbone consumes exactly what it would in production.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

# chameleon: fraction of the sequence that is VQ image tokens in a mixed batch
VLM_IMAGE_TOKENS = 1024          # one 32x32 VQ grid
WHISPER_ENC_FRAMES = 1500        # 30 s of audio at 50 Hz post-conv


def batch_spec(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a full-sequence (train/prefill) batch."""
    spec: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if cfg.frontend == "vq_image":
        n_img = min(VLM_IMAGE_TOKENS, seq_len)
        spec["image_embeds"] = jax.ShapeDtypeStruct((batch, n_img, cfg.d_model), dtype)
        spec["image_positions"] = jax.ShapeDtypeStruct((batch, n_img), jnp.int32)
    elif cfg.frontend == "audio_conv":
        spec["frames"] = jax.ShapeDtypeStruct((batch, WHISPER_ENC_FRAMES, cfg.d_model), dtype)
    return spec


def make_batch(key, cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Concrete random batch matching ``batch_spec`` (smoke tests/examples)."""
    k1, k2, k3 = jax.random.split(key, 3)
    out: Dict[str, jax.Array] = {
        "tokens": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.frontend == "vq_image":
        n_img = min(VLM_IMAGE_TOKENS, seq_len)
        out["image_embeds"] = jax.random.normal(k2, (batch, n_img, cfg.d_model), dtype) * 0.02
        out["image_positions"] = jnp.tile(jnp.arange(n_img, dtype=jnp.int32)[None], (batch, 1))
    elif cfg.frontend == "audio_conv":
        enc_len = min(WHISPER_ENC_FRAMES, 64 if seq_len <= 128 else WHISPER_ENC_FRAMES)
        out["frames"] = jax.random.normal(k3, (batch, enc_len, cfg.d_model), dtype) * 0.02
    return out

"""Activation-sharding policy context.

The launcher sets the residual-stream PartitionSpec before lowering; the
transformer stack applies ``with_sharding_constraint`` at block boundaries so
the SPMD partitioner cannot silently re-shard the batch axis (observed: FSDP
batch sharding over ("data","model") degraded back to 16-way without pins —
EXPERIMENTS.md §Perf iteration 3).
"""
from __future__ import annotations

from typing import Optional

import jax

_ACT_SPEC: Optional[object] = None  # PartitionSpec for (B, S, d) activations
_MOE_BUFFER_SPEC: Optional[object] = None  # PartitionSpec for (E, C, d)
# (mesh, axis_name) for expert-local shard_map MoE dispatch (H2), or None
_MOE_SHARD: Optional[tuple] = None
# (virtual_heads, PartitionSpec for (B,S,H,dh)) — zero-pad awkward head
# counts so the O(S^2) attention einsums shard on the model axis (H4), or None
_HEAD_PAD: Optional[tuple] = None


def set_head_pad(pad) -> None:
    global _HEAD_PAD
    _HEAD_PAD = pad


def get_head_pad():
    return _HEAD_PAD


def set_moe_shard(mesh_and_axis) -> None:
    global _MOE_SHARD
    _MOE_SHARD = mesh_and_axis


def get_moe_shard():
    return _MOE_SHARD


def set_activation_spec(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def get_activation_spec():
    return _ACT_SPEC


def set_moe_buffer_spec(spec) -> None:
    global _MOE_BUFFER_SPEC
    _MOE_BUFFER_SPEC = spec


def constrain(x: jax.Array) -> jax.Array:
    """Apply the active constraint to a (B, S, d) activation, if any."""
    if _ACT_SPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SPEC)


def constrain_moe_buffer(buf: jax.Array) -> jax.Array:
    """Pin the (E, C, d) MoE dispatch buffer to the expert-parallel layout
    (H2 hillclimb: without the pin the SPMD partitioner all-gathers the full
    token activations to every model rank per MoE layer)."""
    if _MOE_BUFFER_SPEC is None:
        return buf
    return jax.lax.with_sharding_constraint(buf, _MOE_BUFFER_SPEC)

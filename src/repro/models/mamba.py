"""Selective SSM (Mamba) block for the Jamba hybrid architecture.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel's
fuse-and-recompute trick becomes (a) a chunked ``lax.scan`` over time with
``jax.checkpoint`` per chunk so the O(S * inner * d_state) state history is
never materialized for the backward pass, and (b) a single-step state update
for decode (O(1) memory -> native long_500k support).

State per layer: conv ring (B, inner, conv_width-1) + SSM state (B, inner, N).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig

CHUNK = 256  # time chunk for remat


def _dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    s = cfg.ssm or SSMConfig()
    inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return inner, dt_rank, s.state_dim


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    inner, dt_rank, N = _dims(cfg)
    ks = jax.random.split(key, 7)
    si = 1.0 / math.sqrt(d)
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * inner)) * si).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, inner)) * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((inner,), dtype),
        "w_x_dbc": (jax.random.normal(ks[2], (inner, dt_rank + 2 * N)) * (1.0 / math.sqrt(inner))).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (dt_rank, inner)) * (1.0 / math.sqrt(dt_rank))).astype(dtype),
        "dt_bias": jnp.full((inner,), -4.6, dtype),   # softplus^-1(0.01)
        # A stored as log of negated diagonal: A = -exp(a_log), (inner, N)
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (inner, 1))
        ).astype(jnp.float32),
        "d_skip": jnp.ones((inner,), jnp.float32),
        "w_out": (jax.random.normal(ks[4], (inner, d)) * (1.0 / math.sqrt(inner))).astype(dtype),
    }


def _ssm_inputs(p: dict, u: jax.Array, cfg: ArchConfig):
    """u: (B, S, inner) post-conv activations -> dt, B_t, C_t (fp32)."""
    _, dt_rank, N = _dims(cfg)
    dbc = u @ p["w_x_dbc"]
    dt_low, B_t, C_t = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_low @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                       # (B,S,inner)
    return dt, B_t.astype(jnp.float32), C_t.astype(jnp.float32)


def _scan_chunk(a_log, d_skip, dt, B_t, C_t, u, h0):
    """Sequential scan over one time chunk. Shapes: dt,u (B,c,inner);
    B_t,C_t (B,c,N); h0 (B,inner,N). Returns (y (B,c,inner), h)."""
    A = -jnp.exp(a_log)                                    # (inner, N)

    def step(h, xs):
        dt_t, B_tt, C_tt, u_t = xs                         # (B,inner),(B,N),(B,N),(B,inner)
        dA = jnp.exp(dt_t[..., None] * A)                  # (B,inner,N)
        dBu = dt_t[..., None] * B_tt[:, None, :] * u_t[..., None]
        h = h * dA + dBu
        y = jnp.einsum("bin,bn->bi", h, C_tt) + d_skip * u_t
        return h, y

    xs = (
        dt.transpose(1, 0, 2),
        B_t.transpose(1, 0, 2),
        C_t.transpose(1, 0, 2),
        u.transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h


def apply_mamba(
    p: dict, x: jax.Array, cfg: ArchConfig, return_state: bool = False,
    impl: str = "scan",
):
    """Training/prefill forward, full sequence. x: (B, S, d) -> (B, S, d).
    With ``return_state``: also return the decode cache after position S-1.

    ``impl="kernel"`` uses the Pallas VMEM-resident selective scan
    (kernels/ssm_scan.py) for the recurrence — the TPU deployment path
    (inference/no-grad; the chunked-remat scan below remains the
    differentiable default). Both match to fp32 round-off (tests)."""
    s = cfg.ssm or SSMConfig()
    B, S, d = x.shape
    inner, _, N = _dims(cfg)
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,S,inner) each

    # causal depthwise conv
    pad = s.conv_width - 1
    up = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    u_conv = sum(
        up[:, i : i + S] * p["conv_w"][i] for i in range(s.conv_width)
    ) + p["conv_b"]
    u_conv = jax.nn.silu(u_conv)

    dt, B_t, C_t = _ssm_inputs(p, u_conv, cfg)
    uf = u_conv.astype(jnp.float32)

    if impl == "kernel":
        from repro.kernels.ssm_scan import ssm_scan_call

        h0 = jnp.zeros((B, inner, N), jnp.float32)
        y, h_final = ssm_scan_call(
            dt, B_t, C_t, uf, p["a_log"], p["d_skip"], h0,
            interpret=jax.default_backend() != "tpu",
            tile_i=min(128, inner),
        )
        y = y.astype(x.dtype) * jax.nn.silu(z)
        out = y @ p["w_out"]
        if return_state:
            conv_state = u[:, S - (s.conv_width - 1):, :].astype(x.dtype)
            return out, {"conv": conv_state, "ssm": h_final}
        return out

    # chunked scan with remat: never materialize (S, B, inner, N)
    c = min(CHUNK, S)
    pad_t = (-S) % c
    if pad_t:
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
        B_t = jnp.pad(B_t, ((0, 0), (0, pad_t), (0, 0)))
        C_t = jnp.pad(C_t, ((0, 0), (0, pad_t), (0, 0)))
        uf = jnp.pad(uf, ((0, 0), (0, pad_t), (0, 0)))
    n_chunks = (S + pad_t) // c

    def chunk_body(h, xs):
        dt_c, B_c, C_c, u_c = xs
        y, h = jax.checkpoint(_scan_chunk, static_argnums=())(
            p["a_log"], p["d_skip"], dt_c, B_c, C_c, u_c, h
        )
        return h, y

    def split_chunks(t):  # (B, S, f) -> (n_chunks, B, c, f)
        return t.reshape(B, n_chunks, c, t.shape[-1]).transpose(1, 0, 2, 3)

    h0 = jnp.zeros((B, inner, N), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_body, h0, (split_chunks(dt), split_chunks(B_t), split_chunks(C_t), split_chunks(uf))
    )
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * c, inner)[:, :S]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["w_out"]
    if return_state:
        # padded tail steps have dt=0 -> exp(0·A)=1, dBu=0: h_final is exact
        conv_state = u[:, S - (s.conv_width - 1):, :].astype(x.dtype)
        return out, {"conv": conv_state, "ssm": h_final}
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    s = cfg.ssm or SSMConfig()
    inner, _, N = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, inner), dtype),
        "ssm": jnp.zeros((batch, inner, N), jnp.float32),
    }


def decode_mamba(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig) -> tuple:
    """One-token decode. x: (B, 1, d) -> (y (B, 1, d), new cache)."""
    s = cfg.ssm or SSMConfig()
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,1,inner)
    window = jnp.concatenate([cache["conv"], u], axis=1)   # (B,cw,inner)
    u_conv = jnp.einsum("bci,ci->bi", window, p["conv_w"]) + p["conv_b"]
    u_conv = jax.nn.silu(u_conv)[:, None]                  # (B,1,inner)

    dt, B_t, C_t = _ssm_inputs(p, u_conv, cfg)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)
    dBu = dt[:, 0, :, None] * B_t[:, 0, None, :] * u_conv[:, 0, :, None].astype(jnp.float32)
    h = cache["ssm"] * dA + dBu
    y = jnp.einsum("bin,bn->bi", h, C_t[:, 0]) + p["d_skip"] * u_conv[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    new_cache = {"conv": window[:, 1:], "ssm": h}
    return y @ p["w_out"], new_cache

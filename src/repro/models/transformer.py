"""Composable decoder stack builder.

A model is a sequence of ``num_layers`` blocks whose kinds follow
``cfg.layer_pattern`` (tiled). Parameters for position ``j`` in the pattern
are STACKED across pattern periods (leading axis ``n_periods``) and the stack
is applied with ``jax.lax.scan`` over periods — one HLO body regardless of
depth, which keeps lowering tractable for the 48-layer full-size configs.

Block kinds: "attn" (attention + FFN), "mamba" (SSM + FFN), "mlstm"/"slstm"
(xLSTM cells, self-contained FFN). Decoder blocks grow a cross-attention
sub-layer when ``cfg.encoder_layers > 0`` (whisper).

Entry points: ``init_params``, ``forward`` (train/prefill full-sequence),
``loss_fn``, ``init_cache`` + ``decode_step`` (single-token serve).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import policy as policy_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    attention_forward,
    decode_attention,
    embed_tokens,
    init_attention,
    init_embed,
    init_mlp,
    init_norm,
    lm_logits,
    sinusoidal_pos_emb,
)
from repro.models.moe import apply_moe, init_moe

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------


def pattern_info(cfg: ArchConfig):
    pat = cfg.layer_pattern
    P = len(pat)
    if cfg.num_layers % P:
        raise ValueError(
            f"{cfg.name}: num_layers={cfg.num_layers} not divisible by "
            f"pattern length {P}"
        )
    n_periods = cfg.num_layers // P
    moe_flags = cfg.moe_layers()[:P]  # parity is period-invariant (P even or moe 'all')
    return pat, P, n_periods, moe_flags


def is_local_layer(cfg: ArchConfig, j: int) -> bool:
    """Does pattern position j use the sliding window?"""
    a = cfg.attention
    if a is None or not a.sliding_window:
        return False
    if a.alternate_local_global:
        return j % 2 == 0
    return True  # uniform SWA (mixtral, jamba long-context mode)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, kind: str, use_moe: bool, cross: bool, dtype) -> Params:
    ks = iter(jax.random.split(key, 8))
    p: Params = {"norm1": init_norm(cfg, dtype)}
    if kind == "attn":
        p["attn"] = init_attention(next(ks), cfg, dtype)
        p["norm2"] = init_norm(cfg, dtype)
        p["ffn"] = (
            init_moe(next(ks), cfg, dtype) if use_moe else init_mlp(next(ks), cfg, cfg.d_ff, dtype)
        )
        if cross:
            p["cross_norm"] = init_norm(cfg, dtype)
            p["cross_attn"] = init_attention(next(ks), cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba(next(ks), cfg, dtype)
        p["norm2"] = init_norm(cfg, dtype)
        p["ffn"] = (
            init_moe(next(ks), cfg, dtype) if use_moe else init_mlp(next(ks), cfg, cfg.d_ff, dtype)
        )
    elif kind == "mlstm":
        p["cell"] = xlstm_mod.init_mlstm(next(ks), cfg, dtype)
    elif kind == "slstm":
        p["cell"] = xlstm_mod.init_slstm(next(ks), cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    pat, P, n_periods, moe_flags = pattern_info(cfg)
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)
    cross = cfg.encoder_layers > 0

    blocks = []
    for j in range(P):
        keys = jax.random.split(jax.random.fold_in(k_blocks, j), n_periods)
        per = [
            _init_block(keys[r], cfg, pat[j], moe_flags[j], cross, dtype)
            for r in range(n_periods)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))

    params: Params = {
        "embed": init_embed(k_embed, cfg, dtype),
        "blocks": blocks,
        "final_norm": init_norm(cfg, dtype),
    }
    if cross:
        keys = jax.random.split(k_enc, cfg.encoder_layers)
        enc = [_init_block(keys[r], cfg, "attn", False, False, dtype) for r in range(cfg.encoder_layers)]
        params["enc_blocks"] = [jax.tree.map(lambda *xs: jnp.stack(xs), *enc)]
        params["enc_norm"] = init_norm(cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_block(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    kind: str,
    use_moe: bool,
    j: int,
    *,
    positions: jax.Array,
    causal: bool,
    cross_kv: Optional[tuple] = None,
):
    """One block, full sequence. Returns (x, aux_loss)."""
    x = policy_mod.constrain(x)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("mlstm", "slstm"):
        h = apply_norm(p["norm1"], x, cfg)
        cell = xlstm_mod.apply_mlstm if kind == "mlstm" else xlstm_mod.apply_slstm
        return x + cell(p["cell"], h, cfg), aux

    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        h = attention_forward(
            p["attn"], h, cfg, positions=positions, causal=causal,
            is_local=is_local_layer(cfg, j),
        )
    else:  # mamba
        h = mamba_mod.apply_mamba(p["mamba"], h, cfg)
    x = x + h

    if cross_kv is not None and "cross_attn" in p:
        h = apply_norm(p["cross_norm"], x, cfg)
        h = attention_forward(
            p["cross_attn"], h, cfg, positions=positions, causal=False,
            kv_override=cross_kv,
        )
        x = x + h

    h = apply_norm(p["norm2"], x, cfg)
    if use_moe:
        h, aux = apply_moe(p["ffn"], h, cfg)
    else:
        h = apply_mlp(p["ffn"], h, cfg)
    return x + h, aux


def _apply_stack(
    blocks,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions,
    causal: bool,
    cross_kv=None,
    pattern=None,
    moe_flags=None,
    remat: bool = True,
):
    pat = pattern if pattern is not None else pattern_info(cfg)[0]
    flags = moe_flags if moe_flags is not None else pattern_info(cfg)[3]

    def period(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for j, p in enumerate(period_params):
            x, a = _apply_block(
                p, x, cfg, pat[j], flags[j], j,
                positions=positions, causal=causal, cross_kv=cross_kv,
            )
            aux = aux + a
        return x, aux

    body = jax.checkpoint(period) if remat else period

    def scan_body(carry, period_params):
        x, aux = carry
        x, a = body(x, period_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), tuple(blocks))
    return x, aux


def _encode(params: Params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, T_enc, d)."""
    T = frames.shape[1]
    pos = jnp.arange(T)
    x = frames + sinusoidal_pos_emb(pos, cfg.d_model, frames.dtype)
    x, _ = _apply_stack(
        params["enc_blocks"], x, cfg, positions=pos, causal=False,
        pattern=("attn",), moe_flags=(False,),
    )
    return apply_norm(params["enc_norm"], x, cfg)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    """Full-sequence forward. Returns (logits fp32 (B,S,V), aux_loss).

    batch: {"tokens": (B,S)} plus modality extras:
      vlm:   "image_embeds" (B,S_img,d), "image_positions" (B,S_img) int32
      audio: "frames" (B,T_enc,d)
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)

    if cfg.frontend == "vq_image" and "image_embeds" in batch:
        # early fusion: splice precomputed patch/VQ embeddings into the stream
        bidx = jnp.arange(B)[:, None]
        x = x.at[bidx, batch["image_positions"]].set(
            batch["image_embeds"].astype(x.dtype)
        )

    positions = jnp.arange(S)
    cross_kv = None
    if cfg.encoder_layers:
        x = x + sinusoidal_pos_emb(positions, cfg.d_model, x.dtype)
        enc = _encode(params, batch["frames"], cfg)
        # precompute is per-block inside attention (kv_override projects there)
        cross_kv = enc

    if cross_kv is not None:
        x, aux = _apply_stack_cross(params, x, cfg, positions, cross_kv)
    else:
        x, aux = _apply_stack(params["blocks"], x, cfg, positions=positions, causal=True)

    x = apply_norm(params["final_norm"], x, cfg)
    return lm_logits(params["embed"], x, cfg), aux


def _apply_stack_cross(params, x, cfg, positions, enc):
    """Decoder stack with cross-attention: K/V projected per block from enc."""
    pat, P, n_periods, moe_flags = pattern_info(cfg)

    def period(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for j, p in enumerate(period_params):
            kc = jnp.einsum("btd,dhk->bthk", enc, p["cross_attn"]["wk"])
            vc = jnp.einsum("btd,dhk->bthk", enc, p["cross_attn"]["wv"])
            x, a = _apply_block(
                p, x, cfg, pat[j], moe_flags[j], j,
                positions=positions, causal=True, cross_kv=(kc, vc, None),
            )
            aux = aux + a
        return x, aux

    body = jax.checkpoint(period)

    def scan_body(carry, pp):
        x, aux = carry
        x, a = body(x, pp)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), tuple(params["blocks"])
    )
    return x, aux


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig):
    """Next-token cross-entropy (+ MoE aux). Returns scalar fp32."""
    logits, aux = forward(params, batch, cfg)
    targets = batch.get("labels")
    auto_shift = targets is None
    if auto_shift:
        targets = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if "loss_mask" in batch:
        mask = batch["loss_mask"].astype(ll.dtype)
    elif auto_shift:  # exclude the (padded) final position
        S = ll.shape[1]
        mask = (jnp.arange(S) < S - 1).astype(ll.dtype)[None, :] * jnp.ones_like(ll)
    else:
        mask = jnp.ones_like(ll)
    ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux


# ---------------------------------------------------------------------------
# Prefill (inference: full sequence -> last logits + decode cache)
# ---------------------------------------------------------------------------


def _to_cache_layout(k: jax.Array, W: int) -> jax.Array:
    """Fit (B, S, H, dh) prefill K/V into a width-W cache (pad or ring-roll)."""
    S = k.shape[1]
    if W >= S:
        return jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
    last = k[:, S - W:]
    return jnp.roll(last, shift=(S - W) % W, axis=1)


def prefill_step(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ArchConfig,
    max_len: Optional[int] = None,
    long_mode: bool = False,
):
    """Inference prefill: run the full prompt, return (last-token logits
    (B, V) fp32, decode cache ready for ``decode_step`` at pos=S)."""
    pat, P, n_periods, moe_flags = pattern_info(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.frontend == "vq_image" and "image_embeds" in batch:
        bidx = jnp.arange(B)[:, None]
        x = x.at[bidx, batch["image_positions"]].set(batch["image_embeds"].astype(x.dtype))
    positions = jnp.arange(S)

    cross_cache = None
    enc = None
    if cfg.encoder_layers:
        x = x + sinusoidal_pos_emb(positions, cfg.d_model, x.dtype)
        enc = _encode(params, batch["frames"], cfg)
        cross_cache = build_cross_cache(params, enc, cfg)

    def period(carry, xs):
        x = carry
        if cross_cache is not None:
            period_params, cross_j = xs
        else:
            (period_params,) = xs
            cross_j = None
        caches = []
        for j in range(P):
            p = period_params[j]
            kind = pat[j]
            h = apply_norm(p["norm1"], x, cfg)
            if kind == "attn":
                y, (k, v) = attention_forward(
                    p["attn"], h, cfg, positions=positions, causal=True,
                    is_local=is_local_layer(cfg, j), return_kv=True,
                )
                W = cache_window(cfg, j, max_len, long_mode)
                cj = {"k": _to_cache_layout(k, W), "v": _to_cache_layout(v, W)}
            elif kind == "mamba":
                y, cj = mamba_mod.apply_mamba(p["mamba"], h, cfg, return_state=True)
            elif kind == "mlstm":
                y, cj = xlstm_mod.apply_mlstm(p["cell"], h, cfg, return_state=True)
            else:
                y, cj = xlstm_mod.apply_slstm(p["cell"], h, cfg, return_state=True)
            x = x + y
            if kind in ("mlstm", "slstm"):
                caches.append(cj)
                continue
            if cross_j is not None and "cross_attn" in p:
                hh = apply_norm(p["cross_norm"], x, cfg)
                hh = attention_forward(
                    p["cross_attn"], hh, cfg, positions=positions, causal=False,
                    kv_override=(cross_j["k"], cross_j["v"], None),
                )
                x = x + hh
            h2 = apply_norm(p["norm2"], x, cfg)
            if moe_flags[j]:
                h2, _ = apply_moe(p["ffn"], h2, cfg)
            else:
                h2 = apply_mlp(p["ffn"], h2, cfg)
            x = x + h2
            caches.append(cj)
        return x, tuple(caches)

    xs = (tuple(params["blocks"]),)
    if cross_cache is not None:
        xs = xs + (cross_cache,)
    x, block_caches = jax.lax.scan(period, x, xs)
    x = apply_norm(params["final_norm"], x[:, -1:], cfg)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    cache: Dict[str, Any] = {"blocks": list(block_caches)}
    if cross_cache is not None:
        cache["cross"] = cross_cache
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


LONG_MODE_WINDOW = 4096  # cap for full-attention layers in "windowed" long serve


def cache_window(cfg: ArchConfig, j: int, max_len: int, long_mode: bool = False) -> int:
    """KV-cache capacity for pattern position j at a given context length."""
    a = cfg.attention
    if a is None:
        return max_len
    if a.sliding_window and is_local_layer(cfg, j):
        return min(a.sliding_window, max_len)
    if long_mode and cfg.long_context == "windowed" and not a.alternate_local_global:
        # e.g. jamba long-context deployment: cap attention layers (DESIGN.md)
        return min(LONG_MODE_WINDOW, max_len)
    return max_len


def init_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    dtype=jnp.float32,
    enc_len: int = 0,
    long_mode: bool = False,
) -> Dict[str, Any]:
    """Build an (empty) decode cache pytree, stacked per pattern position."""
    pat, P, n_periods, _ = pattern_info(cfg)
    a = cfg.attention

    def stacked(make):
        per = [make() for _ in range(n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    blocks = []
    for j, kind in enumerate(pat):
        if kind == "attn":
            W = cache_window(cfg, j, max_len, long_mode)
            c = stacked(lambda W=W: {
                "k": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim), dtype),
            })
        elif kind == "mamba":
            c = stacked(lambda: mamba_mod.init_mamba_cache(cfg, batch, dtype))
        elif kind == "mlstm":
            c = stacked(lambda: xlstm_mod.init_mlstm_cache(cfg, batch))
        else:
            c = stacked(lambda: xlstm_mod.init_slstm_cache(cfg, batch))
        blocks.append(c)
    cache: Dict[str, Any] = {"blocks": blocks}
    if cfg.encoder_layers and enc_len:
        # cross-attention K/V per decoder block (projected once at prefill)
        cache["cross"] = [
            stacked(lambda: {
                "k": jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((batch, enc_len, a.num_kv_heads, a.head_dim), dtype),
            })
            for _ in range(1)
        ][0]
    return cache


def _decode_block(p, cache_j, x, cfg, kind, use_moe, j, pos, max_len, cross_j=None):
    if kind in ("mlstm", "slstm"):
        h = apply_norm(p["norm1"], x, cfg)
        fn = xlstm_mod.decode_mlstm if kind == "mlstm" else xlstm_mod.decode_slstm
        y, new_c = fn(p["cell"], h, cache_j, cfg)
        return x + y, new_c

    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        W = cache_j["k"].shape[1]
        y, nk, nv = decode_attention(
            p["attn"], h, cfg, k_cache=cache_j["k"], v_cache=cache_j["v"],
            pos=pos, is_local=is_local_layer(cfg, j),
            window_cache=W < max_len,
        )
        new_c = {"k": nk, "v": nv}
    else:  # mamba
        y, new_c = mamba_mod.decode_mamba(p["mamba"], h, cache_j, cfg)
    x = x + y

    if cross_j is not None and "cross_attn" in p:
        h = apply_norm(p["cross_norm"], x, cfg)
        a = cfg.attention
        B = x.shape[0]
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"])
        if a.qkv_bias:
            q = q + p["cross_attn"]["bq"]
        Hq, dh = a.num_heads, a.head_dim
        G = Hq // a.num_kv_heads
        qh = q.reshape(B, a.num_kv_heads, G, dh)
        lg = jnp.einsum("bhgk,bshk->bhgs", qh, cross_j["k"]).astype(jnp.float32)
        pr = jax.nn.softmax(lg / math.sqrt(dh), axis=-1)
        o = jnp.einsum("bhgs,bshk->bhgk", pr.astype(cross_j["v"].dtype), cross_j["v"])
        o = o.reshape(B, 1, Hq, dh)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"])

    h = apply_norm(p["norm2"], x, cfg)
    if use_moe:
        h, _ = apply_moe(p["ffn"], h, cfg)
    else:
        h = apply_mlp(p["ffn"], h, cfg)
    return x + h, new_c


def build_cross_cache(params: Params, enc: jax.Array, cfg: ArchConfig):
    """Project encoder output into per-decoder-block cross K/V (whisper)."""

    def project(block_params):
        k = jnp.einsum("btd,dhk->bthk", enc, block_params["cross_attn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", enc, block_params["cross_attn"]["wv"])
        return {"k": k, "v": v}

    # vmap over the stacked period axis of pattern position 0 (whisper P=1)
    return jax.vmap(project)(params["blocks"][0])


def decode_step(
    params: Params,
    cache: Dict[str, Any],
    token: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    max_len: Optional[int] = None,
):
    """One-token serve step. token: (B,) int32; pos: scalar int32 (context
    length so far). ``max_len``: serving context capacity — caches narrower
    than this are treated as ring buffers. Returns (logits (B,V) fp32, cache).
    """
    pat, P, n_periods, moe_flags = pattern_info(cfg)
    if max_len is None:
        widths = [c["k"].shape[2] for c in cache["blocks"] if isinstance(c, dict) and "k" in c]
        max_len = max(widths) if widths else cfg.max_seq_len
    x = embed_tokens(params["embed"], token[:, None], cfg)
    if cfg.encoder_layers:
        x = x + sinusoidal_pos_emb(jnp.asarray(pos)[None], cfg.d_model, x.dtype)

    cross = cache.get("cross")

    def scan_body(x, xs):
        if cross is not None:
            period_params, period_cache, cross_cache = xs
        else:
            period_params, period_cache = xs
            cross_cache = None
        new_cache = []
        for j in range(P):
            p = period_params[j]
            cj = period_cache[j]
            cross_j = cross_cache if (cross_cache is not None and "cross_attn" in p) else None
            x, nc = _decode_block(
                p, cj, x, cfg, pat[j], moe_flags[j], j, pos, max_len, cross_j
            )
            new_cache.append(nc)
        return x, tuple(new_cache)

    xs = (tuple(params["blocks"]), tuple(cache["blocks"]))
    if cross is not None:
        xs = xs + (cross,)
    x, new_blocks = jax.lax.scan(scan_body, x, xs)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)[:, 0]
    new_cache = dict(cache)
    new_cache["blocks"] = list(new_blocks)
    return logits, new_cache

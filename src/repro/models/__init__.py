from repro.models.transformer import (
    build_cross_cache,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)
from repro.models.frontends import batch_spec, make_batch

__all__ = [
    "init_params", "forward", "loss_fn", "init_cache", "decode_step",
    "build_cross_cache", "batch_spec", "make_batch",
]

"""Mixture-of-Experts FFN: top-k token-choice routing with sort-based,
capacity-bounded dispatch (Switch/MaxText style, no (T,E,C) one-hot einsum).

Dispatch pipeline (all jnp, SPMD-friendly):
  router logits -> top-k -> flatten (T*k,) assignments -> argsort by expert ->
  rank-within-expert via bincount/cumsum -> scatter into (E, C, d) buffer ->
  grouped einsum over experts -> gather back -> weighted combine.

FLOPs are ~capacity_factor * top_k * T * d * d_ff * 3 * 2 — the honest active
compute, not the E/top_k dense blowup. The (E, C, d) buffer carries the
expert-parallel sharding; the scatter/gather across the token-sharded /
expert-sharded boundary is where XLA inserts the all-to-all.

Arctic-style ``dense_residual_d_ff`` adds a dense MLP in parallel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import policy as policy_mod
from repro.models.layers import _act, apply_mlp, init_mlp


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    kr, ke1, ke2, ke3, kd = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(m.expert_d_ff)
    p = {
        "router": (jax.random.normal(kr, (d, m.num_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ke1, (m.num_experts, d, m.expert_d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ke2, (m.num_experts, d, m.expert_d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ke3, (m.num_experts, m.expert_d_ff, d)) * s_out).astype(dtype),
    }
    if m.dense_residual_d_ff:
        p["dense"] = init_mlp(kd, cfg, m.dense_residual_d_ff, dtype)
    return p


def capacity(num_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(m.top_k * num_tokens * m.capacity_factor / m.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def apply_moe(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar fp32).

    Two dispatch paths:
      * global (single device / FSDP): sort-based capacity dispatch below.
      * expert-local shard_map (TP meshes, set via models.policy): activations
        are replicated over the "model" axis in the TP layout, so each model
        rank selects the tokens routed to ITS experts locally and the only
        collective is one psum of the (B, S, d) output — replacing the
        full-size (T·k, d) scatter all-reduces XLA emits for the global path
        (349 s -> ~1 s collective on moonshot train_4k; EXPERIMENTS §Perf H2).
    """
    shard = policy_mod.get_moe_shard()
    if shard is not None:
        mesh, axis = shard
        n_ba = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_ba *= mesh.shape[a]
        if x.shape[0] % n_ba == 0:  # long_500k decode (B=1): fall back
            return _apply_moe_shardmap(p, x, cfg, mesh, axis)
    return _apply_moe_global(p, x, cfg)


def _apply_moe_global(p: dict, x: jax.Array, cfg: ArchConfig) -> tuple:
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )                                                           # renormalize

    # --- load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    top1 = expert_ids[:, 0]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_weight

    # --- sort-based dispatch
    e_flat = expert_ids.reshape(-1)                             # (T*k,)
    tok_flat = jnp.repeat(jnp.arange(T), m.top_k)               # token of slot
    w_flat = gate_vals.reshape(-1)

    sort_idx = jnp.argsort(e_flat)                              # stable
    e_sorted = e_flat[sort_idx]
    counts = jnp.bincount(e_flat, length=m.num_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * m.top_k) - starts[e_sorted]           # rank in expert
    keep = rank < C
    dest = jnp.where(keep, e_sorted * C + rank, E_C := m.num_experts * C)

    src_tok = tok_flat[sort_idx]
    buf = jnp.zeros((E_C + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[src_tok] * keep[:, None].astype(x.dtype))
    buf = buf[:E_C].reshape(m.num_experts, C, d)
    buf = policy_mod.constrain_moe_buffer(buf)  # expert-parallel layout pin

    # --- grouped expert FFN
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = _act(cfg.activation)(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])
    out_buf = policy_mod.constrain_moe_buffer(out_buf)

    # --- combine
    out_buf = out_buf.reshape(E_C, d)
    y_sorted = jnp.where(
        keep[:, None], out_buf[jnp.where(keep, dest, 0)], 0.0
    ).astype(jnp.float32)
    w_sorted = w_flat[sort_idx]
    y = jnp.zeros((T, d), jnp.float32)
    y = y.at[src_tok].add(y_sorted * w_sorted[:, None])

    if m.dense_residual_d_ff:
        y = y + apply_mlp(p["dense"], xf, cfg).astype(jnp.float32)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert-local shard_map dispatch (H2)
# ---------------------------------------------------------------------------


def _moe_local_block(p_loc, xf, cfg, e_lo, E_loc, C):
    """Process the tokens routed to this rank's expert slice.

    xf: (T, d) LOCAL batch shard (replicated over the model axis).
    p_loc: router full (d, E); w_* local slices (E_loc, d, f) [or full E with
    a d_ff slice when experts don't divide the axis]. Returns the PARTIAL
    (T, d) output (tokens routed elsewhere contribute 0) and the aux loss.
    """
    m = cfg.moe
    T, d = xf.shape
    logits = xf.astype(jnp.float32) @ p_loc["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    top1 = expert_ids[:, 0]
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), 0)
    aux = m.num_experts * jnp.sum(frac_tokens * jnp.mean(probs, 0)) * m.aux_loss_weight

    e_flat = expert_ids.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), m.top_k)
    w_flat = gate_vals.reshape(-1)
    # map to local expert index; non-local slots -> dump bucket E_loc
    local = (e_flat >= e_lo) & (e_flat < e_lo + E_loc)
    e_loc = jnp.where(local, e_flat - e_lo, E_loc)

    sort_idx = jnp.argsort(e_loc)
    e_sorted = e_loc[sort_idx]
    counts = jnp.bincount(e_loc, length=E_loc + 1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(e_loc.shape[0]) - starts[e_sorted]
    keep = (rank < C) & (e_sorted < E_loc)
    E_C = E_loc * C
    dest = jnp.where(keep, e_sorted * C + rank, E_C)

    src_tok = tok_flat[sort_idx]
    buf = jnp.zeros((E_C + 1, d), xf.dtype)
    buf = buf.at[dest].set(xf[src_tok] * keep[:, None].astype(xf.dtype))
    buf = buf[:E_C].reshape(E_loc, C, d)

    up = jnp.einsum("ecd,edf->ecf", buf, p_loc["w_up"])
    gate = _act(cfg.activation)(jnp.einsum("ecd,edf->ecf", buf, p_loc["w_gate"]))
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, p_loc["w_down"]).reshape(E_C, d)

    y_sorted = jnp.where(keep[:, None], out_buf[jnp.where(keep, dest, 0)], 0.0)
    y = jnp.zeros((T, d), jnp.float32)
    y = y.at[src_tok].add(y_sorted.astype(jnp.float32) * w_flat[sort_idx][:, None])
    return y, aux


def _apply_moe_shardmap(p, x, cfg, mesh, axis):
    m = cfg.moe
    # pin the input to the activation layout so shard_map sees a clean
    # model-axis-replicated operand
    x = policy_mod.constrain(x)
    in_dtype = x.dtype
    # XLA's CPU AllReducePromotion pass crashes ("invalid binary instruction
    # opcode copy") on the bf16 copy-reducer all-reduce shard_map emits at its
    # boundary for bf16 operands; carry the boundary in f32 (converts fuse).
    if x.dtype == jnp.bfloat16:
        x = x.astype(jnp.float32)
    B, S, d = x.shape
    T = B * S
    M = mesh.shape[axis]
    from jax.sharding import PartitionSpec as P

    expert_sharded = m.num_experts % M == 0
    if expert_sharded:
        E_loc = m.num_experts // M
        w_spec = P(axis, None, None)
    else:
        # experts don't divide the axis: shard every expert's d_ff instead;
        # each rank processes ALL experts on its f-slice (partial sums)
        E_loc = m.num_experts
        w_spec = P(None, None, axis)

    p_specs = {
        "router": P(None, None),
        "w_gate": w_spec,
        "w_up": w_spec,
        "w_down": P(axis, None, None) if expert_sharded else P(None, axis, None),
    }
    if "dense" in p:
        p_specs["dense"] = {"w_up": P(None, axis), "w_down": P(axis, None)}
        if "w_gate" in p["dense"]:
            p_specs["dense"]["w_gate"] = P(None, axis)

    # FULL-manual shard_map: with the batch axes left automatic the region
    # sees the GLOBAL token axis and XLA re-partitions the sort/scatter with
    # (T·k, d) data-axis all-reduces — the exact pathology H2 removes.
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_ba = 1
    for a in ba:
        n_ba *= mesh.shape[a]
    # per-expert capacity for a LOCAL batch shard
    C = capacity(max(T // n_ba, 1), cfg)

    def local_fn(p_loc, x_loc):
        xf = x_loc.reshape(-1, d)
        if expert_sharded:
            e_lo = jax.lax.axis_index(axis) * E_loc
        else:
            e_lo = 0
        y, aux = _moe_local_block(p_loc, xf, cfg, e_lo, E_loc, C)
        if "dense" in p_loc:
            y = y + apply_mlp(p_loc["dense"], xf, cfg).astype(jnp.float32)
        y = jax.lax.psum(y, axis)
        # aux: mean over the global batch; psum also hands shard_map an
        # additive replication proof (its copy-reducer all-reduce fallback
        # crashes XLA's CPU AllReducePromotion pass on narrow dtypes)
        aux = jax.lax.psum(aux, ba + (axis,)) / (mesh.shape[axis] * n_ba)
        return y.reshape(x_loc.shape), aux  # fp32 at the boundary

    fn = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(p_specs, P(ba, None, None)),
        out_specs=(P(ba, None, None), P()),
        check_vma=False,
    )
    y, aux = fn(p, x)
    return y.astype(in_dtype), aux

"""xLSTM blocks (mLSTM matrix-memory + sLSTM scalar-memory) [arXiv:2405.04517].

TPU adaptation: recurrences run as chunked ``lax.scan`` over time with
``jax.checkpoint`` per chunk (same policy as mamba.py) so the backward pass
recomputes in-chunk states instead of materializing (S, B, H, dk, dv).

Simplifications vs the paper (recorded in DESIGN.md):
  * sLSTM uses diagonal recurrent gate connections (r ⊙ h_{t-1}) instead of
    full per-head recurrent matrices — keeps the scalar-memory exponential
    gating semantics at O(d) recurrent params.
  * Both blocks use the exp-gating + m-stabilizer formulation.

Decode is a single-step state update: O(1) per token -> native long_500k.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CHUNK = 256


def _dims(cfg: ArchConfig):
    a = cfg.attention  # reused for head geometry (H, head_dim)
    return a.num_heads, a.head_dim


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    H, dh = _dims(cfg)
    qd = H * dh
    ks = jax.random.split(key, 8)
    si = 1.0 / math.sqrt(d)
    return {
        "wq": (jax.random.normal(ks[0], (d, H, dh)) * si).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, H, dh)) * si).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, H, dh)) * si).astype(dtype),
        "w_if": (jax.random.normal(ks[3], (d, H, 2)) * si).astype(jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((H, 1)), jnp.full((H, 1), 3.0)], axis=-1
        ).astype(jnp.float32),                       # forget bias ~ remember
        "wo": (jax.random.normal(ks[4], (H, dh, d)) * (1.0 / math.sqrt(qd))).astype(dtype),
        "w_up": (jax.random.normal(ks[5], (d, 2 * d)) * si).astype(dtype),
        "w_down": (jax.random.normal(ks[6], (2 * d, d)) * (1.0 / math.sqrt(2 * d))).astype(dtype),
    }


def _mlstm_chunk(qc, kc, vc, gc, state):
    """One remat chunk. qc/kc/vc: (B,c,H,dh); gc: (B,c,H,2) raw gate logits.
    state: (C (B,H,dk,dv), n (B,H,dk), m (B,H))."""
    C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        q, k, v, g = xs                                # (B,H,dh)...(B,H,2)
        i_t, f_t = g[..., 0], g[..., 1]
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(logf + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            k[..., :, None] * v[..., None, :]
        )
        n = f_s[..., None] * n + i_s[..., None] * k
        num = jnp.einsum("bhkv,bhk->bhv", C, q)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (qc, kc, vc, gc))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3), (C, n, m)         # (B,c,H,dv)


CHUNKWISE = 64  # chunkwise-parallel block length (matmul form)


def _mlstm_chunkwise_block(qc, kc, vc, gc, state):
    """Chunkwise-parallel mLSTM (EXPERIMENTS.md §Perf hillclimb H1).

    Exact algebraic regrouping of the sequential recurrence: with
    F_t = Σ_{r<=t} log σ(f_r) (in-chunk cumulative forget),
    g_s = i_s − F_s, and stabilizer M_t = max(m0, cummax_{s<=t} g_s):

      C_t ∝ Σ_{s<=t} exp(g_s − M_t)·k_s v_sᵀ + exp(m0 − M_t)·C0
      h_t = [ (q_t·k_s)·exp(g_s − M_t) ]_{s<=t} V + exp(m0 − M_t)·q_t C0

    i.e. a (c, c) masked matmul per chunk — the C matrix is read/written
    once per CHUNKWISE tokens instead of every token (HBM traffic ÷c) and
    the inner products hit the MXU. Matches the sequential scan to fp32
    round-off (tests/test_models.py::test_mlstm_chunkwise_equals_sequential).

    qc/kc/vc: (B, c, H, dh) fp32; gc: (B, c, H, 2) raw gate logits.
    state: (C (B,H,dk,dv), n (B,H,dk), m0 (B,H)).
    """
    C0, n0, m0 = state
    i_t = gc[..., 0]                                   # (B,c,H)
    logf = jax.nn.log_sigmoid(gc[..., 1])
    F = jnp.cumsum(logf, axis=1)                       # (B,c,H)
    g = i_t - F
    M = jnp.maximum(
        jax.lax.cummax(g, axis=1), m0[:, None, :]
    )                                                  # (B,c,H) = M_t
    w_s = g                                            # log source weights
    # intra-chunk: S[t,s] = (q_t·k_s)·exp(g_s − M_t), s <= t
    qk = jnp.einsum("bthk,bshk->bhts", qc, kc)         # (B,H,c,c)
    c_len = qc.shape[1]
    causal = jnp.tril(jnp.ones((c_len, c_len), bool))
    lw = w_s.transpose(0, 2, 1)[:, :, None, :] - M.transpose(0, 2, 1)[:, :, :, None]
    D = jnp.where(causal[None, None], jnp.exp(lw), 0.0)
    S = qk * D
    num = jnp.einsum("bhts,bshv->bthv", S, vc)         # (B,c,H,dv)
    inter_scale = jnp.exp(m0[:, None, :] - M)          # (B,c,H)
    num = num + inter_scale[..., None] * jnp.einsum("bthk,bhkv->bthv", qc, C0)
    nvec = jnp.einsum("bhts,bshk->bthk", D, kc)        # Σ exp(g_s−M_t) k_s
    nvec = nvec + inter_scale[..., None] * n0[:, None]
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bthk,bthk->bth", nvec, qc)), 1.0
    )
    h = num / den[..., None]

    # chunk-end state (t = c): M_c = M[:, -1], scale sources by exp(g_s − M_c)
    M_c = M[:, -1]                                     # (B,H)
    src = jnp.exp(g - M_c[:, None, :])                 # (B,c,H)
    C_new = jnp.einsum("bsh,bshk,bshv->bhkv", src, kc, vc)
    end_scale = jnp.exp(m0 - M_c)
    C_new = C_new + end_scale[..., None, None] * C0
    n_new = jnp.einsum("bsh,bshk->bhk", src, kc) + end_scale[..., None] * n0
    m_new = F[:, -1] + M_c                             # m_c = F_c + M_c
    return h, (C_new, n_new, m_new)


def apply_mlstm(
    p: dict, x: jax.Array, cfg: ArchConfig, return_state: bool = False,
    impl: str = "chunkwise",
):
    B, S, d = x.shape
    H, dh = _dims(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(jnp.float32) / math.sqrt(dh)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(jnp.float32)
    g = jnp.einsum("bsd,dhg->bshg", x.astype(jnp.float32), p["w_if"]) + p["b_if"]

    c = min(CHUNKWISE if impl == "chunkwise" else CHUNK, S)
    pad = (-S) % c
    if pad:
        q, k, v, g = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v, g))
        # padded steps: force forget=keep, input=-inf so state is unchanged
        gpad_mask = jnp.arange(S + pad) < S
        g = jnp.where(gpad_mask[None, :, None, None], g, jnp.array([-1e30, 30.0]))
    n_chunks = (S + pad) // c

    def split(t):
        return t.reshape(B, n_chunks, c, *t.shape[2:]).transpose(1, 0, 2, 3, 4)

    block = _mlstm_chunkwise_block if impl == "chunkwise" else _mlstm_chunk

    def body(state, xs):
        qc, kc, vc, gc = xs
        hs, state = jax.checkpoint(block)(qc, kc, vc, gc, state)
        return state, hs

    state0 = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.zeros((B, H), jnp.float32),
    )
    state_f, hs = jax.lax.scan(body, state0, (split(q), split(k), split(v), split(g)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * c, H, dh)[:, :S]
    y = jnp.einsum("bshk,hkd->bsd", h.astype(x.dtype), p["wo"])
    y = jax.nn.gelu(y @ p["w_up"]) @ p["w_down"]
    if return_state:
        # padded steps were forced to (i=-inf, f=+30): state passes through
        return y, {"C": state_f[0], "n": state_f[1], "m": state_f[2]}
    return y


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> dict:
    H, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def decode_mlstm(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig) -> tuple:
    """x: (B,1,d) -> (y (B,1,d), cache)."""
    H, dh = _dims(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(jnp.float32) / math.sqrt(dh)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(jnp.float32)
    g = jnp.einsum("bsd,dhg->bshg", x.astype(jnp.float32), p["w_if"]) + p["b_if"]
    (state, h) = _mlstm_step_single(
        q[:, 0], k[:, 0], v[:, 0], g[:, 0], (cache["C"], cache["n"], cache["m"])
    )
    y = jnp.einsum("bhk,hkd->bd", h.astype(x.dtype), p["wo"])[:, None]
    y = jax.nn.gelu(y @ p["w_up"]) @ p["w_down"]
    return y, {"C": state[0], "n": state[1], "m": state[2]}


def _mlstm_step_single(q, k, v, g, state):
    C, n, m = state
    i_t, f_t = g[..., 0], g[..., 1]
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(logf + m - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = f_s[..., None] * n + i_s[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return (C, n, m_new), num / den[..., None]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    H, dh = _dims(cfg)
    ks = jax.random.split(key, 6)
    si = 1.0 / math.sqrt(d)
    return {
        # input projections for cell input z and gates i, f, o
        "w_in": (jax.random.normal(ks[0], (d, H, dh, 4)) * si).astype(dtype),
        "b_in": jnp.zeros((H, dh, 4), jnp.float32),
        # diagonal recurrent connections per gate
        "r": (jax.random.normal(ks[1], (H, dh, 4)) * 0.1).astype(jnp.float32),
        "wo": (jax.random.normal(ks[2], (H, dh, d)) * (1.0 / math.sqrt(H * dh))).astype(dtype),
        "w_up": (jax.random.normal(ks[3], (d, 2 * d)) * si).astype(dtype),
        "w_down": (jax.random.normal(ks[4], (2 * d, d)) * (1.0 / math.sqrt(2 * d))).astype(dtype),
    }


def _slstm_chunk(zc, state, r):
    """zc: (B,c,H,dh,4) pre-activations; state: (h,c_,n,m) each (B,H,dh)."""

    def step(carry, z_t):
        h, c_, n, m = carry
        pre = z_t + r * h[..., None]                   # (B,H,dh,4)
        z = jnp.tanh(pre[..., 0])
        i_t = pre[..., 1]
        logf = jax.nn.log_sigmoid(pre[..., 2])
        o = jax.nn.sigmoid(pre[..., 3])
        m_new = jnp.maximum(logf + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_ = f_s * c_ + i_s * z
        n = f_s * n + i_s
        h = o * c_ / jnp.maximum(n, 1.0)
        return (h, c_, n, m_new), h

    state, hs = jax.lax.scan(step, state, zc.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), state


def apply_slstm(p: dict, x: jax.Array, cfg: ArchConfig, return_state: bool = False):
    B, S, d = x.shape
    H, dh = _dims(cfg)
    z = jnp.einsum("bsd,dhkg->bshkg", x, p["w_in"]).astype(jnp.float32) + p["b_in"]

    c = min(CHUNK, S)
    pad = (-S) % c
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        mask = jnp.arange(S + pad) < S
        neutral = jnp.array([0.0, -1e30, 30.0, 0.0])
        z = jnp.where(mask[None, :, None, None, None], z, neutral)
    n_chunks = (S + pad) // c
    zc = z.reshape(B, n_chunks, c, H, dh, 4).transpose(1, 0, 2, 3, 4, 5)

    def body(state, z_chunk):
        hs, state = jax.checkpoint(_slstm_chunk)(z_chunk, state, p["r"])
        return state, hs

    state0 = tuple(jnp.zeros((B, H, dh), jnp.float32) for _ in range(4))
    state_f, hs = jax.lax.scan(body, state0, zc)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * c, H, dh)[:, :S]
    y = jnp.einsum("bshk,hkd->bsd", h.astype(x.dtype), p["wo"])
    y = jax.nn.gelu(y @ p["w_up"]) @ p["w_down"]
    if return_state:
        return y, dict(zip(("h", "c", "n", "m"), state_f))
    return y


def init_slstm_cache(cfg: ArchConfig, batch: int) -> dict:
    H, dh = _dims(cfg)
    return {k: jnp.zeros((batch, H, dh), jnp.float32) for k in ("h", "c", "n", "m")}


def decode_slstm(p: dict, x: jax.Array, cache: dict, cfg: ArchConfig) -> tuple:
    z = jnp.einsum("bsd,dhkg->bshkg", x, p["w_in"]).astype(jnp.float32) + p["b_in"]
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    hs, state = _slstm_chunk(z, state, p["r"])
    y = jnp.einsum("bshk,hkd->bsd", hs.astype(x.dtype), p["wo"])
    y = jax.nn.gelu(y @ p["w_up"]) @ p["w_down"]
    return y, dict(zip(("h", "c", "n", "m"), state))

"""Core transformer layers: norms, RoPE, GQA attention (chunked online-softmax),
gated/classic MLP. Pure functions over param pytrees (dicts of jnp arrays).

Attention is implemented flash-style in pure JAX: a static python loop over
query chunks with exact (causal/window-clipped) KV ranges, and an inner
``lax.scan`` over KV chunks carrying online-softmax statistics in fp32. This
keeps peak memory at O(chunk^2) instead of O(S^2) so 32k prefill lowers with a
sane memory footprint, and gives honest near-S^2/2 causal FLOPs.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttentionConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, dtype) -> dict:
    p = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, cfg: ArchConfig, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32))
    else:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings. x: (..., S, H, dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    # angles: positions (.., S) -> (.., S, half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    # broadcast to (.., S, 1, half) over heads
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions: jax.Array, d_model: int, dtype) -> jax.Array:
    """(S,) -> (S, d_model) classic transformer sinusoids (whisper-style)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    a = cfg.attention
    d = cfg.d_model
    kq, kk, kv_, ko = jax.random.split(key, 4)
    qd, kvd = a.num_heads * a.head_dim, a.num_kv_heads * a.head_dim
    scale = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(kq, (d, a.num_heads, a.head_dim)) * scale).astype(dtype),
        "wk": (jax.random.normal(kk, (d, a.num_kv_heads, a.head_dim)) * scale).astype(dtype),
        "wv": (jax.random.normal(kv_, (d, a.num_kv_heads, a.head_dim)) * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (a.num_heads, a.head_dim, d)) * (1.0 / math.sqrt(qd))).astype(dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.num_heads, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.num_kv_heads, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.num_kv_heads, a.head_dim), dtype)
    return p


def _qkv(p: dict, x: jax.Array, a: AttentionConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _chunk_attend(q, k, v, *, q_pos, kv_start, softcap, scale, causal, window):
    """One (q_chunk, kv_chunk) online-softmax partial, fp32 stats.

    q: (B, cq, Hkv, G, dh); k/v: (B, ck, Hkv, dh); q_pos: (cq,) absolute.
    Returns (m, l, acc) partials for this kv chunk.
    """
    logits = jnp.einsum("bqhgk,bshk->bhgqs", q, k).astype(jnp.float32) * scale
    logits = _softcap(logits, softcap)
    kv_pos = kv_start + jnp.arange(k.shape[1])
    mask = jnp.ones((q_pos.shape[0], k.shape[1]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1)                    # (B,H,G,cq)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqs,bshk->bhgqk", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, acc


def _merge_partials(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    return m, l1 * e1 + l2 * e2, a1 * e1[..., None] + a2 * e2[..., None]


def _chunk_ranges(i, q_chunk, kv_chunk, Skvp, q_offset, causal, window):
    """Static KV range [lo, lo + nkv*kv_chunk) for q chunk i."""
    q_lo = i * q_chunk
    hi = Skvp if not causal else min(Skvp, q_offset + q_lo + q_chunk)
    lo = 0
    if window:
        lo = max(0, q_offset + q_lo - window - kv_chunk + 1)
        lo = (lo // kv_chunk) * kv_chunk
    hi = -(-max(hi, 1) // kv_chunk) * kv_chunk
    hi = min(hi, Skvp)
    nkv = max((hi - lo) // kv_chunk, 1)
    return q_lo, lo, nkv


def _pad_to(x, S, axis=1):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, S - x.shape[axis])
    return jnp.pad(x, pad) if S != x.shape[axis] else x


def _kv_chunks(kp, lo, nkv, kv_chunk):
    ks = jax.lax.dynamic_slice_in_dim(kp, lo, nkv * kv_chunk, axis=1)
    B, _, Hkv, dh = ks.shape
    return ks.reshape(B, nkv, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)


def _flash_fwd_chunk(qi, kp, vp, i, *, q_chunk, kv_chunk, Skvp, q_offset,
                     causal, window, softcap, scale):
    """Online-softmax forward for one q chunk. Returns (out, lse)."""
    B, _, Hkv, G, dh = qi.shape
    q_lo, lo, nkv = _chunk_ranges(i, q_chunk, kv_chunk, Skvp, q_offset, causal, window)
    q_pos = q_offset + q_lo + jnp.arange(q_chunk)
    ks = _kv_chunks(kp, lo, nkv, kv_chunk)
    vs = _kv_chunks(vp, lo, nkv, kv_chunk)
    starts = lo + kv_chunk * jnp.arange(nkv)

    def body(carry, xs):
        m0, l0, a0 = carry
        kc, vc, start = xs
        m1, l1, a1 = _chunk_attend(
            qi, kc, vc, q_pos=q_pos, kv_start=start,
            softcap=softcap, scale=scale, causal=causal, window=window,
        )
        return _merge_partials(m0, l0, a0, m1, l1, a1), None

    m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, q_chunk, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)           # (B,Hkv,G,cq,dh)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))               # (B,Hkv,G,cq)
    return out, lse


def _flash_impl(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk):
    """Forward pass; returns (out (B,Sq,Hq,dh), lse (B,Hkv,G,Sqp))."""
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    Sqp = -(-Sq // q_chunk) * q_chunk
    Skvp = -(-Skv // kv_chunk) * kv_chunk
    qp = _pad_to(q, Sqp).reshape(B, Sqp // q_chunk, q_chunk, Hkv, G, dh)
    kp = _pad_to(k, Skvp)
    vp = _pad_to(v, Skvp)

    outs, lses = [], []
    for i in range(Sqp // q_chunk):
        out, lse = _flash_fwd_chunk(
            qp[:, i], kp, vp, i, q_chunk=q_chunk, kv_chunk=kv_chunk,
            Skvp=Skvp, q_offset=q_offset, causal=causal, window=window,
            softcap=softcap, scale=scale,
        )
        outs.append(out.transpose(0, 3, 1, 2, 4))          # (B,cq,Hkv,G,dh)
        lses.append(lse)
    o = jnp.concatenate(outs, axis=1)[:, :Sq]
    lse = jnp.concatenate(lses, axis=-1)                   # (B,Hkv,G,Sqp)
    return o.reshape(B, Sq, Hq, dh).astype(q.dtype), lse


def _flash_bwd_impl(q, k, v, out, lse, do, causal, window, softcap,
                    q_offset, q_chunk, kv_chunk):
    """Flash backward: recompute probabilities per (q,kv) chunk pair from the
    saved logsumexp — no O(S^2) residuals. Standard Dao-style dq/dk/dv."""
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    Sqp = -(-Sq // q_chunk) * q_chunk
    Skvp = -(-Skv // kv_chunk) * kv_chunk
    nq = Sqp // q_chunk
    qp = _pad_to(q, Sqp).reshape(B, nq, q_chunk, Hkv, G, dh)
    op = _pad_to(out, Sqp).reshape(B, nq, q_chunk, Hkv, G, dh)
    dop = _pad_to(do, Sqp).reshape(B, nq, q_chunk, Hkv, G, dh)
    kp = _pad_to(k, Skvp)
    vp = _pad_to(v, Skvp)

    dq = jnp.zeros((B, nq, q_chunk, Hkv, G, dh), jnp.float32)
    dk = jnp.zeros((B, Skvp, Hkv, dh), jnp.float32)
    dv = jnp.zeros((B, Skvp, Hkv, dh), jnp.float32)

    for i in range(nq):
        qi = qp[:, i]
        oi = op[:, i].astype(jnp.float32)
        doi = dop[:, i].astype(jnp.float32)
        lse_i = lse[..., i * q_chunk : (i + 1) * q_chunk]  # (B,Hkv,G,cq)
        Di = jnp.sum(oi * doi, axis=-1)                    # (B,cq,Hkv,G)
        Di = Di.transpose(0, 2, 3, 1)                      # (B,Hkv,G,cq)
        q_lo, lo, nkv = _chunk_ranges(i, q_chunk, kv_chunk, Skvp, q_offset, causal, window)
        q_pos = q_offset + q_lo + jnp.arange(q_chunk)
        ks = _kv_chunks(kp, lo, nkv, kv_chunk)
        vs = _kv_chunks(vp, lo, nkv, kv_chunk)
        starts = lo + kv_chunk * jnp.arange(nkv)

        def body(dq_acc, xs, qi=qi, doi=doi, lse_i=lse_i, Di=Di, q_pos=q_pos):
            kc, vc, start = xs
            z = jnp.einsum("bqhgk,bshk->bhgqs", qi, kc).astype(jnp.float32) * scale
            s = _softcap(z, softcap)
            kv_pos = start + jnp.arange(kc.shape[1])
            mask = jnp.ones((q_pos.shape[0], kc.shape[1]), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            p = jnp.exp(s - lse_i[..., None])              # (B,H,G,cq,ck)
            dv_c = jnp.einsum("bhgqs,bqhgk->bshk", p, doi)
            dp = jnp.einsum("bqhgk,bshk->bhgqs", doi.astype(vc.dtype), vc).astype(jnp.float32)
            ds = p * (dp - Di[..., None])
            if softcap and softcap > 0:
                ds = ds * (1.0 - jnp.square(jnp.tanh(z / softcap)))
            ds = ds * scale
            dq_c = jnp.einsum("bhgqs,bshk->bqhgk", ds, kc.astype(jnp.float32))
            dk_c = jnp.einsum("bhgqs,bqhgk->bshk", ds, qi.astype(jnp.float32))
            return dq_acc + dq_c, (dk_c, dv_c)

        dq_i = jnp.zeros((B, q_chunk, Hkv, G, dh), jnp.float32)
        dq_i, (dk_parts, dv_parts) = jax.lax.scan(body, dq_i, (ks, vs, starts))
        dq = dq.at[:, i].set(dq_i)
        span = nkv * kv_chunk
        dk_upd = dk_parts.transpose(1, 0, 2, 3, 4).reshape(B, span, Hkv, dh)
        dv_upd = dv_parts.transpose(1, 0, 2, 3, 4).reshape(B, span, Hkv, dh)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, lo, span, axis=1) + dk_upd, lo, axis=1
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, lo, span, axis=1) + dv_upd, lo, axis=1
        )

    dq = dq.reshape(B, Sqp, Hkv, G, dh)[:, :Sq].reshape(B, Sq, Hq, dh)
    return dq.astype(q.dtype), dk[:, :Skv].astype(k.dtype), dv[:, :Skv].astype(v.dtype)


@partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def _flash_attention(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk):
    out, _ = _flash_impl(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk)
    return out


def _flash_fwd_rule(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk):
    out, lse = _flash_impl(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, softcap, q_offset, q_chunk, kv_chunk, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_impl(
        q, k, v, out, lse, do, causal, window, softcap, q_offset, q_chunk, kv_chunk
    )


_flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style chunked attention with an exact-recompute custom VJP.

    q: (B, Sq, Hq, dh); k, v: (B, Skv, Hkv, dh). Returns (B, Sq, Hq, dh).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0).
    Static python loop over q chunks -> exact causal/window KV ranges (honest
    ~S^2/2 FLOPs); inner ``lax.scan`` over KV chunks.

    The custom VJP recomputes per-chunk probabilities from the saved
    logsumexp instead of letting XLA save stacked fp32 logits for every
    (q, kv) chunk pair — without it, a 4k train step wants ~43 GB of
    per-device scratch (EXPERIMENTS.md §Perf iteration 1). Set
    REPRO_ATTN_IMPL=xla to get the naive autodiff path back.
    """
    import os as _os

    if _os.environ.get("REPRO_ATTN_IMPL", "flash") == "xla":
        out, _ = _flash_impl(q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk)
        return out
    return _flash_attention(
        q, k, v, causal, window, softcap, q_offset, q_chunk, kv_chunk
    )


def attention_forward(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    is_local: bool = False,
    kv_override: Optional[tuple] = None,
    return_kv: bool = False,
):
    """Full attention sub-layer for train/prefill (no cache). x: (B,S,d).

    ``is_local``: this layer uses the sliding window (gemma2 alternation or
    uniform SWA). ``kv_override``: (k, v, kv_positions) for cross-attention.
    ``return_kv``: also return the (post-RoPE) k, v for prefill cache capture.
    """
    a = cfg.attention
    q, k, v = _qkv(p, x, a)
    if kv_override is not None:
        k, v, _ = kv_override
        q = rope(q, positions, a.rope_theta) if cfg.norm == "rmsnorm" else q
        out = chunked_attention(q, k, v, causal=False, softcap=a.logit_softcap)
    else:
        if cfg.norm == "rmsnorm":  # rope family (whisper uses absolute)
            q = rope(q, positions, a.rope_theta)
            k = rope(k, positions, a.rope_theta)
        window = a.sliding_window if (is_local and a.sliding_window) else 0

        from repro.models import policy as policy_mod

        pad = policy_mod.get_head_pad()
        if pad is not None and a.num_heads == a.num_kv_heads:
            # H4: zero-pad the head axis to a mesh-divisible count so the
            # O(S^2) einsums shard over "model" (padded heads attend
            # uniformly but are sliced away before wo — exact).
            vH, spec = pad
            H = a.num_heads
            def padh(t):
                t = jnp.pad(t, ((0, 0), (0, 0), (0, vH - H), (0, 0)))
                return jax.lax.with_sharding_constraint(t, spec)
            out = chunked_attention(
                padh(q), padh(k), padh(v), causal=causal, window=window,
                softcap=a.logit_softcap,
            )[:, :, :H]
        else:
            out = chunked_attention(
                q, k, v, causal=causal, window=window, softcap=a.logit_softcap
            )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def decode_attention(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    is_local: bool = False,
    window_cache: bool = False,
) -> tuple:
    """Single-token decode. x: (B,1,d); caches: (B,W,Hkv,dh); pos: scalar int.

    Returns (out (B,1,d), new_k_cache, new_v_cache). With ``window_cache`` the
    cache is a ring buffer of size W; otherwise W >= pos+1 (full cache).
    """
    a = cfg.attention
    q, k, v = _qkv(p, x, a)
    if cfg.norm == "rmsnorm":
        pos_arr = jnp.asarray(pos)[None]
        q = rope(q, pos_arr, a.rope_theta)
        k = rope(k, pos_arr, a.rope_theta)
    W = k_cache.shape[1]
    slot = jnp.mod(pos, W) if window_cache else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)

    B, _, Hq, dh = q.shape
    Hkv = a.num_kv_heads
    G = Hq // Hkv
    qh = q.reshape(B, Hkv, G, dh)
    logits = jnp.einsum("bhgk,bshk->bhgs", qh, k_cache).astype(jnp.float32)
    logits = logits / math.sqrt(dh)
    logits = _softcap(logits, a.logit_softcap)

    idx = jnp.arange(W)
    if window_cache:
        # ring buffer: entry at slot s holds absolute position derived from pos
        abs_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot - W + idx)
        valid = abs_pos >= 0
    else:
        abs_pos = idx
        valid = idx <= pos
    if is_local and a.sliding_window:
        valid &= abs_pos > pos - a.sliding_window
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshk->bhgk", probs.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, Hq, dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def init_mlp(key, cfg: ArchConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d)) * s_out).astype(dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.gated_mlp:
        up = _act(cfg.activation)(x @ p["w_gate"]) * up
    else:
        up = _act(cfg.activation)(up)
    return up @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
            * (1.0 / math.sqrt(cfg.d_model))
        ).astype(dtype)
    return p


def embed_tokens(p: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.tie_embeddings:  # gemma-style sqrt(d) scaling for tied embeddings
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embed"])
    else:
        logits = x @ p["lm_head"]
    logits = _softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits

"""Flat-npz pytree checkpointing (no orbax in this container).

Pytrees are flattened to ``path/to/leaf`` keys. Server state (FedECADO flow
variables + gains + clocks) round-trips losslessly; restore validates
structure against a template.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "|"


def _flatten_with_paths(tree: Pytree, convert_bf16: bool = True):
    # jax.tree.flatten_with_path only exists in newer jax; tree_util's
    # spelling works across the versions this repo supports
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if convert_bf16 and arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # npz can't store bf16; restore recasts
        out[key] = arr
    return out, treedef


def save_pytree(path: str, tree: Pytree) -> None:
    flat, _ = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path: str, template: Pytree) -> Pytree:
    """Restore into the structure of ``template`` (shape/dtype validated)."""
    with np.load(path) as zf:
        flat_t, treedef = _flatten_with_paths(template, convert_bf16=False)
        leaves = []
        for key, tmpl in flat_t.items():
            if key not in zf:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = zf[key]
            if arr.shape != tmpl.shape:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != template {tmpl.shape}"
                )
            leaves.append(jnp.asarray(arr, tmpl.dtype))
    flat_template, treedef = jax.tree.flatten(template)
    return jax.tree.unflatten(jax.tree.structure(template), leaves)


def save_server_state(path: str, state) -> None:
    save_pytree(path, state._asdict())


def restore_server_state(path: str, template) -> Any:
    d = load_pytree(path, template._asdict())
    return type(template)(**d)

from repro.checkpoint.ckpt import load_pytree, restore_server_state, save_pytree, save_server_state

__all__ = ["save_pytree", "load_pytree", "save_server_state", "restore_server_state"]

"""Non-IID data partitioning across clients.

The statistical-skew axis of the scenario subsystem (repro/scenarios,
DESIGN.md §7) is built from the partitioners here:

* ``dirichlet_partition`` reproduces the paper's §5.1 setting: class-label
  proportions per client drawn from Dir(alpha) (paper uses Dir(0.1) over 100
  clients); client dataset sizes |D_i| fall out of the draw and feed the p_i
  weights of the aggregate sensitivity model (eq. 34).
* ``label_shard_partition`` is the McMahan-style pathological split: each
  client holds samples from at most ``shards_per_client`` classes.
* ``quantity_skew_partition`` keeps labels IID but draws client sizes from a
  Zipf profile — a few data-rich clients, a long tail of tiny ones.
* ``iid_partition`` is the uniform control.

All partitioners are deterministic per ``seed`` and return disjoint index
arrays covering every sample exactly once (tests/test_scenarios.py pins the
invariants).
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float = 0.1,
    seed: int = 0,
    min_size: int = 2,
    max_retries: int = 64,
) -> List[np.ndarray]:
    """Partition sample indices by Dirichlet-distributed class proportions.

    Returns a list of index arrays, one per client. A draw leaving any
    client below ``min_size`` samples is rejected and redrawn from a
    deterministically advanced seed (attempt ``a`` uses RandomState
    ``seed + 0x9E3779B9·a mod 2^32``; attempt 0 keeps the historical
    stream, so succeeding-first-try results are unchanged). After
    ``max_retries`` rejected draws — or immediately when ``min_size`` is
    arithmetically unreachable — a ValueError explains which knob to relax.
    """
    n_samples = len(labels)
    if n_samples < n_clients * min_size:
        raise ValueError(
            f"dirichlet_partition: min_size={min_size} is unreachable — "
            f"{n_samples} samples cannot give {n_clients} clients "
            f">= {min_size} each; lower min_size or n_clients"
        )
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)

    for attempt in range(max_retries):
        if attempt:
            # advance the seed deterministically: each retry draws from a
            # fresh, attempt-derived stream instead of whatever state the
            # previous rejection happened to leave behind
            rng = np.random.RandomState((seed + 0x9E3779B9 * attempt) % (1 << 32))
        client_idx: List[list] = [[] for _ in range(n_clients)]
        for idx in idx_by_class:
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx, cuts)):
                client_idx[client].extend(part.tolist())
        sizes = np.array([len(ci) for ci in client_idx])
        if sizes.min() >= min_size:
            out = [np.asarray(sorted(ci), dtype=np.int64) for ci in client_idx]
            for o in out:
                rng.shuffle(o)
            return out
    raise ValueError(
        f"dirichlet_partition: no draw satisfied min_size={min_size} after "
        f"{max_retries} attempts (n_samples={n_samples}, "
        f"n_clients={n_clients}, alpha={alpha}); lower min_size, raise "
        f"alpha, or raise max_retries"
    )


def label_shard_partition(
    labels: np.ndarray,
    n_clients: int,
    shards_per_client: int = 2,
    seed: int = 0,
) -> List[np.ndarray]:
    """Pathological label skew: each client holds <= ``shards_per_client``
    classes (the McMahan et al. 2017 CIFAR/MNIST split). Classes are dealt
    to clients round-robin over a seed-permuted class order, then each
    class's (shuffled) samples are split evenly among the clients that hold
    it — so every sample lands on exactly one client.
    """
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    perm = rng.permutation(n_classes)
    class_clients: List[List[int]] = [[] for _ in range(n_classes)]
    for i in range(n_clients):
        held = set()
        for j in range(shards_per_client):
            c = int(perm[(i * shards_per_client + j) % n_classes])
            if c not in held:            # k > n_classes would deal repeats
                held.add(c)
                class_clients[c].append(i)
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        if not class_clients[c]:
            # fewer shard slots than classes: deal the orphan class to the
            # least-loaded client (keeps the partition complete; that client
            # may then exceed shards_per_client only when
            # n_clients·shards_per_client < n_classes)
            class_clients[c].append(
                int(np.argmin([len(ci) for ci in client_idx]))
            )
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        for cl, chunk in zip(
            class_clients[c], np.array_split(idx, len(class_clients[c]))
        ):
            client_idx[cl].extend(chunk.tolist())
    if min(len(ci) for ci in client_idx) == 0:
        raise ValueError(
            f"label_shard_partition: {n_clients} clients x "
            f"{shards_per_client} shards left an empty client "
            f"(n_classes={n_classes}); lower n_clients or raise "
            f"shards_per_client"
        )
    out = [np.asarray(sorted(ci), dtype=np.int64) for ci in client_idx]
    for o in out:
        rng.shuffle(o)
    return out


def quantity_skew_partition(
    n_samples: int,
    n_clients: int,
    zipf_a: float = 1.4,
    seed: int = 0,
    min_size: int = 2,
) -> List[np.ndarray]:
    """IID labels, Zipf(``zipf_a``) client sizes: client at (permuted) rank
    r holds ~ r^-a of the data — a few data-rich clients, a long tail of
    tiny ones. Sizes are floored at ``min_size``; the rank->client map is a
    seed-drawn permutation so client 0 is not always the giant.
    """
    if n_samples < n_clients * min_size:
        raise ValueError(
            f"quantity_skew_partition: min_size={min_size} is unreachable — "
            f"{n_samples} samples over {n_clients} clients"
        )
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, n_clients + 1, dtype=np.float64) ** (-zipf_a)
    props = ranks / ranks.sum()
    spare = n_samples - n_clients * min_size
    sizes = min_size + np.floor(props * spare).astype(np.int64)
    # largest-remainder: hand the leftover samples to the largest shares
    rem = n_samples - int(sizes.sum())
    order = np.argsort(-(props * spare - np.floor(props * spare)))
    sizes[order[:rem]] += 1
    assert sizes.sum() == n_samples
    sizes = sizes[rng.permutation(n_clients)]       # rank -> client map
    idx = rng.permutation(n_samples)
    cuts = np.cumsum(sizes)[:-1]
    return [np.asarray(p, dtype=np.int64) for p in np.split(idx, cuts)]


def data_fractions(partitions: List[np.ndarray]) -> np.ndarray:
    """p_i = |D_i| / |D|  (eq. 34)."""
    sizes = np.array([len(p) for p in partitions], dtype=np.float64)
    return (sizes / sizes.sum()).astype(np.float32)


def iid_partition(n_samples: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n_samples)
    return [np.asarray(p) for p in np.array_split(idx, n_clients)]

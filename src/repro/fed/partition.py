"""Non-IID data partitioning across clients.

``dirichlet_partition`` reproduces the paper's §5.1 setting: class-label
proportions per client drawn from Dir(alpha) (paper uses Dir(0.1) over 100
clients); client dataset sizes |D_i| fall out of the draw and feed the p_i
weights of the aggregate sensitivity model (eq. 34).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float = 0.1,
    seed: int = 0,
    min_size: int = 2,
) -> List[np.ndarray]:
    """Partition sample indices by Dirichlet-distributed class proportions.

    Returns a list of index arrays, one per client.
    """
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)

    while True:
        client_idx: List[list] = [[] for _ in range(n_clients)]
        for c, idx in enumerate(idx_by_class):
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx, cuts)):
                client_idx[client].extend(part.tolist())
        sizes = np.array([len(ci) for ci in client_idx])
        if sizes.min() >= min_size:
            break
    out = [np.asarray(sorted(ci), dtype=np.int64) for ci in client_idx]
    for o in out:
        rng.shuffle(o)
    return out


def data_fractions(partitions: List[np.ndarray]) -> np.ndarray:
    """p_i = |D_i| / |D|  (eq. 34)."""
    sizes = np.array([len(p) for p in partitions], dtype=np.float64)
    return (sizes / sizes.sum()).astype(np.float32)


def iid_partition(n_samples: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(n_samples)
    return [np.asarray(p) for p in np.array_split(idx, n_clients)]

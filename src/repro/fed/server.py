"""Federated-learning simulation driver.

Runs any algorithm registered in the ``fed/algorithms`` plugin registry
(fedecado, ecado, fedavg, fedprox, fednova, fedadmm, plus anything a user
registers) over a dataset partitioned across n clients with configurable
participation, non-IID Dirichlet skew, and heterogeneous computation
(lr_i, e_i per eqs. 43-44). Used by the paper-reproduction experiments,
examples/ and benchmarks/.

Heterogeneity regimes come from the scenario registry (repro/scenarios,
DESIGN.md §7): when ``FedSimConfig.scenario`` names (or carries) a
``Scenario``, the scenario owns partitioning and per-client statistical
transforms (``FedSim`` materializes them from the raw dataset — pass
``partitions=None``), and its systems axis reshapes every round's
``CohortPlan`` inside ``_draw_plan``: availability traces replace the
uniform cohort draw, device profiles replace the ``HeteroConfig`` envelope
for (lr_i, e_i), and mid-round dropout truncates local windows. Because all
of that happens in the shared host-side plan draw, every execution backend
consumes scenarios unchanged. ``drift_every`` re-partitions at segment
boundaries (handled like gain refresh).

``FedSim`` owns no algorithm-specific logic: ``cfg.algorithm`` is resolved
once through ``make_algorithm`` and every formerly hardwired decision —
client kind, per-client objective weights, server state and gains,
aggregation rule, heterogeneity/participation/eligibility — is a protocol
method or capability flag on ``self.alg`` (DESIGN.md §6).

Client execution is delegated to the multi-rate engine in ``repro/sim``
behind the ``ExecutionBackend`` interface — ``FedSimConfig.backend`` picks
``sequential`` (per-client dispatch, the numerical reference oracle),
``vectorized`` (whole cohort in one vmap-over-scan dispatch), ``event``
(device-resident flight-table scheduler with straggler staleness and
jit-resident segments, optionally mesh-sharded via ``event_sharded``;
requires ``alg.has_flow_dynamics``; all-busy rounds report ``loss = nan``
— summarize histories with ``last_finite_loss``/``mean_finite_loss``), or
``sharded`` (shard_map over the client mesh axis with psum consensus
reductions and jit-resident multi-round segments).
All host-side randomness for a round is rolled into a ``CohortPlan`` up
front so every backend consumes identical cohorts/batches (DESIGN.md §5);
``run`` hands whole segments of pre-drawn plans to the backend and only
returns to the host at eval / gain-update boundaries.

Data fractions p_i are normalized as p̂_i = n·p_i (mean 1) so local update
magnitudes stay on the same timescale as the unweighted baselines; this is a
global rescale of the objective (recorded in DESIGN.md) and leaves the
optimum of Σ p_i f_i unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ConsensusConfig
from repro.fed.algorithms import available_algorithms, make_algorithm
from repro.fed.client import HeteroConfig
from repro.fed.partition import data_fractions
from repro.obs import RunHistory, RunLog, TraceRecorder, make_record, span

Pytree = Any

# snapshot of the registry at import time, kept for back-compat call sites;
# prefer fed.algorithms.available_algorithms() which reflects late plugins
ALGORITHMS = available_algorithms()


def last_finite_loss(losses: Sequence[float]) -> float:
    """The most recent finite entry of a loss history, or nan if none.

    The event backend marks all-busy rounds (no client dispatched, server
    advanced on pending arrivals only) with ``loss = nan`` rather than
    pretending a loss was observed; any consumer that summarizes a history
    endpoint must skip those gaps instead of averaging them away —
    ``nan`` propagating into a "final loss" mislabels an otherwise healthy
    run as diverged."""
    arr = np.asarray(list(losses), np.float64)
    finite = np.isfinite(arr)
    if not finite.any():
        return float("nan")
    return float(arr[finite][-1])


def mean_finite_loss(losses: Sequence[float]) -> float:
    """nan-skipping mean of a loss history (nan if every entry is a gap)."""
    arr = np.asarray(list(losses), np.float64)
    if not np.isfinite(arr).any():
        return float("nan")
    return float(np.nanmean(arr))


@dataclasses.dataclass
class FedSimConfig:
    algorithm: str = "fedecado"
    n_clients: int = 100
    participation: float = 0.1
    rounds: int = 100
    batch_size: int = 32
    steps_per_epoch: int = 5
    # heterogeneity: if None, every client uses (lr_fixed, epochs_fixed)
    hetero: Optional[HeteroConfig] = None
    lr_fixed: float = 5e-3
    epochs_fixed: int = 2
    mu: float = 0.1                     # FedProx proximal weight / FedADMM ρ
    consensus: ConsensusConfig = dataclasses.field(default_factory=ConsensusConfig)
    dt_ref: float = 0.05                # Δt_ref in Ḡ_th = 1/Δt_ref + p·h̄
    hutchinson_probes: int = 2
    # "scalar": Ḡ_th^i is one gain per client (tr(H)/n estimate);
    # "diag": per-parameter gains via the Hutchinson diagonal (eq. 42 with a
    # diagonal H̄ — the Schur solve stays exact elementwise)
    sensitivity: str = "scalar"
    # paper §4.2: the sensitivity model "can be periodically updated";
    # 0 = precompute once before training (the paper's §5 setting)
    gain_update_every: int = 0
    seed: int = 0
    eval_every: int = 5
    # --- multi-rate execution engine (repro/sim, DESIGN.md §5) ---
    # sequential | vectorized | event | sharded, or "auto" to let the HLO
    # cost model pick at construction (repro.tune.autotune, DESIGN.md §12)
    backend: str = "sequential"
    # event backend: quantile of in-flight windows absorbed per round
    # (< 1.0 leaves stragglers in the queue -> mid-round returns next round)
    event_horizon: float = 1.0
    event_max_waves: int = 4        # BE sync groups per round
    # run the event backend's flight table sharded over the client mesh
    # (psum-reduced wave solves, DESIGN.md §8); False = dense single-device
    event_sharded: bool = False
    # fully-asynchronous buffered server (DESIGN.md §10): replace the
    # quantile horizon with a K-trigger — the server aggregates whenever
    # event_buffer_size endpoints are in flight, no round barrier; pending
    # flights age and their endpoints are damped by the staleness weight
    # 1/(1 + event_stale_gamma · stale_rounds) when absorbed
    event_buffered: bool = False
    event_buffer_size: int = 0      # required >= 1 (and <= n_clients) when buffered
    event_stale_gamma: float = 0.25
    # fuse the averaging-family cohort aggregation with the Pallas
    # batched-aggregation kernel (kernels/batch_agg.py)
    agg_kernels: bool = False
    # sharded backend: force the cohort padding unit above the device count
    # (DESIGN.md §5.5) — lets tests exercise uneven client→device padding
    # even on a single-device host; None = pad to the device count
    sharded_pad_multiple: Optional[int] = None
    # hierarchical tree aggregation (DESIGN.md §13): split the client mesh
    # into a 2-D ("groups", "clients") mesh with this many device groups —
    # cross-device reductions become intra-group psum then inter-group
    # reduce. None/0 = the flat 1-D mesh. Numerics: association order of
    # the staged reduction differs from the flat psum (rtol-level, not
    # bitwise — see §13); sharded backend only.
    sharded_groups: Optional[int] = None
    # --- client-state cache (sim/cache.py, DESIGN.md §13) ---
    # participants-only packed state: per-client rows (flow variables,
    # gains, duals, EF residuals, flight table) live in a (capacity, ...)
    # pytree over ADMITTED clients only — memory scales with the cohort,
    # not n_clients. Histories are bitwise-identical to the materialized
    # layout (pinned by tests/test_client_cache.py).
    client_cache: bool = False
    # initial packed capacity floor; 0 = max(2·cohort, event_buffer_size)
    # rounded up to a power of two (min 64). Capacity doubles on demand.
    cache_capacity: int = 0
    # --- heterogeneity scenario (repro/scenarios, DESIGN.md §7) ---
    # a registered scenario name or a Scenario instance; when set, FedSim
    # materializes partitions + per-client transforms from the raw dataset
    # (pass partitions=None) and the scenario's systems axis (availability,
    # device profiles, dropout) steers every round's CohortPlan. Scenario
    # device profiles take precedence over ``hetero``.
    scenario: Optional[Any] = None
    # --- observability (repro/obs, DESIGN.md §9) ---
    # structured JSONL run log: one header + one record per round + summary
    log_jsonl: Optional[str] = None
    # Chrome-trace JSON of host-side spans (plan draw, segment dispatch,
    # gain refresh, eval) — load in chrome://tracing / ui.perfetto.dev
    trace_json: Optional[str] = None
    # --- client→server wire (repro/comm, DESIGN.md §11) ---
    # a registered compressor name (identity | int8 | int4 | topk); None =
    # the uncompressed fp32 wire (still accounted: every record carries
    # bytes_up/bytes_down). Compressor × algorithm combos the capability
    # flags forbid (topk × flow dynamics) are refused at construction.
    compress: Optional[str] = None
    # the compressor's own aggressiveness ladder (topk kept-fraction level);
    # None = the plugin's default_level
    compress_level: Optional[int] = None


class FedSim:
    """Simulates federated training of a (small) model on CPU."""

    def __init__(
        self,
        loss_fn: Callable,                 # loss_fn(params, batch) -> scalar
        params0: Pytree,
        data: Dict[str, np.ndarray],       # {"x": (N, ...), "y": (N,)}
        partitions: Optional[Sequence[np.ndarray]],  # per-client index arrays
        cfg: FedSimConfig,
        eval_fn: Optional[Callable] = None,  # eval_fn(params) -> dict metrics
    ):
        self.alg = make_algorithm(cfg)     # ValueError lists the registry
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.n = cfg.n_clients
        self.scn = None
        self._raw_data = data
        if cfg.scenario is not None:
            from repro.scenarios import make_scenario  # lazy: avoid cycle

            if partitions is not None:
                raise ValueError(
                    "pass partitions=None when cfg.scenario is set — the "
                    "scenario owns partitioning (and the per-client "
                    "statistical transforms that ride on it)"
                )
            self.scn = make_scenario(cfg.scenario)
            data, partitions = self.scn.materialize(data, self.n, cfg.seed)
        self.data = data
        self.partitions = list(partitions)
        assert len(self.partitions) == self.n
        self.eval_fn = eval_fn
        self.rng = np.random.RandomState(cfg.seed)

        p = data_fractions(self.partitions)
        # p̂ over the POPULATION (mean-1 normalization); in client_cache
        # mode ``self.p_hat`` below is the per-SLOT view of it (rebuilt on
        # admission), which is what plan.idx — then holding slots — gathers
        self.p_hat_full = (p * self.n).astype(np.float32)
        self.p_hat = self.p_hat_full

        # participants-only packed state (sim/cache.py, DESIGN.md §13):
        # created BEFORE any per-client state allocation so everything
        # downstream (EF residuals, algorithm state, flight table) sizes by
        # ``state_rows`` = capacity instead of n
        self.cache = None
        if cfg.client_cache:
            from repro.sim.cache import ClientStateCache  # lazy: sim↔fed

            A0 = self.n if self.alg.full_participation_only else max(
                1, int(round(cfg.participation * self.n))
            )
            floor = int(cfg.cache_capacity) or max(
                2 * A0, int(cfg.event_buffer_size or 0)
            )
            self.cache = ClientStateCache(self.n, capacity=floor)
            self.p_hat = np.zeros((self.cache.capacity,), np.float32)

        self.params = jax.tree.map(lambda l: l.astype(jnp.float32), params0)
        self.state = None
        # the wire model: ALWAYS built (identity when cfg.compress is None)
        # so bytes accounting is unconditional; refuses forbidden
        # compressor × algorithm combos with an actionable error
        from repro.comm import make_comm_spec  # lazy: kernels import chain

        self.comm = make_comm_spec(
            cfg.compress, cfg.compress_level, self.params,
            seed=cfg.seed, alg_cls=type(self.alg),
        )
        self.alg.comm = self.comm
        if self.comm.error_feedback and not self.alg.has_flow_dynamics:
            # error-feedback residual rows: averaging family only — the flow
            # family compresses EF-free on every backend so the dense and
            # event/sharded paths agree on what the wire carries
            self.alg.comm_state = self.comm.init_ef_state(
                self.params, self.state_rows
            )
        # algorithm-owned server state (flow variables + gains, dual rows,
        # ...); any host rng it draws (gain estimation batches) comes first
        # in the consumption order, exactly as the seed behaviour
        self.alg.init_state(self)

        from repro.sim.engine import get_backend  # lazy: sim imports fed.client

        # backend="auto": score the candidate backends against the HLO cost
        # model (repro.tune, DESIGN.md §12) for THIS algorithm/model/n and
        # replace cfg with the resolved copy; the decision rides the run-log
        # header so predicted-vs-measured gaps stay auditable
        self.tune_decision = None
        if cfg.backend == "auto":
            from repro.tune.autotune import resolve_auto  # lazy: tune→sim

            cfg, self.tune_decision = resolve_auto(
                cfg, self.alg, loss_fn, self.params, self.data
            )
            self.cfg = cfg
        self.backend = get_backend(cfg)

    # ------------------------------------------------------------------
    @property
    def state_rows(self) -> int:
        """Leading-axis length of every per-client packed array: the cache
        capacity in client_cache mode, else the full population n."""
        return self.cache.capacity if self.cache is not None else self.n

    # ------------------------------------------------------------------
    def _install_gains(self, round_idx: int = 0):
        self.alg.install_gains(self, round_idx=round_idx)

    # ------------------------------------------------------------------
    def _client_batch(self, i: int, bs: int):
        idx = self.partitions[i]
        sel = self.rng.choice(idx, size=min(bs, len(idx)), replace=len(idx) < bs)
        return {k: jnp.asarray(v[sel]) for k, v in self.data.items()}

    # ------------------------------------------------------------------
    def _gain_batch(self, i: int, bs: int, round_idx: int = 0):
        """Gain-estimation minibatch for client ``i``, keyed by
        (seed, round_idx, cid) instead of consuming ``self.rng``
        sequentially. Deterministic per client, so the cached engine can
        estimate a late-admitted client's gain lazily and draw the SAME
        batch the materialized run would have (DESIGN.md §13) — and the
        materialized run no longer pays O(n) rng draws before round 0."""
        part = self.partitions[int(i)]
        r = np.random.RandomState(
            (self.cfg.seed + 1_000_003 * int(round_idx) + 7919 * (int(i) + 1))
            % (1 << 31)
        )
        sel = r.choice(part, size=min(bs, len(part)), replace=len(part) < bs)
        return {k: jnp.asarray(v[sel]) for k, v in self.data.items()}

    # ------------------------------------------------------------------
    def _refresh_slot_weights(self) -> None:
        """Rebuild the per-slot p̂ view after an admission or drift:
        slot j carries p̂_full[cids[j]], padding slots 0."""
        ph = np.zeros((self.cache.capacity,), np.float32)
        ph[: self.cache.n_admitted] = self.p_hat_full[self.cache.cids]
        self.p_hat = ph

    # ------------------------------------------------------------------
    def _admit_and_translate(self, plans):
        """client_cache mode: two-phase segment admission (DESIGN.md §13).
        Plans arrive holding REAL cids; admit the whole segment's union
        (one repack of every packed consumer when the slot map changes),
        then rewrite ``plan.idx`` to cache slots — ``plan.cids`` keeps the
        real ids for participation accounting. No-op without a cache."""
        if self.cache is None:
            return plans
        all_cids = (
            np.concatenate([np.asarray(p.idx, np.int64) for p in plans])
            if plans else np.empty((0,), np.int64)
        )
        rp = self.cache.admit(all_cids)
        if rp is not None:
            self.alg.on_cache_repack(self, rp)
            self.alg.on_cache_admit(self, rp)
            self.backend.on_cache_repack(self, rp)
            self._refresh_slot_weights()
        return [
            dataclasses.replace(
                p,
                idx=self.cache.slots_of(np.asarray(p.idx, np.int64)),
                cids=np.asarray(p.idx, np.int64),
            )
            for p in plans
        ]

    # ------------------------------------------------------------------
    def _plan_stream(self, rnd: int, end: int, A: int):
        """Streaming plan generation: yields one ``CohortPlan`` at a time,
        so only cohort-sized plan data is ever alive per draw; ``run``
        materializes at most one segment (≤ backend.max_segment_rounds
        plans) for the jit-resident backends. Draws are identical to the
        historical eager list comprehension (same rng consumption order;
        pinned by tests/test_client_cache.py)."""
        for r in range(rnd, end):
            yield self._draw_plan(r, A)

    # ------------------------------------------------------------------
    def _sample_cohort(self, A: int) -> np.ndarray:
        """A sorted uniform no-replacement cohort draw. ``RandomState.choice
        (replace=False)`` permutes the WHOLE population — an O(n) host cost
        per round that dwarfs the cohort at million-client n — so above the
        lazy threshold the draw switches to Floyd's algorithm: exactly A
        rng draws, O(A) work, still a pure function of the plan rng stream
        (small populations keep the legacy consumption bit-for-bit)."""
        from repro.scenarios.base import LAZY_N

        n = self.n
        if n <= LAZY_N:
            return np.sort(self.rng.choice(n, A, replace=False))
        chosen = set()
        for j in range(n - A, n):
            t = int(self.rng.randint(0, j + 1))
            chosen.add(j if t in chosen else t)
        return np.sort(np.fromiter(chosen, np.int64, len(chosen)))

    def _draw_plan(self, rnd: int, A: int):
        """Roll ALL host randomness for one round into a CohortPlan: cohort
        choice, lr_i/e_i heterogeneity, and per-step minibatch indices — in
        exactly the rng-consumption order of the seed sequential loop, so
        histories are reproducible across backends (and with the seed).
        The scenario's systems axis hooks in here and ONLY here — cohort
        via availability trace, rates via device profiles, windows via
        mid-round dropout — which is exactly what keeps every backend
        consuming scenarios unchanged (DESIGN.md §7)."""
        from repro.sim.engine import CohortPlan

        cfg = self.cfg
        scn = self.scn
        if scn is not None and not self.alg.full_participation_only:
            # availability-trace cohorts can be smaller than A on sparse
            # rounds; full-participation algorithms (ecado) keep the
            # synchronous all-clients draw by definition
            idx = scn.draw_cohort(self.rng, rnd, self.n, A)
        else:
            idx = self._sample_cohort(A)
        A = len(idx)
        if scn is not None and scn.spec.profiles and self.alg.supports_hetero:
            lrs, eps = scn.draw_rates(self.rng, idx)
        elif cfg.hetero is not None and self.alg.supports_hetero:
            lrs, eps = cfg.hetero.sample(self.rng, A)
        else:
            lrs = np.full(A, cfg.lr_fixed, np.float32)
            eps = np.full(A, cfg.epochs_fixed, np.int64)
        n_steps = eps.astype(np.int64) * cfg.steps_per_epoch
        if (
            scn is not None
            and scn.spec.dropout is not None
            and self.alg.supports_hetero
        ):
            # truncation precedes the minibatch draw, so batch_idx and the
            # windows T_i = lr_i·n_steps_i stay consistent on every backend
            n_steps = scn.apply_dropout(self.rng, n_steps)

        bs = cfg.batch_size
        batch_idx = []
        for j, i in enumerate(idx):
            part = self.partitions[int(i)]
            sel = [
                self.rng.choice(part, size=min(bs, len(part)), replace=len(part) < bs)
                for _ in range(int(n_steps[j]))
            ]
            batch_idx.append(np.stack(sel))
        return CohortPlan(
            rnd=rnd, idx=idx, lrs=lrs, epochs=np.asarray(eps),
            n_steps=np.asarray(n_steps), batch_idx=batch_idx,
        )

    # ------------------------------------------------------------------
    def _apply_drift(self) -> None:
        """Scenario concept drift: re-materialize partitions (and any
        per-client statistical transforms) from the pristine dataset and
        refresh the p_i weights. Runs only at segment boundaries
        (``_segment_end`` breaks segments at drift multiples). When a
        transform rewrites the arrays, materialize returns a NEW data dict,
        so identity-keyed device caches (sim/sharded.py) re-upload."""
        self.data, parts = self.scn.materialize(
            self._raw_data, self.n, self.cfg.seed
        )
        self.partitions = list(parts)
        p = data_fractions(self.partitions)
        self.p_hat_full = (p * self.n).astype(np.float32)
        if self.cache is not None:
            self._refresh_slot_weights()
        else:
            self.p_hat = self.p_hat_full

    # ------------------------------------------------------------------
    def _apply_round(self, plan, result) -> Dict[str, Any]:
        """Server aggregation shared by the sequential/vectorized backends
        and the sharded ragged fallback (the event backend interleaves its
        own consensus integration): delegate to the algorithm plugin, then
        build the round's shared telemetry record — the solver stats the
        plugin stashed on device come back in one batched device_get (these
        backends already sync per round, so this adds no sync points)."""
        if self.alg.has_flow_dynamics and not self.comm.lossless:
            # flow family: compress the consensus endpoints against the
            # dispatch reference x_c before the BE round consumes them
            # (EF-free by design — the averaging family hooks compression
            # inside WeightedDeltaAlgorithm.aggregate with residual rows)
            result.x_new_a, _ = self.comm.compress_endpoints(
                self.current_params(), result.x_new_a, None, plan.rnd
            )
        self.alg.aggregate(self, plan, result)
        loss = float(np.mean(result.losses))
        cohort = plan.cohort_size
        bytes_up = cohort * self.comm.payload_up
        bytes_down = cohort * self.comm.payload_down
        stats = self.alg.pop_round_stats()
        if stats is None:
            return make_record(
                plan.rnd, loss=loss, cohort=cohort,
                bytes_up=bytes_up, bytes_down=bytes_down,
            )
        s = jax.device_get(stats)
        return make_record(
            plan.rnd, loss=loss, cohort=cohort,
            substeps=s.n_substeps, backtracks=s.n_backtracks,
            dt_min=s.dt_min, dt_max=s.dt_max, dt_sum=s.dt_sum,
            tau_end=s.tau_end,
            bytes_up=bytes_up, bytes_down=bytes_down,
        )

    # ------------------------------------------------------------------
    def _segment_end(self, rnd: int, rounds: int) -> int:
        """Largest ``end`` such that rounds [rnd, end) can execute without a
        host-side interposition: segments break *after* any round whose eval
        fires (the eval must see that round's params, not the segment's
        end state) and *before* any periodic gain re-estimation. Backends
        get the whole segment at once (``ExecutionBackend.run_rounds``) —
        the sharded backend turns it into a single jit-resident fori_loop.
        """
        cfg = self.cfg
        # bound the host rng (and plan memory) drawn ahead of execution by
        # the backend's appetite: 1 for per-round backends (seed behaviour),
        # larger for the sharded backend's jit-resident segments
        end = min(rounds, rnd + self.backend.max_segment_rounds)
        if cfg.gain_update_every and self.alg.refreshable_gains:
            nxt = ((rnd // cfg.gain_update_every) + 1) * cfg.gain_update_every
            if nxt > rnd:
                end = min(end, nxt)
        if self.scn is not None and self.scn.spec.drift_every:
            # partition drift re-materializes host-side state, so every
            # drift boundary must start a fresh segment
            de = self.scn.spec.drift_every
            end = min(end, ((rnd // de) + 1) * de)
        if self.eval_fn is not None:
            for r in range(rnd, end):
                if r % cfg.eval_every == 0 or r == rounds - 1:
                    end = min(end, r + 1)
                    break
        return max(end, rnd + 1)

    def run(self, rounds: Optional[int] = None) -> RunHistory:
        """Run ``rounds`` rounds and return the structured ``RunHistory``
        (per-round loss + telemetry records, eval metrics, per-client
        participation counts). With ``cfg.log_jsonl``/``cfg.trace_json``
        set, a JSONL run log / Chrome-trace span file is written alongside
        (repro/obs, DESIGN.md §9)."""
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        A = max(1, int(round(cfg.participation * self.n)))
        if self.alg.full_participation_only:
            A = self.n
        history = RunHistory()
        # plan-derived participation: exact for every backend that
        # dispatches the plans verbatim; the event backend overrides it
        # below with its device-exact counts (busy re-draws excluded)
        part_plan = np.zeros((self.n,), np.int64)

        runlog = RunLog(cfg.log_jsonl) if cfg.log_jsonl else None
        recorder = TraceRecorder(cfg.trace_json) if cfg.trace_json else None
        if runlog is not None:
            tune_extra = (
                {"autotune": self.tune_decision.to_dict()}
                if self.tune_decision is not None else {}
            )
            runlog.start(
                config=cfg, algorithm=self.alg.name,
                backend=self.backend.name, n_clients=self.n, rounds=rounds,
                **tune_extra,
            )
        if recorder is not None:
            recorder.install()
        try:
            rnd = 0
            while rnd < rounds:
                if self.scn is not None and self.scn.drift_due(rnd):
                    with span("drift", round=rnd):
                        self._apply_drift()
                if (
                    cfg.gain_update_every
                    and rnd
                    and rnd % cfg.gain_update_every == 0
                    and self.alg.refreshable_gains
                ):
                    with span("gain_refresh", round=rnd):
                        self._install_gains(round_idx=rnd)
                end = self._segment_end(rnd, rounds)
                # all host randomness for the segment up front — same rng
                # consumption order as the per-round loop (run_round does
                # not touch self.rng), so histories are backend-independent
                with span("plan_draw", rounds=end - rnd):
                    plans = self._admit_and_translate(
                        list(self._plan_stream(rnd, end, A))
                    )
                for p in plans:
                    ids = p.cids if p.cids is not None else p.idx
                    part_plan[np.asarray(ids, np.int64)] += 1
                with span("segment", backend=self.backend.name,
                          rounds=end - rnd):
                    recs = self.backend.run_rounds(self, plans)
                for r, rec in zip(range(rnd, end), recs):
                    history.rounds.append(r)
                    history.loss.append(rec["loss"])
                    history.telemetry.append(rec)
                    m = None
                    if self.eval_fn is not None and (
                        r % cfg.eval_every == 0 or r == rounds - 1
                    ):
                        with span("eval", round=r):
                            m = self.eval_fn(self.current_params())
                        history.eval_rounds.append(r)
                        history.metrics.append(m)
                    if runlog is not None:
                        runlog.round(rec, metrics=m)
                rnd = end
            part_dev = self.backend.pop_participation()
            history.participation = (
                part_dev if part_dev is not None else part_plan
            )
            if runlog is not None:
                runlog.summary(history.summary())
        finally:
            if runlog is not None:
                runlog.close()
            if recorder is not None:
                recorder.uninstall()
                recorder.save()
        return history

    def current_params(self) -> Pytree:
        return self.state.x_c if self.state is not None else self.params

"""Federated-learning simulation driver.

Runs any of {fedecado, ecado, fedavg, fedprox, fednova} over a dataset
partitioned across n clients with configurable participation, non-IID
Dirichlet skew, and heterogeneous computation (lr_i, e_i per eqs. 43-44).
Used by the paper-reproduction experiments, examples/ and benchmarks/.

Client execution is delegated to the multi-rate engine in ``repro/sim``
behind the ``ExecutionBackend`` interface — ``FedSimConfig.backend`` picks
``sequential`` (per-client dispatch, the numerical reference oracle),
``vectorized`` (whole cohort in one vmap-over-scan dispatch), ``event``
(continuous-time scheduler with straggler staleness), or ``sharded``
(shard_map over the client mesh axis with psum consensus reductions and
jit-resident multi-round segments). All host-side randomness for a round is
rolled into a ``CohortPlan`` up front so every backend consumes identical
cohorts/batches (DESIGN.md §5); ``run`` hands whole segments of pre-drawn
plans to the backend and only returns to the host at eval / gain-update
boundaries.

Data fractions p_i are normalized as p̂_i = n·p_i (mean 1) so local update
magnitudes stay on the same timescale as the unweighted baselines; this is a
global rescale of the objective (recorded in DESIGN.md) and leaves the
optimum of Σ p_i f_i unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ConsensusConfig,
    init_server_state,
    make_gain,
    hutchinson_scalar,
    server_round,
    set_gains,
)
from repro.fed.baselines import fedavg_aggregate, fednova_aggregate
from repro.fed.client import HeteroConfig
from repro.fed.partition import data_fractions

Pytree = Any

ALGORITHMS = ("fedecado", "ecado", "fedavg", "fedprox", "fednova")


@dataclasses.dataclass
class FedSimConfig:
    algorithm: str = "fedecado"
    n_clients: int = 100
    participation: float = 0.1
    rounds: int = 100
    batch_size: int = 32
    steps_per_epoch: int = 5
    # heterogeneity: if None, every client uses (lr_fixed, epochs_fixed)
    hetero: Optional[HeteroConfig] = None
    lr_fixed: float = 5e-3
    epochs_fixed: int = 2
    mu: float = 0.1                     # FedProx proximal weight
    consensus: ConsensusConfig = dataclasses.field(default_factory=ConsensusConfig)
    dt_ref: float = 0.05                # Δt_ref in Ḡ_th = 1/Δt_ref + p·h̄
    hutchinson_probes: int = 2
    # "scalar": Ḡ_th^i is one gain per client (tr(H)/n estimate);
    # "diag": per-parameter gains via the Hutchinson diagonal (eq. 42 with a
    # diagonal H̄ — the Schur solve stays exact elementwise)
    sensitivity: str = "scalar"
    # paper §4.2: the sensitivity model "can be periodically updated";
    # 0 = precompute once before training (the paper's §5 setting)
    gain_update_every: int = 0
    seed: int = 0
    eval_every: int = 5
    # --- multi-rate execution engine (repro/sim, DESIGN.md §5) ---
    backend: str = "sequential"     # sequential | vectorized | event | sharded
    # event backend: quantile of in-flight windows absorbed per round
    # (< 1.0 leaves stragglers in the queue -> mid-round returns next round)
    event_horizon: float = 1.0
    event_max_waves: int = 4        # BE sync groups per round
    # fuse the fedavg/fedprox/fednova cohort aggregation with the Pallas
    # batched-aggregation kernel (kernels/batch_agg.py)
    agg_kernels: bool = False
    # sharded backend: force the cohort padding unit above the device count
    # (DESIGN.md §5.5) — lets tests exercise uneven client→device padding
    # even on a single-device host; None = pad to the device count
    sharded_pad_multiple: Optional[int] = None


class FedSim:
    """Simulates federated training of a (small) model on CPU."""

    def __init__(
        self,
        loss_fn: Callable,                 # loss_fn(params, batch) -> scalar
        params0: Pytree,
        data: Dict[str, np.ndarray],       # {"x": (N, ...), "y": (N,)}
        partitions: Sequence[np.ndarray],  # per-client index arrays
        cfg: FedSimConfig,
        eval_fn: Optional[Callable] = None,  # eval_fn(params) -> dict metrics
    ):
        assert cfg.algorithm in ALGORITHMS, cfg.algorithm
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.data = data
        self.partitions = list(partitions)
        self.n = cfg.n_clients
        assert len(self.partitions) == self.n
        self.eval_fn = eval_fn
        self.rng = np.random.RandomState(cfg.seed)

        p = data_fractions(self.partitions)
        self.p_hat = (p * self.n).astype(np.float32)   # mean-1 normalization

        self.params = jax.tree.map(lambda l: l.astype(jnp.float32), params0)
        self.state = None
        if cfg.algorithm in ("fedecado", "ecado"):
            self.state = init_server_state(self.params, self.n, cfg.consensus.dt_init)
            self._install_gains()

        self._round_fn = jax.jit(
            partial(server_round, ccfg=cfg.consensus), static_argnums=()
        )
        from repro.sim.engine import get_backend  # lazy: sim imports fed.client

        self.backend = get_backend(cfg)

    # ------------------------------------------------------------------
    def _install_gains(self, round_idx: int = 0):
        """(Re)compute Ḡ_th per client (paper §4.2, eq. 42). By default
        precomputed once before training (the paper's §5 setting); with
        ``gain_update_every > 0`` re-estimated periodically."""
        cfg = self.cfg
        if cfg.algorithm == "ecado":
            g = jnp.ones((self.n,), jnp.float32) / (1.0 / cfg.dt_ref)
            self.state = set_gains(self.state, g)
            return
        key = jax.random.PRNGKey(cfg.seed + 17 + round_idx)
        params = self.state.x_c if round_idx else self.params

        if cfg.sensitivity == "diag":
            from repro.core import hutchinson_diag

            hfn = jax.jit(
                lambda p, b, k: hutchinson_diag(
                    self.loss_fn, p, b, k, cfg.hutchinson_probes
                )
            )
            g_rows = []
            for i in range(self.n):
                batch = self._client_batch(i, cfg.batch_size)
                diag = hfn(params, batch, jax.random.fold_in(key, i))
                G_i = jax.tree.map(
                    lambda h, p_i=float(self.p_hat[i]): 1.0 / cfg.dt_ref
                    + p_i * jnp.maximum(h, 0.0),
                    diag,
                )
                g_rows.append(jax.tree.map(lambda g: 1.0 / g, G_i))
            g_inv = jax.tree.map(lambda *rows: jnp.stack(rows), *g_rows)
            self.state = set_gains(self.state, g_inv)
            return

        h_bars = np.zeros((self.n,), np.float32)
        hfn = jax.jit(
            lambda p, b, k: hutchinson_scalar(
                self.loss_fn, p, b, k, cfg.hutchinson_probes
            )
        )
        for i in range(self.n):
            batch = self._client_batch(i, cfg.batch_size)
            h = hfn(params, batch, jax.random.fold_in(key, i))
            h_bars[i] = float(np.maximum(h, 0.0))
        G = 1.0 / cfg.dt_ref + self.p_hat * h_bars          # eq. 42
        self.state = set_gains(self.state, jnp.asarray(1.0 / G, jnp.float32))
        self.h_bars = h_bars

    # ------------------------------------------------------------------
    def _client_batch(self, i: int, bs: int):
        idx = self.partitions[i]
        sel = self.rng.choice(idx, size=min(bs, len(idx)), replace=len(idx) < bs)
        return {k: jnp.asarray(v[sel]) for k, v in self.data.items()}

    # ------------------------------------------------------------------
    def _draw_plan(self, rnd: int, A: int):
        """Roll ALL host randomness for one round into a CohortPlan: cohort
        choice, lr_i/e_i heterogeneity, and per-step minibatch indices — in
        exactly the rng-consumption order of the seed sequential loop, so
        histories are reproducible across backends (and with the seed)."""
        from repro.sim.engine import CohortPlan

        cfg = self.cfg
        idx = np.sort(self.rng.choice(self.n, A, replace=False))
        if cfg.hetero is not None and cfg.algorithm != "ecado":
            lrs, eps = cfg.hetero.sample(self.rng, A)
        else:
            lrs = np.full(A, cfg.lr_fixed, np.float32)
            eps = np.full(A, cfg.epochs_fixed, np.int64)
        n_steps = eps.astype(np.int64) * cfg.steps_per_epoch

        bs = cfg.batch_size
        batch_idx = []
        for j, i in enumerate(idx):
            part = self.partitions[int(i)]
            sel = [
                self.rng.choice(part, size=min(bs, len(part)), replace=len(part) < bs)
                for _ in range(int(n_steps[j]))
            ]
            batch_idx.append(np.stack(sel))
        return CohortPlan(
            rnd=rnd, idx=idx, lrs=lrs, epochs=np.asarray(eps),
            n_steps=np.asarray(n_steps), batch_idx=batch_idx,
        )

    # ------------------------------------------------------------------
    def _apply_round(self, plan, result) -> Dict[str, Any]:
        """Server aggregation shared by the sequential/vectorized backends
        (the event backend interleaves its own consensus integration)."""
        cfg = self.cfg
        x_new_a = result.x_new_a
        p_a = jnp.asarray(self.p_hat[plan.idx], jnp.float32)

        if cfg.algorithm in ("fedecado", "ecado"):
            self.state, _stats = self._round_fn(
                self.state,
                x_new_a,
                jnp.asarray(result.Ts, jnp.float32),
                jnp.asarray(plan.idx, jnp.int32),
            )
        elif cfg.algorithm == "fednova":
            tau_a = jnp.asarray(result.taus, jnp.float32)
            if cfg.agg_kernels:
                from repro.kernels import batched_aggregate

                p = p_a / jnp.maximum(jnp.sum(p_a), 1e-12)
                tau_eff = jnp.sum(p * tau_a)
                self.params = batched_aggregate(
                    self.params, x_new_a, p / jnp.maximum(tau_a, 1.0), tau_eff
                )
            else:
                self.params = fednova_aggregate(self.params, x_new_a, p_a, tau_a)
        else:  # fedavg / fedprox
            if cfg.agg_kernels:
                from repro.kernels import batched_aggregate

                w = p_a / jnp.maximum(jnp.sum(p_a), 1e-12)
                self.params = batched_aggregate(self.params, x_new_a, w)
            else:
                self.params = fedavg_aggregate(self.params, x_new_a, p_a)
        return {"loss": float(np.mean(result.losses))}

    # ------------------------------------------------------------------
    def _segment_end(self, rnd: int, rounds: int) -> int:
        """Largest ``end`` such that rounds [rnd, end) can execute without a
        host-side interposition: segments break *after* any round whose eval
        fires (the eval must see that round's params, not the segment's
        end state) and *before* any periodic gain re-estimation. Backends
        get the whole segment at once (``ExecutionBackend.run_rounds``) —
        the sharded backend turns it into a single jit-resident fori_loop.
        """
        cfg = self.cfg
        # bound the host rng (and plan memory) drawn ahead of execution by
        # the backend's appetite: 1 for per-round backends (seed behaviour),
        # larger for the sharded backend's jit-resident segments
        end = min(rounds, rnd + self.backend.max_segment_rounds)
        if cfg.gain_update_every and cfg.algorithm == "fedecado":
            nxt = ((rnd // cfg.gain_update_every) + 1) * cfg.gain_update_every
            if nxt > rnd:
                end = min(end, nxt)
        if self.eval_fn is not None:
            for r in range(rnd, end):
                if r % cfg.eval_every == 0 or r == rounds - 1:
                    end = min(end, r + 1)
                    break
        return max(end, rnd + 1)

    def run(self, rounds: Optional[int] = None) -> Dict[str, list]:
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        A = max(1, int(round(cfg.participation * self.n)))
        if cfg.algorithm == "ecado":
            A = self.n  # full participation by definition
        history: Dict[str, list] = {"round": [], "loss": [], "metrics": []}

        rnd = 0
        while rnd < rounds:
            if (
                cfg.gain_update_every
                and rnd
                and rnd % cfg.gain_update_every == 0
                and cfg.algorithm == "fedecado"
            ):
                self._install_gains(round_idx=rnd)
            end = self._segment_end(rnd, rounds)
            # all host randomness for the segment up front — same rng
            # consumption order as the per-round loop (run_round does not
            # touch self.rng), so histories are backend-independent
            plans = [self._draw_plan(r, A) for r in range(rnd, end)]
            recs = self.backend.run_rounds(self, plans)
            for r, rec in zip(range(rnd, end), recs):
                history["round"].append(r)
                history["loss"].append(rec["loss"])
                if self.eval_fn is not None and (
                    r % cfg.eval_every == 0 or r == rounds - 1
                ):
                    m = self.eval_fn(self.current_params())
                    history["metrics"].append((r, m))
            rnd = end
        return history

    def current_params(self) -> Pytree:
        return self.state.x_c if self.state is not None else self.params

"""FedECADO and ECADO as plugins: the flow-dynamics family.

Owns everything ``FedSim`` used to hardwire for the two: the ``ServerState``
(central params + per-client flow variables I_i + gains), the sensitivity
gain estimation Ḡ_th = 1/Δt_ref + p̂·h̄ (paper §4.2, eq. 42; scalar
Hutchinson trace or per-parameter diagonal), and the consensus aggregation
(Backward-Euler adaptive integration of the central ODE, Algorithm 2 steps
12-16). ECADO is the §4 ablation: full participation, uniform gains,
synchronous clients (no heterogeneity), unweighted local objectives.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.algorithms.base import FederatedAlgorithm


class FedECADO(FederatedAlgorithm):
    name = "fedecado"
    has_flow_dynamics = True
    refreshable_gains = True
    client_kind = "fedecado"

    # ------------------------------------------------------------- client --
    def client_weights(self, sim, idx):
        return sim.p_hat[idx].astype(np.float32)

    def client_rows(self, sim, idx):
        return jax.tree.map(lambda l: l[jnp.asarray(idx)], sim.state.I)

    # ------------------------------------------------------------- server --
    def init_state(self, sim) -> None:
        from repro.core import init_server_state, server_round

        cfg = sim.cfg
        sim.state = init_server_state(sim.params, sim.n, cfg.consensus.dt_init)
        self._round_fn = jax.jit(
            partial(server_round, ccfg=cfg.consensus), static_argnums=()
        )
        self.install_gains(sim)

    def install_gains(self, sim, round_idx: int = 0) -> None:
        """(Re)compute Ḡ_th per client (paper §4.2, eq. 42). By default
        precomputed once before training (the paper's §5 setting); with
        ``gain_update_every > 0`` re-estimated periodically."""
        from repro.core import hutchinson_scalar, set_gains

        cfg = sim.cfg
        key = jax.random.PRNGKey(cfg.seed + 17 + round_idx)
        params = sim.state.x_c if round_idx else sim.params

        if cfg.sensitivity == "diag":
            from repro.core import hutchinson_diag

            hfn = jax.jit(
                lambda p, b, k: hutchinson_diag(
                    sim.loss_fn, p, b, k, cfg.hutchinson_probes
                )
            )
            g_rows = []
            for i in range(sim.n):
                batch = sim._client_batch(i, cfg.batch_size)
                diag = hfn(params, batch, jax.random.fold_in(key, i))
                G_i = jax.tree.map(
                    lambda h, p_i=float(sim.p_hat[i]): 1.0 / cfg.dt_ref
                    + p_i * jnp.maximum(h, 0.0),
                    diag,
                )
                g_rows.append(jax.tree.map(lambda g: 1.0 / g, G_i))
            g_inv = jax.tree.map(lambda *rows: jnp.stack(rows), *g_rows)
            sim.state = set_gains(sim.state, g_inv)
            return

        h_bars = np.zeros((sim.n,), np.float32)
        hfn = jax.jit(
            lambda p, b, k: hutchinson_scalar(
                sim.loss_fn, p, b, k, cfg.hutchinson_probes
            )
        )
        for i in range(sim.n):
            batch = sim._client_batch(i, cfg.batch_size)
            h = hfn(params, batch, jax.random.fold_in(key, i))
            h_bars[i] = float(np.maximum(h, 0.0))
        G = 1.0 / cfg.dt_ref + sim.p_hat * h_bars          # eq. 42
        sim.state = set_gains(sim.state, jnp.asarray(1.0 / G, jnp.float32))
        sim.h_bars = h_bars

    # -------------------------------------------------------- aggregation --
    def aggregate(self, sim, plan, result) -> None:
        sim.state, stats = self._round_fn(
            sim.state,
            result.x_new_a,
            jnp.asarray(result.Ts, jnp.float32),
            jnp.asarray(plan.idx, jnp.int32),
        )
        # stashed on-device; fed/server.py pops it into the round's shared
        # telemetry record with one batched device_get alongside the loss
        self._last_round_stats = stats

    def pop_round_stats(self):
        stats = getattr(self, "_last_round_stats", None)
        self._last_round_stats = None
        return stats


class ECADO(FedECADO):
    name = "ecado"
    supports_hetero = False          # synchronous clients by definition
    full_participation_only = True
    refreshable_gains = False

    def client_weights(self, sim, idx):
        return np.ones(np.shape(idx), np.float32)

    def install_gains(self, sim, round_idx: int = 0) -> None:
        from repro.core import set_gains

        g = jnp.ones((sim.n,), jnp.float32) / (1.0 / sim.cfg.dt_ref)
        sim.state = set_gains(sim.state, g)

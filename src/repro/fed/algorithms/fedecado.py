"""FedECADO and ECADO as plugins: the flow-dynamics family.

Owns everything ``FedSim`` used to hardwire for the two: the ``ServerState``
(central params + per-client flow variables I_i + gains), the sensitivity
gain estimation Ḡ_th = 1/Δt_ref + p̂·h̄ (paper §4.2, eq. 42; scalar
Hutchinson trace or per-parameter diagonal), and the consensus aggregation
(Backward-Euler adaptive integration of the central ODE, Algorithm 2 steps
12-16). ECADO is the §4 ablation: full participation, uniform gains,
synchronous clients (no heterogeneity), unweighted local objectives.
"""
from __future__ import annotations

import weakref
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.algorithms.base import FederatedAlgorithm

# jitted batched-Hutchinson maps, weakly keyed by loss_fn so repeated sims
# over the same problem (e.g. a bench warm run + timed run) share the
# compiled executable instead of re-tracing per FedSim instance
_HMAPS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _hutchinson_map(loss_fn, probes: int):
    from repro.core import hutchinson_scalar

    per = _HMAPS.setdefault(loss_fn, {})
    fn = per.get(probes)
    if fn is None:
        fn = jax.jit(
            lambda p, bs, ks: jax.lax.map(
                lambda bk: hutchinson_scalar(
                    loss_fn, p, bk[0], bk[1], probes
                ),
                (bs, ks),
            )
        )
        per[probes] = fn
    return fn


class FedECADO(FederatedAlgorithm):
    name = "fedecado"
    has_flow_dynamics = True
    refreshable_gains = True
    client_kind = "fedecado"

    # ------------------------------------------------------------- client --
    def client_weights(self, sim, idx):
        return sim.p_hat[idx].astype(np.float32)

    def client_rows(self, sim, idx):
        return jax.tree.map(lambda l: l[jnp.asarray(idx)], sim.state.I)

    # ------------------------------------------------------------- server --
    def init_state(self, sim) -> None:
        from repro.core import init_server_state, server_round

        cfg = sim.cfg
        sim.state = init_server_state(
            sim.params, sim.state_rows, cfg.consensus.dt_init
        )
        self._round_fn = jax.jit(
            partial(server_round, ccfg=cfg.consensus), static_argnums=()
        )
        self.install_gains(sim)

    def install_gains(self, sim, round_idx: int = 0) -> None:
        """(Re)compute Ḡ_th per client (paper §4.2, eq. 42). By default
        precomputed once before training (the paper's §5 setting); with
        ``gain_update_every > 0`` re-estimated periodically. In
        client_cache mode only ADMITTED clients are estimated — the
        (params, key) reference is stashed so late joiners get the exact
        gain the materialized run would have given them (DESIGN.md §13)."""
        cfg = sim.cfg
        key = jax.random.PRNGKey(cfg.seed + 17 + round_idx)
        params = sim.state.x_c if round_idx else sim.params
        # admission-time reference for lazily-admitted clients: frozen
        # device values, so later x_c evolution cannot leak in
        self._gain_ref = (params, key, round_idx)
        if sim.cache is not None:
            cids = sim.cache.cids
            if len(cids):
                self._set_gain_rows(
                    sim, cids, np.arange(len(cids)), params, key, round_idx
                )
            return
        ids = np.arange(sim.n)
        self._set_gain_rows(sim, ids, ids, params, key, round_idx)

    def _set_gain_rows(
        self, sim, cids, slots, params, key, round_idx
    ) -> None:
        """Estimate Ḡ_th for ``cids`` and write 1/Ḡ into g_inv rows at
        ``slots``. Per-client arithmetic is independent (deterministic
        per-cid minibatch via ``sim._gain_batch`` + ``fold_in(key, cid)``),
        so a lazily-admitted subset computes bitwise the same rows a full
        materialized pass would."""
        from repro.core import set_gains

        cfg = sim.cfg
        slots = jnp.asarray(np.asarray(slots, np.int64))

        if cfg.sensitivity == "diag":
            from repro.core import hutchinson_diag

            hfn = jax.jit(
                lambda p, b, k: hutchinson_diag(
                    sim.loss_fn, p, b, k, cfg.hutchinson_probes
                )
            )
            g_rows = []
            for i in cids:
                batch = sim._gain_batch(int(i), cfg.batch_size, round_idx)
                diag = hfn(params, batch, jax.random.fold_in(key, int(i)))
                G_i = jax.tree.map(
                    lambda h, p_i=float(sim.p_hat_full[int(i)]):
                    1.0 / cfg.dt_ref + p_i * jnp.maximum(h, 0.0),
                    diag,
                )
                g_rows.append(jax.tree.map(lambda g: 1.0 / g, G_i))
            rows = jax.tree.map(lambda *r: jnp.stack(r), *g_rows)
            cur = sim.state.g_inv
            mismatch = (
                jax.tree.structure(cur) != jax.tree.structure(rows)
                or any(
                    c.shape[1:] != r.shape[1:]
                    for c, r in zip(jax.tree.leaves(cur), jax.tree.leaves(rows))
                )
            )
            if mismatch:
                # first diag install: g_inv is still the scalar placeholder
                # from init_server_state — allocate the per-parameter layout
                cur = jax.tree.map(
                    lambda r: jnp.zeros(
                        (sim.state_rows,) + r.shape[1:], r.dtype
                    ),
                    rows,
                )
            g_inv = jax.tree.map(lambda c, r: c.at[slots].set(r), cur, rows)
            sim.state = set_gains(sim.state, g_inv)
            return

        # Batched scalar path: one lax.map over the stacked per-cid
        # minibatches instead of a jit dispatch + host sync per client —
        # a cohort-sized admission (10^2-10^3 fresh cids per segment at
        # sparse participation) would otherwise pay seconds of pure
        # dispatch overhead. The map body is a single compiled function
        # applied per element with no cross-element ops, so each h̄ is
        # invariant to how admissions are grouped — the property the
        # cached==materialized bitwise contract rests on. Stacks are
        # grouped by batch shape (ragged partitions can't stack) and
        # padded to the next power of two so recompiles stay O(log A).
        h_bars = np.zeros((len(cids),), np.float32)
        batches = [
            sim._gain_batch(int(i), cfg.batch_size, round_idx) for i in cids
        ]
        by_shape: dict = {}
        for j, b in enumerate(batches):
            shp = tuple(sorted((k, v.shape) for k, v in b.items()))
            by_shape.setdefault(shp, []).append(j)
        hmap = _hutchinson_map(sim.loss_fn, cfg.hutchinson_probes)
        for js in by_shape.values():
            m = 1
            while m < len(js):
                m <<= 1
            pad = [js[0]] * (m - len(js))
            rows_j = js + pad
            stacked = {
                k: jnp.stack([batches[j][k] for j in rows_j])
                for k in batches[js[0]]
            }
            ks = jnp.stack(
                [jax.random.fold_in(key, int(cids[j])) for j in rows_j]
            )
            hs = np.asarray(hmap(params, stacked, ks), np.float32)
            h_bars[np.asarray(js, np.int64)] = np.maximum(
                hs[: len(js)], 0.0
            )
        p_rows = sim.p_hat_full[np.asarray(cids, np.int64)]
        G = 1.0 / cfg.dt_ref + p_rows * h_bars             # eq. 42
        rows = np.asarray(1.0 / G, np.float32)
        g = sim.state.g_inv.at[slots].set(jnp.asarray(rows))
        sim.state = set_gains(sim.state, g)

    # ------------------------------------------- client-state cache hooks --
    def on_cache_repack(self, sim, repack) -> None:
        from repro.sim.cache import repack_rows

        st = sim.state
        sim.state = st._replace(
            I=repack_rows(st.I, repack),
            g_inv=repack_rows(st.g_inv, repack),
        )
        super().on_cache_repack(sim, repack)

    def on_cache_admit(self, sim, repack) -> None:
        if repack.fresh_cids.size == 0:
            return
        params, key, round_idx = self._gain_ref
        self._set_gain_rows(
            sim, repack.fresh_cids, repack.fresh, params, key, round_idx
        )

    # -------------------------------------------------------- aggregation --
    def aggregate(self, sim, plan, result) -> None:
        sim.state, stats = self._round_fn(
            sim.state,
            result.x_new_a,
            jnp.asarray(result.Ts, jnp.float32),
            jnp.asarray(plan.idx, jnp.int32),
        )
        # stashed on-device; fed/server.py pops it into the round's shared
        # telemetry record with one batched device_get alongside the loss
        self._last_round_stats = stats

    def pop_round_stats(self):
        stats = getattr(self, "_last_round_stats", None)
        self._last_round_stats = None
        return stats


class ECADO(FedECADO):
    name = "ecado"
    supports_hetero = False          # synchronous clients by definition
    full_participation_only = True
    refreshable_gains = False

    def client_weights(self, sim, idx):
        return np.ones(np.shape(idx), np.float32)

    def install_gains(self, sim, round_idx: int = 0) -> None:
        from repro.core import set_gains

        g = jnp.ones((sim.state_rows,), jnp.float32) / (1.0 / sim.cfg.dt_ref)
        sim.state = set_gains(sim.state, g)

    def on_cache_admit(self, sim, repack) -> None:
        # uniform gains: refill the whole (constant) array — fresh slots
        # were zeroed by the repack
        self.install_gains(sim)

"""``FederatedAlgorithm`` protocol: everything an algorithm owns.

A federated algorithm, to this codebase, is four things:

  1. a **client step** — the name of a registered client kind
     (fed/client.py) plus the scalar ``mu`` its gradient addend closes
     over, the per-client objective weights p_i, and (for kinds with
     ``takes_flow``) the per-client state rows the step consumes;
  2. **server state** — either the FedECADO ``ServerState`` (flow
     variables + gains, installed via ``init_state``/``install_gains``)
     or algorithm-owned per-client rows (``has_client_state``, e.g.
     FedADMM's dual variables) living on the algorithm instance;
  3. an **aggregation rule** — for the averaging family a
     (weights, scale, endpoint-transform) spec applied through ONE shared
     weighted-delta primitive (dense or Pallas-fused or psum-sharded);
     for the flow family the Backward-Euler consensus round;
  4. **capability flags** the execution backends query instead of
     string-matching algorithm names: ``has_flow_dynamics`` (event-backend
     eligibility + consensus aggregation), ``supports_hetero``,
     ``full_participation_only`` and ``has_client_state``.

Backends (repro/sim) never branch on ``cfg.algorithm``; they ask the
instance at ``sim.alg``. Registration lives in fed/algorithms/__init__.py.
"""
from __future__ import annotations

from typing import Any, ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


class FederatedAlgorithm:
    """Base protocol. Subclass, set ``name`` + capability flags, implement
    ``aggregate`` (or inherit the weighted-delta family below), and decorate
    with ``@register`` (fed/algorithms/__init__.py)."""

    name: ClassVar[str] = "base"

    # --- capability flags (class-level: queryable without instantiation) ---
    has_flow_dynamics: ClassVar[bool] = False   # consensus aggregation + event backend
    supports_hetero: ClassVar[bool] = True      # heterogeneous (lr_i, e_i) draws
    full_participation_only: ClassVar[bool] = False
    has_client_state: ClassVar[bool] = False    # algorithm-owned per-client rows
    refreshable_gains: ClassVar[bool] = False   # periodic Ḡ_th re-estimation
    client_kind: ClassVar[str] = "sgd"          # key into fed/client.py registry

    def __init__(self, cfg=None):
        self.cfg = cfg
        # the wire model (repro.comm.CommSpec) FedSim binds after it knows
        # the model, plus the error-feedback residual rows it allocates when
        # the compressor calls for them (averaging family only) — None until
        # then so direct-construction tests stay valid
        self.comm = None
        self.comm_state = None

    # ------------------------------------------------------------- client --
    def client_mu(self) -> float:
        """Scalar the client kind's gradient addend closes over (FedProx's
        proximal weight, FedADMM's ρ); 0.0 when unused."""
        return 0.0

    def client_weights(self, sim, idx: np.ndarray) -> np.ndarray:
        """Per-client local objective weights p_i, same shape as ``idx``
        (fp32 numpy; call sites convert). Default: unweighted."""
        return np.ones(np.shape(idx), np.float32)

    def client_rows(self, sim, idx) -> Optional[Pytree]:
        """Per-client state rows the client step consumes, leaves
        (A, ...) gathered at ``idx`` — FedECADO's flow variables, FedADMM's
        duals — or None for stateless kinds."""
        return None

    # ------------------------------------------------------------- server --
    def init_state(self, sim) -> None:
        """Install server-side state on ``sim`` (and/or the instance) at
        construction. Host rng drawn here must keep the documented
        consumption order (fed/server.py::FedSim)."""
        return None

    def install_gains(self, sim, round_idx: int = 0) -> None:
        """(Re)compute sensitivity gains; only meaningful for flow
        algorithms."""
        return None

    # -------------------------------------------------------- aggregation --
    def aggregate(self, sim, plan, result) -> None:
        """Dense server aggregation for one round: consume the cohort's
        ``CohortResult`` and update ``sim.state`` / ``sim.params`` (and any
        algorithm-owned rows). Shared by the sequential and vectorized
        backends and the sharded ragged fallback; the sharded segment path
        replays the same spec inside ``shard_map`` (DESIGN.md §6)."""
        raise NotImplementedError

    def pop_round_stats(self):
        """Device-resident solver stats stashed by the last ``aggregate``
        (a ``core.fedecado.RoundStats``), or None for algorithms without an
        adaptive solver. ``FedSim._apply_round`` pops them into the round's
        shared telemetry record with one batched device_get."""
        return None

    # ------------------------------------------- client-state cache hooks --
    def on_cache_repack(self, sim, repack) -> None:
        """Client-state-cache hook (sim/cache.py, DESIGN.md §13): the packed
        per-client layout changed — permute every algorithm-owned packed
        pytree to the new slot map. Fresh slots come back exactly zero;
        ``on_cache_admit`` then fills any that need non-zero values."""
        from repro.sim.cache import repack_rows  # lazy: fed↔sim

        if self.comm_state is not None:
            self.comm_state = repack_rows(self.comm_state, repack)

    def on_cache_admit(self, sim, repack) -> None:
        """Fill freshly admitted slots whose correct initial value is not
        zero (FedECADO's gains). Default: zeros are already right — duals
        and EF residuals start at zero by definition."""
        return None


# ---------------------------------------------------------------------------
# the shared weighted-delta aggregation primitive
# ---------------------------------------------------------------------------


def weighted_delta(x_c: Pytree, x_new_a: Pytree, weights: jax.Array) -> Pytree:
    """Σ_a w_a (x_a − x_c) per leaf; weights (A,) normalized by caller."""

    def leaf(xc, xa):
        w = weights.reshape((-1,) + (1,) * (xa.ndim - 1)).astype(jnp.float32)
        return jnp.sum(
            w * (xa.astype(jnp.float32) - xc.astype(jnp.float32)[None]), axis=0
        )

    return jax.tree.map(leaf, x_c, x_new_a)


def apply_weighted_delta(
    x_c: Pytree,
    y_a: Pytree,
    w: jax.Array,
    scale,
    use_kernel: bool = False,
) -> Pytree:
    """x_c ← x_c + scale·Σ_a w_a (y_a − x_c) — THE dense aggregation entry
    for the averaging family. ``use_kernel`` routes through the fused Pallas
    batched-aggregation kernel (kernels/batch_agg.py); the plain path is the
    per-leaf jnp reduction. Both consume the same (w, scale) spec, so kernel
    fusion is a property of the call, not a per-algorithm fork."""
    if use_kernel:
        from repro.kernels import batched_aggregate

        return batched_aggregate(x_c, y_a, w, scale)
    delta = weighted_delta(x_c, y_a, w)
    return jax.tree.map(lambda xc, d: xc + scale * d, x_c, delta)


class WeightedDeltaAlgorithm(FederatedAlgorithm):
    """Averaging family: aggregation is a weighted delta of (optionally
    transformed) client endpoints. Subclasses override ``agg_weights`` (the
    one place their weight math lives) and optionally ``agg_transform``
    (endpoint rewrite + per-client state update, e.g. FedADMM's duals).

    ``agg_weights`` is written array-module-generically (``xp`` = jnp or
    np) and shape-generically (operates on the last axis), so the dense
    per-round path (1-D (A,)) and the sharded backend's host precompute
    (batched (R, A_pad), padding pre-zeroed via the cohort mask) share the
    exact same lines.
    """

    def agg_weights(self, p_a, taus, xp=jnp) -> Tuple[Any, Any]:
        """(..., A) masked data weights + local step counts → per-client
        aggregation weights w (..., A) and update scale (...,)."""
        raise NotImplementedError

    def agg_transform(
        self, x_c: Pytree, x_new_a: Pytree, rows: Optional[Pytree]
    ) -> Tuple[Pytree, Optional[Pytree]]:
        """Rewrite cohort endpoints before the weighted delta and produce
        updated per-client state rows. Must be elementwise per client row
        (it also runs device-local inside the sharded backend's shard_map
        program). Default: identity endpoints, rows passed through unchanged
        — so a ``has_client_state`` plugin that overrides only part of the
        spec gets no-op state writes on every backend instead of a silent
        skip on the dense path and a tree-structure crash in shard_map."""
        return x_new_a, rows

    # -- algorithm-owned per-client state (has_client_state) ---------------
    def init_client_state(self, params: Pytree, n: int) -> Pytree:
        """Fresh per-client rows, leaves (n, ...): zeros by default."""
        return jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params
        )

    def init_state(self, sim) -> None:
        if self.has_client_state:
            self.client_state = self.init_client_state(
                sim.params, sim.state_rows
            )

    def on_cache_repack(self, sim, repack) -> None:
        from repro.sim.cache import repack_rows  # lazy: fed↔sim

        if getattr(self, "client_state", None) is not None:
            self.client_state = repack_rows(self.client_state, repack)
        super().on_cache_repack(sim, repack)

    def client_rows(self, sim, idx) -> Optional[Pytree]:
        if not self.has_client_state:
            return None
        return jax.tree.map(lambda l: l[jnp.asarray(idx)], self.client_state)

    def set_client_state(self, state: Pytree) -> None:
        """Install updated rows wholesale (the sharded segment returns the
        full (n, ...) tensor from its jit-resident fori_loop)."""
        self.client_state = state

    # -- error-feedback residual rows (comm, DESIGN.md §11) ----------------
    def comm_rows(self, idx) -> Optional[Pytree]:
        """Per-client error-feedback residual rows gathered at ``idx``
        (leaves (A, ...)), or None when the wire is lossless / EF-free.
        Same gather as ``client_rows`` — residuals are algorithm-owned rows
        exactly like FedADMM's duals, just keyed by the compressor."""
        if self.comm_state is None:
            return None
        return jax.tree.map(lambda l: l[jnp.asarray(idx)], self.comm_state)

    def set_comm_state(self, state: Pytree) -> None:
        """Install updated residual rows wholesale (sharded segment)."""
        self.comm_state = state

    # -- dense aggregation -------------------------------------------------
    def aggregate(self, sim, plan, result) -> None:
        p_a = jnp.asarray(sim.p_hat[plan.idx], jnp.float32)
        tau_a = jnp.asarray(result.taus, jnp.float32)
        w, scale = self.agg_weights(p_a, tau_a)
        rows = self.client_rows(sim, plan.idx)
        x_new_a = result.x_new_a
        comm = self.comm
        if comm is not None and not comm.lossless:
            # compress the cohort endpoints against the broadcast reference
            # BEFORE the endpoint transform, so the transform (and the one
            # shared weighted-delta) consumes exactly what the wire carried
            ef = self.comm_rows(plan.idx)
            x_new_a, ef_new = comm.compress_endpoints(
                sim.params, x_new_a, ef, plan.rnd
            )
            if ef_new is not None:
                from repro.core.flow import put_rows

                self.comm_state = put_rows(
                    self.comm_state, jnp.asarray(plan.idx), ef_new
                )
        y_a, new_rows = self.agg_transform(sim.params, x_new_a, rows)
        sim.params = apply_weighted_delta(
            sim.params, y_a, w, scale, use_kernel=sim.cfg.agg_kernels
        )
        if new_rows is not None:
            from repro.core.flow import put_rows

            self.client_state = put_rows(
                self.client_state, jnp.asarray(plan.idx), new_rows
            )

"""The averaging baselines as plugins: FedAvg, FedProx, FedNova.

* FedAvg   (McMahan et al. 2017): data-weighted average of client deltas.
* FedProx  (Li et al. 2020): FedAvg aggregation; the μ-proximal term lives
  in the client step (the ``fedprox`` client kind, fed/client.py).
* FedNova  (Wang et al. 2020): normalized averaging — each client's delta is
  divided by its local step count τ_i, then recombined with an effective
  step Σ p̃_i τ_i, removing objective inconsistency under heterogeneous e_i.

``fedavg_weights``/``fednova_weights`` are THE single home of the
p/Σp / τ_eff weight math — the dense per-round path, the Pallas-fused
kernel path, the sharded backend's host precompute, and the public
``fed.baselines`` helpers all call these two functions.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.fed.algorithms.base import WeightedDeltaAlgorithm


def fedavg_weights(p_a, taus=None, xp=jnp):
    """w = p̃ = p/Σp, scale 1. Shape-generic over the last axis; ``xp``
    picks the array module (jnp for the jit paths, np for the sharded
    backend's host precompute)."""
    p = p_a / xp.maximum(
        xp.sum(p_a, axis=-1, keepdims=True), np.float32(1e-12)
    )
    return p, xp.ones(p.shape[:-1], np.float32)


def fednova_weights(p_a, taus, xp=jnp):
    """w = p̃/max(τ, 1), scale τ_eff = Σ p̃ τ (normalized averaging)."""
    p = p_a / xp.maximum(
        xp.sum(p_a, axis=-1, keepdims=True), np.float32(1e-12)
    )
    tau = taus.astype(np.float32)
    tau_eff = xp.sum(p * tau, axis=-1)
    w = p / xp.maximum(tau, np.float32(1.0))
    return w, tau_eff


class FedAvg(WeightedDeltaAlgorithm):
    name = "fedavg"
    client_kind = "sgd"

    def agg_weights(self, p_a, taus, xp=jnp):
        return fedavg_weights(p_a, taus, xp=xp)


class FedProx(FedAvg):
    name = "fedprox"
    client_kind = "fedprox"

    def client_mu(self) -> float:
        return float(self.cfg.mu)


class FedNova(FedAvg):
    name = "fednova"
    client_kind = "sgd"

    def agg_weights(self, p_a, taus, xp=jnp):
        return fednova_weights(p_a, taus, xp=xp)

"""First-class federated-algorithm plugin registry (DESIGN.md §6).

Every comparison algorithm is a ``FederatedAlgorithm`` subclass registered
here by name. The registry is the ONLY place algorithm names are resolved:
``FedSim`` instantiates via ``make_algorithm(cfg)``, the execution backends
(repro/sim) query capability flags on ``sim.alg`` instead of string-matching
names, and the CLI entry points enumerate ``available_algorithms()`` for
their ``--algorithm`` choices. Adding an algorithm is one module that
subclasses the protocol (plus, if its client step needs a new gradient
addend, one ``register_client_kind`` call) — zero edits anywhere else.
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

from repro.fed.algorithms.base import (
    FederatedAlgorithm,
    WeightedDeltaAlgorithm,
    apply_weighted_delta,
    weighted_delta,
)

_REGISTRY: Dict[str, Type[FederatedAlgorithm]] = {}


def register(cls: Type[FederatedAlgorithm]) -> Type[FederatedAlgorithm]:
    """Class decorator: add ``cls`` to the registry under ``cls.name``.
    Duplicate names are rejected loudly — two plugins silently shadowing
    each other would corrupt every comparison experiment."""
    name = getattr(cls, "name", None)
    if not name or name == "base":
        raise ValueError(f"{cls!r} must set a non-default ``name`` to register")
    if name in _REGISTRY:
        prev = _REGISTRY[name]
        raise ValueError(
            f"algorithm {name!r} is already registered "
            f"(by {prev.__module__}.{prev.__qualname__})"
        )
    _REGISTRY[name] = cls
    return cls


def available_algorithms() -> Tuple[str, ...]:
    """Registered algorithm names, in registration order."""
    return tuple(_REGISTRY)


def get_algorithm(name: str) -> Type[FederatedAlgorithm]:
    """Resolve a name to its algorithm class (capability flags are
    class-level, so callers can query them without instantiating)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; registered algorithms: "
            f"{', '.join(_REGISTRY)}"
        ) from None


def make_algorithm(cfg) -> FederatedAlgorithm:
    """Instantiate the algorithm named by ``cfg.algorithm`` (one instance
    per ``FedSim`` — instances own per-client state like FedADMM's duals)."""
    return get_algorithm(cfg.algorithm)(cfg)


def comparison_algorithms() -> Tuple[str, ...]:
    """Registered algorithms eligible for the partial-participation
    comparison sweeps (examples, table benches): everything that is not
    full-participation-only. ONE home for the filter so the example and
    the benches can never enumerate different sets."""
    return tuple(
        n for n in _REGISTRY if not _REGISTRY[n].full_participation_only
    )


# --- built-in plugins ------------------------------------------------------
from repro.fed.algorithms.averaging import (  # noqa: E402
    FedAvg,
    FedNova,
    FedProx,
    fedavg_weights,
    fednova_weights,
)
from repro.fed.algorithms.fedadmm import FedADMM  # noqa: E402
from repro.fed.algorithms.fedecado import ECADO, FedECADO  # noqa: E402

for _cls in (FedECADO, ECADO, FedAvg, FedProx, FedNova, FedADMM):
    register(_cls)

__all__ = [
    "FederatedAlgorithm", "WeightedDeltaAlgorithm",
    "apply_weighted_delta", "weighted_delta",
    "register", "available_algorithms", "get_algorithm", "make_algorithm",
    "comparison_algorithms",
    "FedECADO", "ECADO", "FedAvg", "FedProx", "FedNova", "FedADMM",
    "fedavg_weights", "fednova_weights",
]

"""FedADMM (Gong, Li & Freris, 2022) as a registry plugin.

Each client k keeps a dual variable λ_k (parameter-shaped, like SCAFFOLD
control variates) and locally minimizes the augmented Lagrangian

    L_k(x) = f_k(x) + ⟨λ_k, x − z⟩ + (ρ/2)·‖x − z‖²

by SGD from the broadcast server state z — gradient addend
λ_k + ρ(x − z), i.e. the FedECADO flow-row machinery composed with the
FedProx proximal pull, registered below as the ``admm`` client kind
(``takes_flow``: the backends gather/vmap the λ rows exactly like flow
variables). After K local steps the duals and server state update

    λ_k ← λ_k + ρ(x_k − z)            (dual ascent)
    z   ← Σ_k p̃_k (x_k + λ_k⁺/ρ)      (data-weighted over the cohort)

which in weighted-delta form is the transformed endpoint
y_k = x_k + λ_k⁺/ρ with FedAvg weights — so aggregation rides the shared
``apply_weighted_delta`` / Pallas batch-agg / psum machinery untouched.
ρ reuses ``FedSimConfig.mu`` (both are the proximal strength).

This module is the API's acceptance proof: registering it here makes
FedADMM run on the sequential, vectorized AND sharded backends — and be
picked up by the registry-parametrized equivalence fuzz, the CLIs and the
engine bench — with **zero lines changed** in ``sim/``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.algorithms.averaging import FedAvg
from repro.fed.client import register_client_kind


def _admm_extra(mu):
    """λ_i + ρ(x − x0): the dual + augmentation gradient addend (ρ = mu)."""

    def extra(x, x0, lam):
        return jax.tree.map(
            lambda l, a, b: l
            + mu * (a.astype(jnp.float32) - b.astype(jnp.float32)),
            lam, x, x0,
        )

    return extra


register_client_kind("admm", _admm_extra, takes_flow=True)


class FedADMM(FedAvg):
    name = "fedadmm"
    client_kind = "admm"
    has_client_state = True      # the duals λ, leaves (n, ...), zeros at init

    @property
    def rho(self) -> float:
        # clamp away 0 so the y = x + λ/ρ transform stays finite even if a
        # user zeroes mu (the client step then degenerates to plain SGD)
        return float(max(self.cfg.mu, 1e-8))

    def client_mu(self) -> float:
        return float(self.cfg.mu)

    def agg_transform(self, x_c, x_new_a, rows):
        rho = np.float32(self.rho)
        lam_new = jax.tree.map(
            lambda lam, xa, xc: lam
            + rho * (xa.astype(jnp.float32) - xc.astype(jnp.float32)[None]),
            rows, x_new_a, x_c,
        )
        y_a = jax.tree.map(
            lambda xa, lam: xa.astype(jnp.float32) + lam / rho,
            x_new_a, lam_new,
        )
        return y_a, lam_new

from repro.fed.algorithms import (
    FederatedAlgorithm,
    WeightedDeltaAlgorithm,
    available_algorithms,
    get_algorithm,
    make_algorithm,
    register,
)
from repro.fed.baselines import fedavg_aggregate, fednova_aggregate, fedprox_aggregate
from repro.fed.client import (
    CLIENT_KINDS,
    ClientOutput,
    HeteroConfig,
    client_step,
    fedecado_client_sim,
    fedprox_client,
    register_client_kind,
    sgd_client,
)
from repro.fed.partition import (
    data_fractions,
    dirichlet_partition,
    iid_partition,
    label_shard_partition,
    quantity_skew_partition,
)
from repro.fed.server import (
    ALGORITHMS,
    FedSim,
    FedSimConfig,
    last_finite_loss,
    mean_finite_loss,
)
from repro.obs import RunHistory

__all__ = [
    "FedSim", "FedSimConfig", "ALGORITHMS", "RunHistory",
    "last_finite_loss", "mean_finite_loss",
    "FederatedAlgorithm", "WeightedDeltaAlgorithm",
    "available_algorithms", "get_algorithm", "make_algorithm", "register",
    "HeteroConfig", "ClientOutput", "CLIENT_KINDS", "client_step",
    "register_client_kind",
    "fedecado_client_sim", "sgd_client", "fedprox_client",
    "fedavg_aggregate", "fednova_aggregate", "fedprox_aggregate",
    "dirichlet_partition", "iid_partition", "data_fractions",
    "label_shard_partition", "quantity_skew_partition",
]

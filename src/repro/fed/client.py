"""Client-side local simulation.

A FedECADO client integrates its local gradient-flow ODE with Forward Euler
(paper eq. 9 — "equivalent to gradient descent" — plus the flow-variable
term):  x_i ← x_i − Δt_i·(p_i·∇f_i(x_i) + I_i)

Heterogeneous computation (paper eqs. 43-44): each client's learning rate
lr_i ~ U[1e-4, 1e-3] and epoch count e_i ~ U[1, 10]; its continuous-time
window is T_i = e_i·lr_i (×steps per epoch).

The same machinery also runs the baselines' local steps (FedProx's proximal
term, vanilla SGD for FedAvg/FedNova).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class HeteroConfig:
    """Paper eqs. (43)-(44) heterogeneity sampling."""
    lr_min: float = 1e-4
    lr_max: float = 1e-3
    epochs_min: int = 1
    epochs_max: int = 10

    def sample(self, rng: np.random.RandomState, n: int):
        lr = rng.uniform(self.lr_min, self.lr_max, size=n).astype(np.float32)
        ep = rng.randint(self.epochs_min, self.epochs_max + 1, size=n)
        return lr, ep


class ClientOutput(NamedTuple):
    x_new: Pytree        # final local state (fp32)
    T: jax.Array         # simulation window Σ_k Δt_i^k
    n_steps: jax.Array   # local SGD/FE steps taken
    loss: jax.Array      # last minibatch loss


def _sgd_like_steps(
    loss_fn: Callable,
    x0: Pytree,
    batches,                 # (n_steps, ...) stacked minibatch pytree
    lr: float,
    extra_grad: Callable,    # fn(x, x0) -> pytree added to the gradient
    p_i: float,
):
    def step(x, batch):
        g = jax.grad(loss_fn)(x, batch)
        g = jax.tree.map(lambda gg: p_i * gg.astype(jnp.float32), g)
        g = jax.tree.map(jnp.add, g, extra_grad(x, x0))
        x = jax.tree.map(lambda xx, gg: xx - lr * gg, x, g)
        loss = loss_fn(x, batch)
        return x, loss

    x, losses = jax.lax.scan(step, x0, batches)
    return x, losses[-1]


def fedecado_client_sim(
    loss_fn: Callable,
    x0: Pytree,
    I_i: Pytree,
    batches,
    lr: float,
    p_i: float,
) -> ClientOutput:
    """FE integration of ẋ_i = −p_i∇f_i(x_i) − I_i for n_steps × Δt_i=lr."""
    extra = lambda x, x0_: I_i
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, extra, p_i)
    n_steps = jax.tree.leaves(batches)[0].shape[0]
    return ClientOutput(
        x_new=x,
        T=jnp.asarray(lr * n_steps, jnp.float32),
        n_steps=jnp.asarray(n_steps, jnp.int32),
        loss=loss,
    )


def sgd_client(loss_fn, x0, batches, lr, p_i: float = 1.0):
    """Vanilla local SGD (FedAvg / FedNova client)."""
    zero = jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), x0)
    extra = lambda x, x0_: zero
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, extra, p_i)
    return x, loss


def fedprox_client(loss_fn, x0, batches, lr, mu: float, p_i: float = 1.0):
    """FedProx: local SGD with proximal pull μ(x − x_global)."""
    extra = lambda x, x0_: jax.tree.map(
        lambda a, b: mu * (a.astype(jnp.float32) - b.astype(jnp.float32)), x, x0_
    )
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, extra, p_i)
    return x, loss

"""Client-side local simulation.

A FedECADO client integrates its local gradient-flow ODE with Forward Euler
(paper eq. 9 — "equivalent to gradient descent" — plus the flow-variable
term):  x_i ← x_i − Δt_i·(p_i·∇f_i(x_i) + I_i)

Heterogeneous computation (paper eqs. 43-44): each client's learning rate
lr_i ~ U[1e-4, 1e-3] and epoch count e_i ~ U[1, 10]; its continuous-time
window is T_i = e_i·lr_i (×steps per epoch).

The same machinery runs every algorithm's local step through an extensible
**client-kind registry**: a kind names the gradient addend of the local FE
update (the flow variable I_i for fedecado, the proximal pull μ(x − x0) for
fedprox, zero for plain SGD) and declares whether the step consumes a
per-client state row (``takes_flow``). Algorithm plugins
(fed/algorithms/) register new kinds with ``register_client_kind`` — e.g.
FedADMM's dual-augmented addend λ_i + ρ(x − x0) — and every execution
backend (repro/sim) picks them up with zero backend edits, because the
backends only ever query the registry.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class HeteroConfig:
    """Paper eqs. (43)-(44) heterogeneity sampling."""
    lr_min: float = 1e-4
    lr_max: float = 1e-3
    epochs_min: int = 1
    epochs_max: int = 10

    def sample(self, rng: np.random.RandomState, n: int):
        lr = rng.uniform(self.lr_min, self.lr_max, size=n).astype(np.float32)
        ep = rng.randint(self.epochs_min, self.epochs_max + 1, size=n)
        return lr, ep


class ClientOutput(NamedTuple):
    x_new: Pytree        # final local state (fp32)
    T: jax.Array         # simulation window Σ_k Δt_i^k
    n_steps: jax.Array   # local SGD/FE steps taken
    loss: jax.Array      # last minibatch loss


# ---------------------------------------------------------------------------
# client-kind registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClientKindSpec:
    """One local-update flavour: ``make_extra(mu)`` builds the kind-specific
    gradient addend ``extra(x, x0, I_i) -> pytree`` added to p_i·∇f_i;
    ``takes_flow`` marks kinds whose addend consumes a per-client state row
    I_i (the backends then gather/vmap those rows alongside the cohort)."""
    name: str
    takes_flow: bool
    make_extra: Callable[[float], Callable]


CLIENT_KINDS: Dict[str, ClientKindSpec] = {}


def register_client_kind(
    name: str, make_extra: Callable[[float], Callable], takes_flow: bool = False
) -> ClientKindSpec:
    """Register a new local-update kind. Raises on duplicate names so two
    plugins cannot silently shadow each other's client arithmetic."""
    if name in CLIENT_KINDS:
        raise ValueError(f"client kind {name!r} is already registered")
    spec = ClientKindSpec(name=name, takes_flow=takes_flow, make_extra=make_extra)
    CLIENT_KINDS[name] = spec
    return spec


def client_kind_spec(name: str) -> ClientKindSpec:
    if name not in CLIENT_KINDS:
        raise ValueError(
            f"unknown client kind {name!r}; registered kinds: "
            f"{', '.join(sorted(CLIENT_KINDS))}"
        )
    return CLIENT_KINDS[name]


register_client_kind(
    "fedecado", lambda mu: (lambda x, x0, I_i: I_i), takes_flow=True
)
register_client_kind(
    "fedprox",
    lambda mu: (
        lambda x, x0, I_i: jax.tree.map(
            lambda a, b: mu * (a.astype(jnp.float32) - b.astype(jnp.float32)),
            x, x0,
        )
    ),
)
register_client_kind(
    "sgd",
    lambda mu: (
        lambda x, x0, I_i: jax.tree.map(
            lambda l: jnp.zeros_like(l, jnp.float32), x
        )
    ),
)


def client_step(loss_fn: Callable, kind: str, mu: float = 0.0) -> Callable:
    """The one local FE/SGD update shared by every execution backend.

    Returns ``step(x, batch, x0, I_i, lr, p_i) -> (x_new, loss)``:

      x ← x − lr·(p_i·∇f_i(x) + extra(x))

    where ``extra`` is the registered kind's gradient addend (see the
    client-kind registry above). The sequential client sims below and the
    vectorized cohort runner in ``repro/sim/vectorized.py`` both call
    exactly this function, so all backends execute identical per-step
    arithmetic (DESIGN.md §5).
    """
    extra = client_kind_spec(kind).make_extra(mu)

    def step(x, batch, x0, I_i, lr, p_i):
        g = jax.grad(loss_fn)(x, batch)
        g = jax.tree.map(lambda gg: p_i * gg.astype(jnp.float32), g)
        g = jax.tree.map(jnp.add, g, extra(x, x0, I_i))
        x = jax.tree.map(lambda xx, gg: xx - lr * gg, x, g)
        loss = loss_fn(x, batch)
        return x, loss

    return step


def _sgd_like_steps(
    loss_fn: Callable,
    x0: Pytree,
    batches,                 # (n_steps, ...) stacked minibatch pytree
    lr: float,
    kind: str,
    p_i: float,
    I_i: Optional[Pytree] = None,
    mu: float = 0.0,
):
    step = client_step(loss_fn, kind, mu)

    def scan_step(x, batch):
        return step(x, batch, x0, I_i, lr, p_i)

    x, losses = jax.lax.scan(scan_step, x0, batches)
    return x, losses[-1]


def run_client(
    loss_fn: Callable,
    kind: str,
    mu: float,
    x0: Pytree,
    I_i: Optional[Pytree],
    batches,
    lr,
    p_i,
):
    """Uniform single-client entry for the sequential backend: scan
    ``client_step`` over the minibatches and return (x_new, last loss).
    ``I_i`` is the client's per-client state row for ``takes_flow`` kinds
    and None otherwise."""
    return _sgd_like_steps(loss_fn, x0, batches, lr, kind, p_i, I_i=I_i, mu=mu)


def fedecado_client_sim(
    loss_fn: Callable,
    x0: Pytree,
    I_i: Pytree,
    batches,
    lr: float,
    p_i: float,
) -> ClientOutput:
    """FE integration of ẋ_i = −p_i∇f_i(x_i) − I_i for n_steps × Δt_i=lr."""
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, "fedecado", p_i, I_i=I_i)
    n_steps = jax.tree.leaves(batches)[0].shape[0]
    return ClientOutput(
        x_new=x,
        T=jnp.asarray(lr * n_steps, jnp.float32),
        n_steps=jnp.asarray(n_steps, jnp.int32),
        loss=loss,
    )


def sgd_client(loss_fn, x0, batches, lr, p_i: float = 1.0):
    """Vanilla local SGD (FedAvg / FedNova client)."""
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, "sgd", p_i)
    return x, loss


def fedprox_client(loss_fn, x0, batches, lr, mu: float, p_i: float = 1.0):
    """FedProx: local SGD with proximal pull μ(x − x_global)."""
    x, loss = _sgd_like_steps(loss_fn, x0, batches, lr, "fedprox", p_i, mu=mu)
    return x, loss

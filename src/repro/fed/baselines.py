"""Back-compat aggregation helpers for the averaging baselines.

The weight math lives in the algorithm plugins (fed/algorithms/averaging.py
— the single home of the p/Σp and τ_eff arithmetic) and the delta
application in fed/algorithms/base.py::apply_weighted_delta; these wrappers
keep the original standalone-function API for examples and tests.
"""
from __future__ import annotations

from typing import Any

import jax

Pytree = Any

from repro.fed.algorithms.averaging import fedavg_weights, fednova_weights
from repro.fed.algorithms.base import apply_weighted_delta


def fedavg_aggregate(x_c: Pytree, x_new_a: Pytree, p_a: jax.Array) -> Pytree:
    """x_c ← x_c + Σ_a (p_a/Σp) Δ_a."""
    w, scale = fedavg_weights(p_a)
    return apply_weighted_delta(x_c, x_new_a, w, scale)


# FedProx uses FedAvg aggregation
fedprox_aggregate = fedavg_aggregate


def fednova_aggregate(
    x_c: Pytree,
    x_new_a: Pytree,
    p_a: jax.Array,
    tau_a: jax.Array,
) -> Pytree:
    """Normalized averaging:
    x_c ← x_c + (Σ_a p̃_a τ_a) · Σ_a p̃_a Δ_a/τ_a,  p̃ = p/Σp.
    """
    w, scale = fednova_weights(p_a, tau_a)
    return apply_weighted_delta(x_c, x_new_a, w, scale)

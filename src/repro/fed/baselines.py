"""Server-side aggregation baselines the paper compares against.

* FedAvg   (McMahan et al. 2017): data-weighted average of client deltas.
* FedProx  (Li et al. 2020): FedAvg aggregation; the μ-proximal term lives in
  the client step (fed/client.py:fedprox_client).
* FedNova  (Wang et al. 2020): normalized averaging — each client's delta is
  divided by its local step count τ_i, then recombined with an effective
  step Σ p_i τ_i, removing objective inconsistency under heterogeneous e_i.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def _weighted_delta(x_c, x_new_a, weights):
    """Σ_a w_a (x_a − x_c) per leaf; weights (A,) normalized by caller."""

    def leaf(xc, xa):
        w = weights.reshape((-1,) + (1,) * (xa.ndim - 1)).astype(jnp.float32)
        return jnp.sum(w * (xa.astype(jnp.float32) - xc.astype(jnp.float32)[None]), axis=0)

    return jax.tree.map(leaf, x_c, x_new_a)


def fedavg_aggregate(x_c: Pytree, x_new_a: Pytree, p_a: jax.Array) -> Pytree:
    """x_c ← x_c + Σ_a (p_a/Σp) Δ_a."""
    w = p_a / jnp.maximum(jnp.sum(p_a), 1e-12)
    delta = _weighted_delta(x_c, x_new_a, w)
    return jax.tree.map(lambda xc, d: xc + d, x_c, delta)


# FedProx uses FedAvg aggregation
fedprox_aggregate = fedavg_aggregate


def fednova_aggregate(
    x_c: Pytree,
    x_new_a: Pytree,
    p_a: jax.Array,
    tau_a: jax.Array,
) -> Pytree:
    """Normalized averaging:
    x_c ← x_c + (Σ_a p̃_a τ_a) · Σ_a p̃_a Δ_a/τ_a,  p̃ = p/Σp.
    """
    p = p_a / jnp.maximum(jnp.sum(p_a), 1e-12)
    tau_eff = jnp.sum(p * tau_a.astype(jnp.float32))
    w = p / jnp.maximum(tau_a.astype(jnp.float32), 1.0)
    delta = _weighted_delta(x_c, x_new_a, w)
    return jax.tree.map(lambda xc, d: xc + tau_eff * d, x_c, delta)

"""FedECADO Algorithm 2 — the central-agent multi-rate round.

Per communication round:
  1. Active clients simulate their local ODE for window T_i = Σ_k Δt_i^k
     (client side lives in fed/client.py) and send x_i(T_i), T_i.
  2. The server integrates the central ODE over the synchronous window
     τ ∈ [0, max_i T_i]: at each BE time point, client states are estimated
     with Γ (interp/extrap), Δt is chosen by the Algorithm-1 LTE backtracking,
     and the arrowhead system (eq. 28) is solved in closed Schur form.
  3. Flow variables of the active cohort are written back; the new central
     state is broadcast for the next round.

``server_round`` is a single jittable function; in the distributed runtime it
is pjit-ed with the client axis sharded over the mesh (launch/).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.consensus import ConsensusConfig, adaptive_be_step
from repro.core.flow import (
    ServerState,
    broadcast_clients,
    gather_active,
    put_rows,
)

Pytree = Any


class RoundStats(NamedTuple):
    n_substeps: jax.Array
    n_backtracks: jax.Array
    final_dt: jax.Array
    max_eps: jax.Array
    tau_end: jax.Array
    # accepted-Δt envelope over the round's BE substeps (repro.obs rows;
    # dt_min is 0 when no substep ran, dt_sum/n_substeps gives dt_mean)
    dt_min: jax.Array
    dt_max: jax.Array
    dt_sum: jax.Array


def consensus_integrate(
    x_c: Pytree,
    I_a0: Pytree,
    J_a: Pytree,
    x_prev_a: Pytree,
    x_new_a: Pytree,
    T_a: jax.Array,
    g_inv_a,
    S_frozen: Pytree,
    dt0: jax.Array,
    ccfg: ConsensusConfig,
    axis_name: Optional[str] = None,
    mask: Optional[jax.Array] = None,
) -> tuple:
    """Adaptive-BE integrate the central ODE over τ ∈ [0, max_a T_a].

    The Algorithm-1 substep loop shared by the dense synchronous round
    (``server_round``) and the sharded backend (sim/sharded.py, which calls
    this inside ``shard_map`` with the client axis sharded — ``axis_name``
    names the mesh axis and ``mask`` zeroes cohort-padding rows; the T_max
    horizon and every LTE scalar are then pmax/psum-replicated).

    Returns (x_c, I_a, tau_end, dt_next, stats) with stats =
    (n_substeps, n_backtracks, final_dt, max_eps, dt_min, dt_max, dt_sum)
    — the last three the accepted-step envelope (telemetry; dt_min is 0
    when the loop never ran).
    """
    T_eff = T_a if mask is None else jnp.where(mask > 0, T_a, 0.0)
    T_max = jnp.max(T_eff)
    if axis_name:
        T_max = jax.lax.pmax(T_max, axis_name)

    def cond(carry):
        x_c, I_a, tau, dt, stats = carry
        return (tau < T_max) & (stats[0] < ccfg.max_substeps)

    def body(carry):
        x_c, I_a, tau, dt, stats = carry
        n_sub, n_back, _, max_eps, dt_mn, dt_mx, dt_sm = stats
        dt = jnp.minimum(dt, ccfg.dt_max)
        res = adaptive_be_step(
            x_c, I_a, J_a, x_prev_a, x_new_a, T_a, g_inv_a, S_frozen,
            tau, dt, ccfg, axis_name=axis_name, mask=mask,
        )
        # warm-start the next step; gently grow when LTE is slack
        grow = jnp.where(res.eps < 0.5 * ccfg.delta, 1.5, 1.0)
        new_dt = jnp.minimum(res.dt_used * grow, ccfg.dt_max)
        stats = (
            n_sub + 1,
            n_back + res.n_backtracks,
            res.dt_used,
            jnp.maximum(max_eps, res.eps),
            jnp.minimum(dt_mn, res.dt_used),
            jnp.maximum(dt_mx, res.dt_used),
            dt_sm + res.dt_used,
        )
        return res.x_c, res.I_a, tau + res.dt_used, new_dt, stats

    stats0 = (
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
        dt0,
        jnp.zeros((), jnp.float32),
        jnp.full((), jnp.inf, jnp.float32),
        jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.float32),
    )
    x_c_f, I_a_f, tau_f, dt_f, stats = jax.lax.while_loop(
        cond, body, (x_c, I_a0, jnp.zeros((), jnp.float32), dt0, stats0)
    )
    n_sub, n_back, final_dt, max_eps, dt_mn, dt_mx, dt_sm = stats
    dt_mn = jnp.where(n_sub > 0, dt_mn, 0.0)  # no substep: clear the +inf seed
    return x_c_f, I_a_f, tau_f, dt_f, (
        n_sub, n_back, final_dt, max_eps, dt_mn, dt_mx, dt_sm
    )


def server_round(
    state: ServerState,
    x_new_a: Pytree,
    T_a: jax.Array,
    active_idx: jax.Array,
    ccfg: ConsensusConfig,
) -> tuple:
    """One FedECADO consensus round (steps 12-16 of Algorithm 2).

    x_new_a: active-client final states, leaves (A, ...) fp32.
    T_a: (A,) client simulation windows. active_idx: (A,) int32 client ids.
    """
    A = T_a.shape[0]
    x_c = state.x_c
    J_a, S_frozen, g_inv_a = gather_active(state, active_idx)
    # clients start each round from the broadcast central state
    x_prev_a = broadcast_clients(x_c, A)

    x_c_f, I_a_f, tau_f, dt_f, stats = consensus_integrate(
        x_c, J_a, J_a, x_prev_a, x_new_a, T_a, g_inv_a, S_frozen,
        state.dt_last, ccfg,
    )

    new_state = ServerState(
        x_c=x_c_f,
        I=put_rows(state.I, active_idx, I_a_f),
        g_inv=state.g_inv,
        t=state.t + tau_f,
        dt_last=dt_f,
        round=state.round + 1,
    )
    rstats = RoundStats(
        n_substeps=stats[0], n_backtracks=stats[1],
        final_dt=stats[2], max_eps=stats[3], tau_end=tau_f,
        dt_min=stats[4], dt_max=stats[5], dt_sum=stats[6],
    )
    return new_state, rstats


def set_gains(state: ServerState, g_inv, idx: Optional[jax.Array] = None) -> ServerState:
    """Install (inverse) sensitivity gains 1/Ḡ_th for all or selected clients."""
    if idx is None:
        return state._replace(g_inv=g_inv)
    if isinstance(state.g_inv, jax.Array):
        return state._replace(g_inv=state.g_inv.at[idx].set(g_inv))
    return state._replace(g_inv=put_rows(state.g_inv, idx, g_inv))


make_server_round = lambda ccfg: partial(server_round, ccfg=ccfg)

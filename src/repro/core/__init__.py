from repro.core.consensus import ConsensusConfig, adaptive_be_step, be_step, lte
from repro.core.ecado import ecado_round
from repro.core.fedecado import (
    RoundStats,
    consensus_integrate,
    server_round,
    set_gains,
)
from repro.core.flow import ServerState, init_server_state
from repro.core.gamma import gamma, gamma_leaf, gamma_stacked
from repro.core.multirate import (
    FlightTable,
    MultirateStats,
    flight_insert,
    flight_insert_checked,
    init_flight_table,
    masked_quantile,
    multirate_integrate,
)
from repro.core.sensitivity import (
    hutchinson_diag,
    hutchinson_scalar,
    hvp,
    make_gain,
)

__all__ = [
    "ConsensusConfig", "be_step", "adaptive_be_step", "lte",
    "server_round", "set_gains", "RoundStats", "ecado_round",
    "consensus_integrate",
    "ServerState", "init_server_state",
    "FlightTable", "MultirateStats", "init_flight_table", "flight_insert",
    "flight_insert_checked",
    "masked_quantile", "multirate_integrate",
    "gamma", "gamma_leaf", "gamma_stacked",
    "hutchinson_scalar", "hutchinson_diag", "hvp", "make_gain",
]

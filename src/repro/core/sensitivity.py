"""Aggregate sensitivity model Ḡ_th^i = 1/Δt_ref + p_i·H̄_i (paper eq. 42).

The paper precomputes H̄_i by averaging the local Hessian over client data —
infeasible to materialize at transformer scale, so (DESIGN.md §2) we estimate
it stochastically with Hutchinson probes through Hessian-vector products:

  scalar mode: h̄ ≈ tr(H)/n_params  (one gain per client — keeps the
               arrowhead consensus solve exact with scalar Schur terms)
  diag mode:   h̄ ≈ E[v ⊙ Hv], v ~ Rademacher  (per-parameter gains; the
               Schur solve stays exact because everything is elementwise)

Gains are clipped to be positive: negative curvature directions would turn
the proportional controller into positive feedback.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def _rademacher_like(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    vs = [
        (jax.random.bernoulli(k, 0.5, l.shape).astype(jnp.float32) * 2.0 - 1.0)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, vs)


def hvp(loss_fn: Callable, params, batch, v):
    """Hessian-vector product via forward-over-reverse."""
    grad_fn = lambda p: jax.grad(loss_fn)(p, batch)
    _, hv = jax.jvp(grad_fn, (params,), (v,))
    return hv


def hutchinson_scalar(loss_fn: Callable, params, batch, key, n_probes: int = 2) -> jax.Array:
    """tr(H)/n_params estimate (fp32 scalar)."""
    n_params = sum(int(l.size) for l in jax.tree.leaves(params))

    def one(k):
        v = _rademacher_like(k, params)
        hv = hvp(loss_fn, params, batch, v)
        dots = jax.tree.map(
            lambda a, b: jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32)), v, hv
        )
        return sum(jax.tree.leaves(dots))

    keys = jax.random.split(key, n_probes)
    tr = jnp.mean(jnp.stack([one(k) for k in keys]))
    return tr / n_params


def hutchinson_diag(loss_fn: Callable, params, batch, key, n_probes: int = 2):
    """E[v ⊙ Hv] diagonal estimate (pytree, fp32)."""

    def one(k):
        v = _rademacher_like(k, params)
        hv = hvp(loss_fn, params, batch, v)
        return jax.tree.map(
            lambda a, b: a.astype(jnp.float32) * b.astype(jnp.float32), v, hv
        )

    keys = jax.random.split(key, n_probes)
    acc = one(keys[0])
    for k in keys[1:]:
        nxt = one(k)
        acc = jax.tree.map(jnp.add, acc, nxt)
    return jax.tree.map(lambda a: a / n_probes, acc)


def make_gain(h_bar, p_i, dt_ref: float, h_floor: float = 0.0):
    """Ḡ_th^i = 1/Δt_ref + p_i·max(h̄, floor)   (eq. 42).

    ``h_bar``: scalar or diag pytree; ``p_i``: scalar data fraction.
    Returns the same structure as ``h_bar``.
    """
    if isinstance(h_bar, (jnp.ndarray, jax.Array, float, int)):
        return 1.0 / dt_ref + p_i * jnp.maximum(jnp.asarray(h_bar, jnp.float32), h_floor)
    return jax.tree.map(
        lambda h: 1.0 / dt_ref + p_i * jnp.maximum(h, h_floor), h_bar
    )

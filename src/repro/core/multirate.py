"""Device-resident multi-rate event integration: the flight table.

The event scheduler's unit of work is an in-flight client: a dispatched
client whose local trajectory the server has not yet fully absorbed. PR 1
represented those as a host-Python list of ``InFlight`` dataclasses, which
forced a device→host sync on every adaptive-BE substep and could neither
shard nor ride a jit-resident multi-round segment. This module replaces the
list with a fixed-capacity **flight table** — a pytree of stacked arrays —
and reimplements the whole event round (horizon, waves, adaptive-BE
substepping, staleness re-anchoring) as pure jax control flow:

  * ``FlightTable``: capacity-C stacked Γ anchors ``x_prev``/``x_new``
    (leaves (C, ...)), remaining windows ``T_rem`` (C,), ``stale_rounds``
    (C,), client ids ``cid`` (C,) and an ``alive`` mask (C,). The table is
    **direct-indexed**: slot ``c`` holds client ``offset + c``'s flight (a
    client has at most one in-flight record, so capacity = n_clients is an
    exact bound and busy lookups are O(1) gathers). ``cid`` carries an
    out-of-bounds sentinel on dead slots so every write-back is a
    ``mode="drop"`` one-hot scatter — dead rows can never alias a real
    client.
  * ``flight_insert``: batched masked insert of a freshly dispatched cohort
    (one one-hot scatter per leaf; masked rows — busy clients, cohort
    padding — leave the table bitwise untouched).
  * ``multirate_integrate``: one full event round. The horizon is a masked
    ``jnp.nanquantile`` over alive windows; arrivals are partitioned into at
    most ``max_waves`` waves by per-wave quantile thresholds of the arrived
    windows; each wave runs the Algorithm-1 adaptive-BE loop as a
    ``lax.while_loop`` with the active set expressed as a mask into
    ``be_step``/``lte`` (core/consensus.py) — the same masked path the
    sharded backend uses, so passing ``axis_name`` shards the capacity axis
    over the client mesh with psum-reduced wave solves; stale flights are
    Γ re-anchored to τ_end with one batched masked lerp (the Pallas
    anchor-rebase kernel when ``ccfg.use_kernels``).

Zero host syncs: every quantity that used to round-trip through ``float()``
(horizon, wave boundaries, dt, LTE scalars) stays on device, so a whole
segment of event rounds can live inside one jit (sim/events.py).

Wave semantics vs PR 1: the host scheduler split arrivals into
``np.array_split`` rank groups; the device version uses quantile thresholds
over the arrived windows — identical at ``max_waves=1`` (and in particular
at the ``horizon_quantile=1.0`` setting pinned against the sequential
oracle in tests/test_backend_equiv.py), and the same up to tie-breaking
elsewhere. Like the synchronous round, a wave's last BE substep may
overshoot its boundary (Γ extrapolates); stale windows are clamped at a
small positive remainder so an overshot straggler simply arrives first
thing next round. The Σ_i I_i = 0 fixed-point invariant is preserved by
construction for any slicing: every wave's solve sees
Σ_active I_a + S_frozen = Σ_all I_i (DESIGN.md §8).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import ConsensusConfig, adaptive_be_step
from repro.core.flow import take_rows, tree_sum_clients

Pytree = Any

# dead-slot sentinel: far out of bounds for any client tensor, so every
# mode="drop" scatter keyed on ``cid`` drops dead rows
DEAD_CID = 1 << 30


class FlightTable(NamedTuple):
    """Fixed-capacity table of in-flight clients (leaves stacked on a
    leading capacity axis C; direct-indexed, slot c <-> client offset+c)."""

    cid: jax.Array          # (C,) int32 client id; DEAD_CID on dead slots
    x_prev: Pytree          # leaves (C, ...) Γ anchor at τ=0 of this round
    x_new: Pytree           # leaves (C, ...) local endpoint x_i(T_i)
    T_rem: jax.Array        # (C,) float32 remaining continuous-time window
    stale_rounds: jax.Array  # (C,) int32 rounds spent in the queue
    alive: jax.Array        # (C,) float32 1 = in flight, 0 = free slot

    @property
    def capacity(self) -> int:
        return self.T_rem.shape[0]


class MultirateStats(NamedTuple):
    """Per-round event statistics (global counts under ``axis_name``)."""

    arrived: jax.Array      # int32 flights absorbed this round
    stale: jax.Array        # int32 flights left pending
    waves: jax.Array        # int32 waves that integrated > 0 time
    substeps: jax.Array     # int32 total adaptive-BE substeps
    horizon: jax.Array      # float32 round horizon W
    tau_end: jax.Array      # float32 centrally integrated time
    backtracks: jax.Array   # int32 LTE rejections across all waves
    dt_min: jax.Array       # float32 smallest accepted step (0 if none)
    dt_max: jax.Array       # float32 largest accepted step
    dt_sum: jax.Array       # float32 Σ accepted steps
    stale_hist: jax.Array   # (N_STALE_BUCKETS,) f32 pending-age histogram
    max_stale: jax.Array    # int32 oldest pending flight (rounds queued)


def init_flight_table(params_like: Pytree, capacity: int) -> FlightTable:
    """An empty table whose anchor leaves mirror ``params_like`` with a
    leading capacity axis."""
    zeros = jax.tree.map(
        lambda l: jnp.zeros((capacity,) + jnp.shape(l), jnp.float32),
        params_like,
    )
    return FlightTable(
        cid=jnp.full((capacity,), DEAD_CID, jnp.int32),
        x_prev=zeros,
        x_new=jax.tree.map(jnp.array, zeros),
        T_rem=jnp.zeros((capacity,), jnp.float32),
        stale_rounds=jnp.zeros((capacity,), jnp.int32),
        alive=jnp.zeros((capacity,), jnp.float32),
    )


def _bcast(v: jax.Array, like: jax.Array) -> jax.Array:
    return v.reshape((-1,) + (1,) * (like.ndim - 1))


def _concrete(x) -> Optional[np.ndarray]:
    """The array's concrete numpy value, or None under a jit trace."""
    try:
        return np.asarray(x)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return None


def flight_insert(
    table: FlightTable,
    cid: jax.Array,         # (A,) int32 global client ids
    x_prev_a: Pytree,       # leaves (A, ...)
    x_new_a: Pytree,        # leaves (A, ...)
    T_a: jax.Array,         # (A,) float32 windows
    mask: jax.Array,        # (A,) float32 1 = insert, 0 = leave untouched
    offset: int = 0,        # first global client id owned by this table shard
) -> FlightTable:
    """Masked batched insert of a dispatched cohort.

    Slot assignment is direct: client ``cid`` lands in slot ``cid - offset``
    (rows outside [offset, offset + C) are dropped — that is how each shard
    of a sharded table claims its own rows from an all-gathered cohort).
    Every leaf updates through a one-hot scatter-add into zeros + a
    hit-masked select, so masked-out rows and untouched slots stay bitwise
    identical. The caller must mask out busy clients (slots already alive);
    inserting into an alive slot would alias two flights of one client.

    When called with concrete (non-traced) inputs the overflow and busy
    invariants are checked eagerly and raise ``ValueError``; under a jit
    trace the contract is the caller's (sim/events.py masks busy draws and
    sizes the capacity to n_clients, which makes overflow impossible).
    """
    C = table.capacity
    raw_slots = cid.astype(jnp.int32) - jnp.int32(offset)

    c_slots, c_mask, c_alive = (
        _concrete(raw_slots), _concrete(mask), _concrete(table.alive)
    )
    # eager invariant checks apply to whole (unsharded) tables only: a shard
    # (offset from a traced axis_index, or a later shard's rows) legitimately
    # sees out-of-range rows and masks them below
    c_off = _concrete(offset)
    if (c_off is not None and int(c_off) == 0
            and c_slots is not None and c_mask is not None):
        sel = c_slots[c_mask > 0]
        if sel.size and (sel.min() < 0 or sel.max() >= C):
            raise ValueError(
                f"FlightTable overflow: insert targets slot(s) "
                f"{sorted(set(int(s) for s in sel if s < 0 or s >= C))} "
                f"outside capacity {C} — the table is direct-indexed, so "
                "capacity must cover every dispatchable client id"
            )
        if c_alive is not None and (c_alive[sel] > 0).any():
            raise ValueError(
                "FlightTable busy-slot insert: client(s) "
                f"{sorted(int(c_slots[j]) for j in range(len(c_slots)) if c_mask[j] > 0 and c_alive[c_slots[j]] > 0)} "
                "are already in flight — mask busy draws out before inserting"
            )

    # rows outside this shard's slot range are someone else's to claim —
    # mask them instead of relying on scatter dropping (negative indices
    # would WRAP, landing a flight in the wrong client's slot)
    in_range = ((raw_slots >= 0) & (raw_slots < C)).astype(mask.dtype)
    mask = mask * in_range
    slots = jnp.clip(raw_slots, 0, C - 1)

    hit = jnp.zeros((C,), jnp.float32).at[slots].add(mask, mode="drop")

    def put_leaf(leaf, rows):
        upd = jnp.zeros_like(leaf).at[slots].add(
            rows.astype(leaf.dtype) * _bcast(mask, rows).astype(leaf.dtype),
            mode="drop",
        )
        return jnp.where(_bcast(hit, leaf) > 0, upd, leaf)

    imask = mask.astype(jnp.int32)
    cid_new = jnp.full((C,), 0, jnp.int32).at[slots].add(
        cid.astype(jnp.int32) * imask, mode="drop"
    )
    return FlightTable(
        cid=jnp.where(hit > 0, cid_new, table.cid),
        x_prev=jax.tree.map(put_leaf, table.x_prev, x_prev_a),
        x_new=jax.tree.map(put_leaf, table.x_new, x_new_a),
        T_rem=put_leaf(table.T_rem, T_a.astype(jnp.float32)),
        stale_rounds=jnp.where(hit > 0, 0, table.stale_rounds),
        alive=jnp.where(hit > 0, 1.0, table.alive),
    )


def flight_insert_checked(
    table: FlightTable,
    cid: jax.Array,         # (A,) int32 global client ids
    x_prev_a: Pytree,       # leaves (A, ...)
    x_new_a: Pytree,        # leaves (A, ...)
    T_a: jax.Array,         # (A,) float32 windows
    mask: jax.Array,        # (A,) float32 1 = insert, 0 = leave untouched
    offset: int = 0,
):
    """Jit-safe insert with an explicit masked-drop contract.

    ``flight_insert``'s busy-slot refusal only fires on concrete inputs —
    under a jit trace an unmasked busy row would one-hot-scatter on top of
    the live flight, silently aliasing two flights of one client. This
    wrapper enforces the contract inside the trace: rows whose target slot
    is already alive are masked out of the insert and counted, so callers
    that cannot (or did not) pre-mask busy draws get explicit ``dropped``
    accounting instead of wrong-slot writes. Out-of-range rows (another
    shard's slots in sharded mode) are masked but NOT counted — they are
    that shard's to claim, not drops.

    Returns ``(table, dropped)`` where ``dropped`` is the float32 count of
    in-range busy refusals. Pre-masked callers see ``dropped == 0`` and a
    bitwise-identical table to plain ``flight_insert``.
    """
    C = table.capacity
    raw_slots = cid.astype(jnp.int32) - jnp.int32(offset)
    in_range = (raw_slots >= 0) & (raw_slots < C)
    slots = jnp.clip(raw_slots, 0, C - 1)
    busy = jnp.take(table.alive, slots) > 0
    refused = mask * in_range.astype(mask.dtype) * busy.astype(mask.dtype)
    safe = mask * (in_range & ~busy).astype(mask.dtype)
    table = flight_insert(
        table, cid, x_prev_a, x_new_a, T_a, safe, offset=offset
    )
    return table, jnp.sum(refused)


def masked_quantile(vals: jax.Array, mask: jax.Array, q) -> jax.Array:
    """``np.quantile`` (linear interpolation) over the masked entries of
    ``vals``; nan when the mask is empty."""
    return jnp.nanquantile(
        jnp.where(mask > 0, vals, jnp.nan), q, method="linear"
    )


def _masked_sum_rows(tree: Pytree, mask: jax.Array,
                     axis_name: Optional[str]) -> Pytree:
    """Σ over the capacity axis of mask-selected rows (+psum when sharded).
    Left-fold association (``consensus._fold0``): the capacity axis is
    layout-dependent (n materialized vs cache capacity packed), so the sum
    must be invariant to zero-row padding for the cached == materialized
    bitwise contract (DESIGN.md §13)."""
    from repro.core.consensus import _fold0

    def leaf(l):
        s = _fold0(l * _bcast(mask, l))
        return jax.lax.psum(s, axis_name) if axis_name else s

    return jax.tree.map(leaf, tree)


def _psum_scalar(x, axis_name):
    return jax.lax.psum(x, axis_name) if axis_name else x


def multirate_integrate(
    x_c: Pytree,
    I: Pytree,                      # replicated (n_clients, ...) flow rows
    g_inv,                          # (n,) scalar gains or diag pytree rows
    dt_last: jax.Array,
    t: jax.Array,
    table: FlightTable,
    ccfg: ConsensusConfig,
    horizon_quantile: float,
    max_waves: int,
    axis_name: Optional[str] = None,
    buffer_k: Optional[int] = None,
    stale_gamma: float = 0.0,
):
    """One event round over the flight table (Algorithm 2, multi-rate form).

    Absorbs the ``horizon_quantile`` of alive windows in ≤ ``max_waves``
    waves of adaptive-BE integration, Γ re-anchors the stragglers to the
    integrated time τ_end, and writes the arrived flights' flow rows back
    into ``I``. With ``axis_name`` the capacity axis is a shard of a
    ``shard_map`` program over the client mesh: the horizon/wave thresholds
    are computed from all-gathered (tiny, (C,)) window vectors, the BE Schur
    sums psum across devices via the masked path of ``be_step``/``lte``, and
    the flow write-back is the exact-set one-hot psum scatter — every scalar
    steering the wave/substep loops is replicated, so all devices branch
    identically.

    ``buffer_k`` switches the horizon to the *buffered-server* K-trigger
    (DESIGN.md §10): the round drains nothing until at least K flights are
    in the table, then absorbs exactly the K earliest windows (ties drain
    together) — the continuous-time analogue of a size-K aggregation
    buffer, with no per-round barrier. ``stale_gamma > 0`` additionally
    damps each *arrived* stale flight's endpoint toward its Γ-rebased
    anchor with weight w_i = 1/(1 + γ·stale_rounds_i) before the wave
    solves — the staleness-weighted aggregation rule (fresh flights,
    stale_rounds = 0, are bitwise untouched; ``stale_gamma = 0`` skips the
    damping entirely, so the buffer=cohort equivalence pin is exact).

    Returns ``(x_c, I, dt_last, t, table, MultirateStats)``.
    """
    from repro.kernels.ops import anchor_rebase_op  # lazy: kernels are leaf deps

    alive = table.alive
    T = table.T_rem

    if axis_name:
        T_all = jax.lax.all_gather(T, axis_name, tiled=True)
        alive_all = jax.lax.all_gather(alive, axis_name, tiled=True)
    else:
        T_all, alive_all = T, alive

    m = jnp.sum(alive_all)
    if buffer_k is None:
        # round horizon: quantile of alive windows, but always admit the
        # earliest arrival so the server makes progress. The empty-table
        # quantile is all-NaN — sanitize BEFORE any comparison so a NaN can
        # never leak into wave activation, then zero the horizon explicitly
        # (m = 0 rounds integrate nothing; non-empty tables see values
        # bitwise identical to the unguarded computation).
        W = masked_quantile(T_all, alive_all, horizon_quantile)
        earliest = jnp.min(jnp.where(alive_all > 0, T_all, jnp.inf))
        W = jnp.maximum(jnp.nan_to_num(W), earliest)
        W = jnp.where(m > 0, W, 0.0)
    else:
        # buffered K-trigger: the K-th order statistic of alive windows when
        # >= K flights are queued, else a negative sentinel no window can
        # satisfy (T_rem is clamped >= 1e-6) — the server waits, flights age
        kk = int(min(max(1, buffer_k), T_all.shape[0]))
        sortedT = jnp.sort(jnp.where(alive_all > 0, T_all, jnp.inf))
        W = jnp.where(m >= kk, sortedT[kk - 1], -1.0)

    arrived = (alive > 0) & (T <= W + 1e-12)
    arrived_f = arrived.astype(jnp.float32)
    arrived_all = (alive_all > 0) & (T_all <= W + 1e-12)
    n_arr = jnp.sum(arrived_all.astype(jnp.float32))

    # round-start views: previous-round flows J0 and per-flight gains,
    # gathered by cid (dead slots clamp harmlessly — masked everywhere)
    gather_ids = jnp.minimum(table.cid, jax.tree.leaves(I)[0].shape[0] - 1)
    J0 = take_rows(I, gather_ids)
    g_rows = (
        jnp.take(g_inv, gather_ids, axis=0)
        if isinstance(g_inv, jax.Array)
        else take_rows(g_inv, gather_ids)
    )
    S_all0 = tree_sum_clients(I)

    # staleness-weighted aggregation (buffered server, DESIGN.md §10): an
    # arrived flight that waited s rounds contributes its endpoint damped
    # toward the Γ-rebased anchor with weight 1/(1 + γ·s). Statically gated
    # on γ so the γ = 0 path (and every pre-existing caller) stays bitwise
    # identical — a lerp at weight 1.0 is NOT a bitwise no-op.
    x_new_eff = table.x_new
    if float(stale_gamma) != 0.0:
        w_s = 1.0 / (1.0 + float(stale_gamma)
                     * table.stale_rounds.astype(jnp.float32))
        damp = arrived_f * (table.stale_rounds > 0).astype(jnp.float32)
        damped = anchor_rebase_op(
            table.x_prev, table.x_new, w_s, damp, use_kernel=ccfg.use_kernels
        )
        x_new_eff = jax.tree.map(
            lambda d, o: jnp.where(_bcast(damp, d) > 0, d, o),
            damped, table.x_new,
        )

    def wave_step(w, carry):
        x_c, I_tab, tau, dt, n_sub, n_waves, n_back, dt_mn, dt_mx, dt_sm = carry
        qw = (w + 1).astype(jnp.float32) / max_waves
        tau1 = masked_quantile(T_all, arrived_all.astype(jnp.float32), qw)
        tau1 = jnp.where(n_arr > 0, tau1, 0.0)
        active = arrived_f * (T <= tau1 + 1e-12).astype(jnp.float32)
        # frozen flows: everything outside this wave's active set — rows not
        # yet active carry their round-start values, so Σ_inactive current
        # = Σ_all I − Σ_active J0 (active sets are nested across waves)
        S_act = _masked_sum_rows(J0, active, axis_name)
        S_frozen = jax.tree.map(jnp.subtract, S_all0, S_act)
        J_w = I_tab  # wave-start anchor for the (I − J)·g⁻¹ gain term

        def cond(c):
            _, _, tau_c, _, k, _, _, _, _ = c
            return (tau_c < tau1) & (k < ccfg.max_substeps)

        def body(c):
            xc_c, I_c, tau_c, dt_c, k, nb, dmn, dmx, dsm = c
            dt_c = jnp.minimum(dt_c, ccfg.dt_max)
            res = adaptive_be_step(
                xc_c, I_c, J_w, table.x_prev, x_new_eff, T, g_rows,
                S_frozen, tau_c, dt_c, ccfg,
                axis_name=axis_name, mask=active, fold=True,
            )
            grow = jnp.where(res.eps < 0.5 * ccfg.delta, 1.5, 1.0)
            new_dt = jnp.minimum(res.dt_used * grow, ccfg.dt_max)
            # masked rows come back 0 from the Schur solve — keep theirs
            I_next = jax.tree.map(
                lambda new, old: jnp.where(_bcast(active, new) > 0, new, old),
                res.I_a, I_c,
            )
            return (res.x_c, I_next, tau_c + res.dt_used, new_dt, k + 1,
                    nb + res.n_backtracks,
                    jnp.minimum(dmn, res.dt_used),
                    jnp.maximum(dmx, res.dt_used),
                    dsm + res.dt_used)

        x_c, I_tab, tau_w, dt, k, n_back, dt_mn, dt_mx, dt_sm = (
            jax.lax.while_loop(
                cond, body,
                (x_c, I_tab, tau, dt, jnp.zeros((), jnp.int32),
                 n_back, dt_mn, dt_mx, dt_sm),
            )
        )
        return (x_c, I_tab, tau_w, dt, n_sub + k,
                n_waves + (k > 0).astype(jnp.int32),
                n_back, dt_mn, dt_mx, dt_sm)

    zero_i = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)
    (x_c, I_tab, tau_end, dt_f, n_sub, n_waves,
     n_back, dt_mn, dt_mx, dt_sm) = jax.lax.fori_loop(
        0, int(max_waves), wave_step,
        (x_c, J0, zero_f, dt_last, zero_i, zero_i,
         zero_i, jnp.full((), jnp.inf, jnp.float32), zero_f, zero_f),
    )
    dt_mn = jnp.where(n_sub > 0, dt_mn, 0.0)  # no substep: clear the +inf seed

    # arrived flights: flow rows re-enter the replicated I through the
    # exact-set one-hot scatter (each real slot owned by exactly one shard)
    n = jax.tree.leaves(I)[0].shape[0]
    hit = _psum_scalar(
        jnp.zeros((n,), jnp.float32).at[table.cid].add(arrived_f, mode="drop"),
        axis_name,
    )
    rows = jax.tree.map(
        lambda full, r: _psum_scalar(
            jnp.zeros_like(full).at[table.cid].add(
                r * _bcast(arrived_f, r), mode="drop"
            ),
            axis_name,
        ),
        I, I_tab,
    )
    I_new = jax.tree.map(
        lambda full, r: jnp.where(_bcast(hit, full) > 0, r, full), I, rows
    )

    # stragglers: deduct the centrally integrated window and re-anchor Γ
    # there (exact by Theorem-1 linearity) with one batched masked lerp
    stale = alive * (1.0 - arrived_f)
    frac = tau_end / jnp.maximum(T, 1e-12)
    x_prev_new = anchor_rebase_op(
        table.x_prev, table.x_new, frac, stale,
        use_kernel=ccfg.use_kernels,
    )
    table_new = FlightTable(
        cid=jnp.where(stale > 0, table.cid, DEAD_CID),
        x_prev=x_prev_new,
        x_new=table.x_new,
        # clamp: a wave may overshoot its boundary (as the synchronous round
        # does); an overshot straggler keeps a tiny positive remainder and
        # arrives first thing next round
        T_rem=jnp.where(stale > 0, jnp.maximum(T - tau_end, 1e-6), 0.0),
        stale_rounds=jnp.where(stale > 0, table.stale_rounds + 1, 0),
        alive=stale,
    )
    from repro.obs.telemetry import stale_histogram  # lazy: obs is a leaf dep

    max_stale = jnp.max(
        jnp.where(table_new.alive > 0, table_new.stale_rounds, 0)
    )
    if axis_name:
        max_stale = jax.lax.pmax(max_stale, axis_name)
    stats = MultirateStats(
        arrived=_psum_scalar(jnp.sum(arrived_f), axis_name).astype(jnp.int32),
        stale=_psum_scalar(jnp.sum(stale), axis_name).astype(jnp.int32),
        waves=n_waves,
        substeps=n_sub,
        # a no-trigger buffered round carries the -1 sentinel internally;
        # report it as a zero-width horizon
        horizon=jnp.maximum(W, 0.0) if buffer_k is not None else W,
        tau_end=tau_end,
        backtracks=n_back,
        dt_min=dt_mn,
        dt_max=dt_mx,
        dt_sum=dt_sm,
        stale_hist=stale_histogram(
            table_new.stale_rounds, table_new.alive, axis_name
        ),
        max_stale=max_stale,
    )
    return x_c, I_new, dt_f, t + tau_end, table_new, stats

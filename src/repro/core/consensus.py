"""FedECADO consensus: Backward-Euler central step with closed-form
arrowhead (Schur) solve, local-truncation-error estimate, and the Algorithm-1
adaptive step-size backtracking loop.

Sign convention (documented in DESIGN.md): the paper's eqs. (5)-(7) carry a
sign inconsistency — taking (5) ẋ_c = Σ I_L and (6) ẋ_i = −∇f_i − I_L as
written, linear stability of the coupled system requires L·İ_L = x_i − x_c
(eq. 7 flipped). We implement that stable orientation; with it the fixed
point is x_i = x_c, I_i = −∇f_i(x_c), Σ_i I_i = 0 — a critical point of the
global objective, exactly as the paper intends.

BE system per synchronous time point τ→τ+Δt (all elementwise over params;
client axis A stacked on the leading dim):

  x_c⁺ = x_c + Δt·(Σ_a I_a⁺ + S_frozen)
  I_a⁺ = I_a + (Δt/L)·(Γ_a(τ+Δt) − (I_a⁺ − J_a)·g⁻¹_a − x_c⁺)

Closed form (arrowhead Schur complement — the TPU-native replacement for the
paper's LU factorization, DESIGN.md §2):

  d_a  = 1 + (Δt/L)·g⁻¹_a
  u_a  = (I_a + (Δt/L)·(Γ_a⁺ + J_a·g⁻¹_a)) / d_a
  w_a  = (Δt/L) / d_a
  x_c⁺ = (x_c + Δt·(Σ_a u_a + S_frozen)) / (1 + Δt·Σ_a w_a)
  I_a⁺ = u_a − w_a·x_c⁺
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gamma import gamma_stacked

Pytree = Any


def _fold0(x: jax.Array) -> jax.Array:
    """Strict left-fold Σ over the leading axis: the association is fixed
    by position, so zero rows are exact no-ops and the result is invariant
    to how many padding rows the layout carries — unlike ``jnp.sum``, whose
    XLA reduction tree depends on the axis LENGTH. The client-state cache's
    bitwise contract (sim/cache.py, DESIGN.md §13) rests on this for every
    reduction over a capacity-sized axis."""
    if x.shape[0] <= 1:
        return jnp.sum(x, axis=0)
    return jax.lax.scan(
        lambda c, r: (c + r, None), jnp.zeros(x.shape[1:], x.dtype), x
    )[0]


def _sum0(x: jax.Array, axis_name: Optional[str],
          fold: bool = False) -> jax.Array:
    """Σ over the leading (client) axis; cross-device ``psum`` when the
    client axis is sharded under ``shard_map`` (sim/sharded.py). ``fold``
    selects the layout-invariant left fold (event/table paths, where the
    leading axis is capacity-sized and differs between cached and
    materialized runs)."""
    s = _fold0(x) if fold else jnp.sum(x, axis=0)
    return jax.lax.psum(s, axis_name) if axis_name else s


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    L: float = 1.0                 # inductance hyperparameter
    delta: float = 1e-3            # LTE tolerance (Algorithm 1)
    dt_init: float = 0.1           # initial central step
    dt_max: float = 10.0
    max_backtracks: int = 8
    max_substeps: int = 64         # cap on BE steps per round
    use_kernels: bool = False      # fuse Γ+BE with the Pallas kernel path


def _bcast(v: jax.Array, like: jax.Array) -> jax.Array:
    """(A,) -> (A, 1, 1, ...) to broadcast against (A, ...) leaves."""
    return v.reshape((-1,) + (1,) * (like.ndim - 1))


def be_step(
    x_c: Pytree,
    I_a: Pytree,
    J_a: Pytree,
    gamma_a: Pytree,
    g_inv: jax.Array,
    S_frozen: Pytree,
    dt: jax.Array,
    L: float,
    axis_name: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    fold: bool = False,
):
    """One Backward-Euler consensus solve. Returns (x_c_new, I_a_new).

    Leaves: x_c (...); I_a/J_a/gamma_a (A, ...); g_inv (A,) scalar gains (or
    a pytree of (A, ...) diagonal gains); S_frozen (...) = Σ_{inactive} I_i.

    With ``axis_name`` the client axis is a local shard of a ``shard_map``
    program and the Schur sums Σ_a u_a, Σ_a w_a run as local partial sums +
    ``psum`` across devices. ``mask`` (A_local,) zeroes padded cohort rows
    out of both reductions (their I_new comes out 0 and is dropped by the
    caller's scatter).
    """
    r = dt / L
    diag_gains = not isinstance(g_inv, jax.Array)

    def per_leaf(xc, Ia, Ja, Ga, Sf, gi):
        gib = gi if diag_gains else _bcast(gi, Ia)
        d = 1.0 + r * gib
        u = (Ia + r * (Ga + Ja * gib)) / d
        w = (r / d) * jnp.ones_like(Ia)
        if mask is not None:
            mb = _bcast(mask, Ia)
            u = u * mb
            w = w * mb
        num = xc + dt * (_sum0(u, axis_name, fold) + Sf)
        den = 1.0 + dt * _sum0(w, axis_name, fold)
        xc_new = num / den
        I_new = u - w * xc_new[None]
        return xc_new, I_new

    leaves_xc, treedef = jax.tree.flatten(x_c)
    leaves_I = treedef.flatten_up_to(I_a)
    leaves_J = treedef.flatten_up_to(J_a)
    leaves_G = treedef.flatten_up_to(gamma_a)
    leaves_S = treedef.flatten_up_to(S_frozen)
    leaves_g = treedef.flatten_up_to(g_inv) if diag_gains else [g_inv] * len(leaves_xc)

    outs = [
        per_leaf(xc, Ia, Ja, Ga, Sf, gi)
        for xc, Ia, Ja, Ga, Sf, gi in zip(
            leaves_xc, leaves_I, leaves_J, leaves_G, leaves_S, leaves_g
        )
    ]
    x_c_new = jax.tree.unflatten(treedef, [o[0] for o in outs])
    I_new = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return x_c_new, I_new


def _flow_rhs(x_c, I_a, J_a, gamma_a, g_inv, L):
    """İ_a = (Γ_a − (I_a − J_a)·g⁻¹ − x_c) / L, per leaf."""
    diag_gains = not isinstance(g_inv, jax.Array)

    def per_leaf(xc, Ia, Ja, Ga, gi):
        gib = gi if diag_gains else _bcast(gi, Ia)
        return (Ga - (Ia - Ja) * gib - xc[None]) / L

    if diag_gains:
        return jax.tree.map(per_leaf, x_c, I_a, J_a, gamma_a, g_inv)
    return jax.tree.map(lambda xc, Ia, Ja, Ga: per_leaf(xc, Ia, Ja, Ga, g_inv),
                        x_c, I_a, J_a, gamma_a)


def lte(
    x_c, I_a, x_c_new, I_new, J_a, gamma_tau, gamma_new, g_inv, dt, L,
    axis_name: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    fold: bool = False,
) -> jax.Array:
    """max|ε_BE| over both eq. 29 (central) and eq. 30 (flow) terms.

    ``axis_name``/``mask`` follow ``be_step``: the client-axis sum in ε_C is
    psum-reduced and padded rows are excluded from both error terms, so the
    backtracking decision is identical on every device.
    """
    # ε_C = (Δt/2)·|Σ_a I⁺ − Σ_a I|  (frozen flows cancel)
    def leaf_c(a, b):
        d = b - a
        if mask is not None:
            d = d * _bcast(mask, d)
        return jnp.max(jnp.abs(_sum0(d, axis_name, fold)))

    eps_c = jax.tree.map(leaf_c, I_a, I_new)
    # ε_L = (Δt/2)·|İ(τ+Δt) − İ(τ)|
    rhs_old = _flow_rhs(x_c, I_a, J_a, gamma_tau, g_inv, L)
    rhs_new = _flow_rhs(x_c_new, I_new, J_a, gamma_new, g_inv, L)

    def leaf_l(a, b):
        d = jnp.abs(b - a)
        if mask is not None:
            d = d * _bcast(mask, d)
        return jnp.max(d)

    eps_l = jax.tree.map(leaf_l, rhs_old, rhs_new)
    m = jnp.maximum(
        jnp.max(jnp.stack(jax.tree.leaves(eps_c))),
        jnp.max(jnp.stack(jax.tree.leaves(eps_l))),
    )
    if axis_name:
        m = jax.lax.pmax(m, axis_name)
    return (dt / 2.0) * m


class StepResult(NamedTuple):
    x_c: Pytree
    I_a: Pytree
    dt_used: jax.Array
    eps: jax.Array
    n_backtracks: jax.Array


def adaptive_be_step(
    x_c: Pytree,
    I_a: Pytree,
    J_a: Pytree,
    x_prev_a: Pytree,
    x_new_a: Pytree,
    T_a: jax.Array,
    g_inv,
    S_frozen: Pytree,
    tau: jax.Array,
    dt0: jax.Array,
    ccfg: ConsensusConfig,
    axis_name: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    fold: bool = False,
) -> StepResult:
    """Algorithm 1: backtrack Δt until max|ε_BE| ≤ δ, then take the BE step.

    ``x_prev_a``/``x_new_a``/``T_a`` feed the Γ operator at trial times.
    With ``axis_name`` the client axis is sharded (see ``be_step``); every
    scalar driving the backtracking loop is psum/pmax-replicated, so all
    devices take the same trajectory through the while loop. ``fold``
    pins the Schur/LTE client sums to the layout-invariant left fold
    (capacity-axis callers, see ``_sum0``) — it also forces the non-kernel
    path, since the fused kernel reduces with its own association.
    """
    use_kernel = (
        ccfg.use_kernels
        and isinstance(g_inv, jax.Array)
        and axis_name is None   # the fused kernel reduces densely, no psum
        and not fold
    )
    if use_kernel:
        # Fused Pallas path: Γ + BE Schur + LTE in one pass over parameters,
        # with explicit per-client Γ anchors and an optional activity mask —
        # the anchored-masked form the event scheduler's stale flights need
        # (core/multirate.py), degenerating to the synchronous round when
        # x_prev_a is the broadcast x_c and the mask is None.
        from repro.kernels.ops import fused_consensus_step

        def trial(dt):
            xc_n, I_n, eps = fused_consensus_step(
                x_c, S_frozen, I_a, J_a, x_prev_a, x_new_a, T_a, g_inv,
                dt, tau, ccfg.L, mask=mask,
            )
            return xc_n, I_n, eps

    else:
        gamma_tau = gamma_stacked(x_prev_a, x_new_a, T_a, tau)

        def trial(dt):
            g_new = gamma_stacked(x_prev_a, x_new_a, T_a, tau + dt)
            xc_n, I_n = be_step(
                x_c, I_a, J_a, g_new, g_inv, S_frozen, dt, ccfg.L,
                axis_name=axis_name, mask=mask, fold=fold,
            )
            eps = lte(
                x_c, I_a, xc_n, I_n, J_a, gamma_tau, g_new, g_inv, dt, ccfg.L,
                axis_name=axis_name, mask=mask, fold=fold,
            )
            return xc_n, I_n, eps

    def cond(carry):
        dt, _, _, eps, k = carry
        return (eps > ccfg.delta) & (k < ccfg.max_backtracks)

    def body(carry):
        dt, _, _, eps, k = carry
        # Algorithm 1 line 3: Δt ← Δt · δ / max|ε|  (with a safety factor)
        dt = jnp.maximum(dt * 0.9 * ccfg.delta / jnp.maximum(eps, 1e-30), 1e-12)
        xc_n, I_n, eps = trial(dt)
        return dt, xc_n, I_n, eps, k + 1

    xc0, I0, eps0 = trial(dt0)
    dt, xc_n, I_n, eps, k = jax.lax.while_loop(
        cond, body, (dt0, xc0, I0, eps0, jnp.zeros((), jnp.int32))
    )
    return StepResult(xc_n, I_n, dt, eps, k)

"""The Γ linear interpolation/extrapolation operator (paper eq. 23).

Γ(x_i(·), τ) estimates client i's state at an arbitrary synchronous time τ
from two known samples — here the round-start state x_i(t0) (the broadcast
central state) and the end-of-window state x_i(t0 + T_i). For τ ≤ T_i this
interpolates; for τ > T_i (the client finished early) it extrapolates along
the same line. Both Theorem-1 properties (additivity, homogeneity) hold by
construction; tests/test_gamma.py checks them with hypothesis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gamma_leaf(x_prev: jax.Array, x_new: jax.Array, T: jax.Array, tau: jax.Array) -> jax.Array:
    """Elementwise Γ for one tensor. T, tau are scalars (relative to t0=0)."""
    frac = tau / jnp.maximum(T, 1e-12)
    return x_prev + (x_new - x_prev) * frac


def gamma(x_prev, x_new, T, tau):
    """Γ over pytrees. ``x_prev``/``x_new``: matching pytrees; ``T`` scalar
    per-client window; ``tau`` scalar synchronous time."""
    return jax.tree.map(lambda a, b: gamma_leaf(a, b, T, tau), x_prev, x_new)


def gamma_stacked(x_prev, x_new, T, tau):
    """Γ where every leaf carries a leading client axis and ``T`` is (A,).

    x_prev/x_new leaves: (A, ...); T: (A,); tau: scalar. Broadcasting aligns
    T against the client axis.
    """

    def leaf(a, b):
        frac = (tau / jnp.maximum(T, 1e-12)).reshape((-1,) + (1,) * (a.ndim - 1))
        return a + (b - a) * frac.astype(a.dtype)

    return jax.tree.map(leaf, x_prev, x_new)

"""FedECADO server state: central params, per-client flow variables, gains.

The flow variables I_L^i are parameter-shaped integral-controller states, one
per client (like SCAFFOLD control variates). They are stored stacked on a
leading client axis so the consensus math is batched elementwise — and, in
the distributed runtime, sharded over the mesh client/data axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class ServerState(NamedTuple):
    x_c: Pytree          # central params (fp32)
    I: Pytree            # flow variables, leaves (n_clients, ...)
    g_inv: Any           # (n_clients,) fp32 scalar inverse gains, or diag pytree
    t: jax.Array         # global continuous time
    dt_last: jax.Array   # adaptive step memory (warm-start for Algorithm 1)
    round: jax.Array     # communication round counter


def init_server_state(params: Pytree, n_clients: int, dt_init: float = 0.1) -> ServerState:
    x_c = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    I = jax.tree.map(lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32), params)
    g_inv = jnp.ones((n_clients,), jnp.float32)
    return ServerState(
        x_c=x_c,
        I=I,
        g_inv=g_inv,
        t=jnp.zeros((), jnp.float32),
        dt_last=jnp.asarray(dt_init, jnp.float32),
        round=jnp.zeros((), jnp.int32),
    )


def take_rows(tree: Pytree, idx: jax.Array) -> Pytree:
    """Gather client rows: leaves (n, ...) -> (A, ...)."""
    return jax.tree.map(lambda l: jnp.take(l, idx, axis=0), tree)


def put_rows(tree: Pytree, idx: jax.Array, rows: Pytree) -> Pytree:
    """Scatter client rows back: leaves (n, ...) <- (A, ...) at idx."""
    return jax.tree.map(lambda l, r: l.at[idx].set(r), tree, rows)


def tree_sum_clients(tree: Pytree) -> Pytree:
    """Σ over the leading client axis of every leaf, as a strict left fold.

    The fold order matters: ``jnp.sum`` lets XLA pick a reduction tree that
    depends on the leading-axis LENGTH, so the same nonzero rows sum to
    different bits in an (n, ...) materialized layout vs a (capacity, ...)
    client-cache packed layout (sim/cache.py). A sequential left fold makes
    interleaved zero rows exact no-ops, which is what the cached ==
    materialized bitwise guarantee (DESIGN.md §13) rests on. Called once
    per round, on (rows, |params|) arrays — the serialization is noise."""
    def leaf(l):
        if l.shape[0] <= 1:
            return jnp.sum(l, axis=0)
        return jax.lax.scan(
            lambda c, r: (c + r, None),
            jnp.zeros(l.shape[1:], l.dtype), l,
        )[0]

    return jax.tree.map(leaf, tree)


def gather_active(state: ServerState, active_idx: jax.Array):
    """Active-cohort views for a consensus solve: previous-round flows J_a,
    the frozen-flow sum S_frozen = Σ_{inactive} I_i, and the active gains
    (scalar (A,) or diag pytree rows). Shared by the synchronous round
    (core/fedecado.py) and the event scheduler (sim/events.py) so the
    flow-freezing bookkeeping cannot drift between the two."""
    J_a = take_rows(state.I, active_idx)
    S_all = tree_sum_clients(state.I)
    S_frozen = jax.tree.map(lambda s, j: s - jnp.sum(j, axis=0), S_all, J_a)
    g_inv_a = (
        jnp.take(state.g_inv, active_idx, axis=0)
        if isinstance(state.g_inv, jax.Array)
        else take_rows(state.g_inv, active_idx)
    )
    return J_a, S_frozen, g_inv_a


def broadcast_clients(tree: Pytree, n: int) -> Pytree:
    """x -> stacked (n, ...) copies."""
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), tree
    )

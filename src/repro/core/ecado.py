"""Plain ECADO (Agarwal & Pileggi 2023) — the synchronous, full-participation
ancestor of FedECADO. Kept as an ablation baseline: identical circuit model
and BE arrowhead solve, but

  * every client participates each round,
  * all clients share one window T (identical lr/epochs), so Γ degenerates to
    the endpoint value (no multi-rate synchronization needed),
  * gains are uniform (no p_i data weighting).

This is exactly what FedECADO §4 argues breaks under heterogeneity; the
benchmarks compare both to quantify the paper's two contributions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.consensus import ConsensusConfig
from repro.core.fedecado import server_round
from repro.core.flow import ServerState


def ecado_round(
    state: ServerState,
    x_new_all,                 # leaves (n, ...) — FULL participation
    T: jax.Array,              # scalar shared window
    ccfg: ConsensusConfig,
):
    n = jax.tree.leaves(x_new_all)[0].shape[0]
    T_a = jnp.full((n,), T, jnp.float32)
    idx = jnp.arange(n, dtype=jnp.int32)
    # uniform gains: overwrite whatever per-client gains exist
    uniform = state._replace(
        g_inv=(
            jnp.ones((n,), jnp.float32) * jnp.mean(state.g_inv)
            if isinstance(state.g_inv, jax.Array)
            else state.g_inv
        )
    )
    return server_round(uniform, x_new_all, T_a, idx, ccfg)

"""First-class heterogeneity-scenario registry (DESIGN.md §7).

Mirrors the fed/algorithms plugin registry: every named heterogeneity
regime is a frozen ``Scenario`` spec registered here, and the registry is
the ONLY place scenario names resolve. ``FedSim`` consumes
``FedSimConfig.scenario`` (a name or a ``Scenario`` instance) through
``make_scenario``, the CLI entry points (examples/, launch/sweep.py)
enumerate ``available_scenarios()`` for their ``--scenario`` choices, and
the sweep runner crosses this registry with the algorithm registry into the
paper-style evaluation matrix. Adding a scenario is one
``register_scenario(Scenario(...))`` call — zero edits anywhere else.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

from repro.scenarios.base import (
    ARRIVAL_KINDS,
    AVAILABILITY_KINDS,
    PARTITION_KINDS,
    ArrivalSpec,
    AvailabilitySpec,
    DeviceProfile,
    DropoutSpec,
    FeatureShiftSpec,
    PartitionSpec,
    Scenario,
    ScenarioRuntime,
)

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(spec: Scenario) -> Scenario:
    """Add ``spec`` to the registry under ``spec.name``. Duplicate names are
    rejected loudly — two scenarios silently shadowing each other would
    corrupt every sweep row labelled with that name."""
    if not spec.name:
        raise ValueError("a Scenario must carry a non-empty name to register")
    if spec.name in _REGISTRY:
        raise ValueError(f"scenario {spec.name!r} is already registered")
    _REGISTRY[spec.name] = spec
    return spec


def available_scenarios() -> Tuple[str, ...]:
    """Registered scenario names, in registration order."""
    return tuple(_REGISTRY)


def get_scenario(name: str) -> Scenario:
    """Resolve a name to its (frozen, declarative) ``Scenario`` spec."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered scenarios: "
            f"{', '.join(_REGISTRY)}"
        ) from None


def make_scenario(spec: Union[str, Scenario]) -> ScenarioRuntime:
    """Instantiate the runtime for a scenario name or an (ad-hoc, possibly
    unregistered) ``Scenario`` spec — one runtime per ``FedSim``, since it
    owns mutable trace/drift/profile state."""
    if isinstance(spec, str):
        spec = get_scenario(spec)
    if not isinstance(spec, Scenario):
        raise TypeError(
            f"scenario must be a registered name or a Scenario, got {spec!r}"
        )
    return ScenarioRuntime(spec)


# --- built-in scenarios ----------------------------------------------------
from repro.scenarios.library import BUILTIN_SCENARIOS, THREE_TIERS  # noqa: E402

for _spec in BUILTIN_SCENARIOS:
    register_scenario(_spec)

__all__ = [
    "Scenario", "ScenarioRuntime",
    "PartitionSpec", "FeatureShiftSpec", "DeviceProfile",
    "AvailabilitySpec", "ArrivalSpec", "DropoutSpec",
    "PARTITION_KINDS", "AVAILABILITY_KINDS", "ARRIVAL_KINDS",
    "register_scenario", "available_scenarios", "get_scenario",
    "make_scenario",
    "BUILTIN_SCENARIOS", "THREE_TIERS",
]

"""Declarative heterogeneity scenarios: spec dataclasses + runtime.

A ``Scenario`` composes the two heterogeneity axes the paper's §5 evaluation
sweeps (and that FedProx/FedNova/FedECADO react to differently):

**statistical skew** — what each client's data looks like:
  * ``partition``       which partitioner builds the client index sets
                        (iid | dirichlet(alpha) | label_shard(k) |
                        quantity_skew(zipf)), fed/partition.py;
  * ``feature_shift``   per-client input rotation/scale on the synthetic
                        teacher — client i sees s_i·R(θ_i)·x, a genuine
                        covariate shift the label skew axes cannot express;
  * ``label_noise``     per-client uniform label flips;
  * ``drift_every``     re-draw the partition every R rounds (concept
                        drift); each re-draw advances the partition seed
                        deterministically.

**systems** — how each client computes and when it shows up:
  * ``profiles``        device tiers: each client is pinned to a
                        ``DeviceProfile`` whose (lr, epochs) ranges drive
                        its per-round e_i/lr_i draws — replacing the single
                        uniform ``HeteroConfig`` envelope;
  * ``availability``    round-varying participation traces (sine diurnal /
                        timezone blocks / Markov churn) replacing the
                        uniform cohort draw in ``FedSim._draw_plan``;
  * ``dropout``         mid-round dropout: a hit client finishes only a
                        prefix of its local window, so its effective
                        T_i = lr_i·n_steps_i shrinks — exercising the event
                        backend's staleness/re-anchoring path and FedNova's
                        τ_i normalization.

All specs are frozen (hashable — ``FedSimConfig.scenario`` may carry one)
and purely declarative. Mutable evolution (Markov availability state, drift
counters, the client->profile pinning) lives in ``ScenarioRuntime``, one per
``FedSim``. Two rng domains keep backend equivalence intact: ``materialize``
uses a scenario-owned RandomState (never the sim's plan rng), while the
per-round hooks consume the sim's plan rng *inside* ``_draw_plan`` — so
every execution backend sees byte-identical ``CohortPlan`` streams and the
backend-equivalence harness extends to scenarios unchanged
(tests/test_backend_equiv.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fed.partition import (
    dirichlet_partition,
    iid_partition,
    label_shard_partition,
    quantity_skew_partition,
)

PARTITION_KINDS = ("iid", "dirichlet", "label_shard", "quantity_skew")

# population size above which availability/tier draws switch from the exact
# materialized-mask paths to the O(cohort) per-cid hash paths (million-client
# engine, DESIGN.md §13). Below it the legacy rng consumption is preserved
# bit-for-bit, so committed small-n trajectories never move.
LAZY_N = 4096

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _mix01(seed: int, salt: int, ids: np.ndarray) -> np.ndarray:
    """Deterministic per-id uniform [0, 1): splitmix64 finalizer over
    (seed, salt, id). Pure function of its arguments — no rng stream, no
    n-length state — so any subset of clients can be evaluated lazily and
    the answer never depends on who else was asked (DESIGN.md §13)."""
    with np.errstate(over="ignore"):      # wraparound is the point
        z = np.asarray(ids, np.uint64)
        z = z + (
            np.uint64(seed & 0x7FFFFFFF) * np.uint64(0x9E3779B97F4A7C15)
            + np.uint64(salt & 0x7FFFFFFF) * np.uint64(0xD1B54A32D192ED03)
        )
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
        return (z >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """Which partitioner builds the client index sets, with its knobs."""
    kind: str = "iid"
    alpha: float = 0.1              # dirichlet concentration
    shards_per_client: int = 2      # label_shard classes per client
    zipf_a: float = 1.4             # quantity_skew size exponent
    min_size: int = 2               # dirichlet / quantity_skew floor

    def build(self, labels: np.ndarray, n_clients: int, seed: int) -> List[np.ndarray]:
        if self.kind == "iid":
            return iid_partition(len(labels), n_clients, seed=seed)
        if self.kind == "dirichlet":
            return dirichlet_partition(
                labels, n_clients, self.alpha, seed=seed, min_size=self.min_size
            )
        if self.kind == "label_shard":
            return label_shard_partition(
                labels, n_clients, self.shards_per_client, seed=seed
            )
        if self.kind == "quantity_skew":
            return quantity_skew_partition(
                len(labels), n_clients, self.zipf_a, seed=seed,
                min_size=self.min_size,
            )
        raise ValueError(
            f"unknown partition kind {self.kind!r}; choose from {PARTITION_KINDS}"
        )


@dataclasses.dataclass(frozen=True)
class FeatureShiftSpec:
    """Per-client covariate shift x -> s_i·R(θ_i)·x: θ_i ~ U[-max_angle,
    max_angle] rotates each consecutive coordinate pair (a block-diagonal
    orthogonal map), s_i ~ U[scale_min, scale_max] rescales. Orthogonality
    keeps the teacher's decision structure recoverable, so the shift is a
    distribution mismatch rather than label destruction."""
    max_angle: float = 0.7854       # ~pi/4
    scale_min: float = 0.7
    scale_max: float = 1.3

    def apply(self, x: np.ndarray, rng: np.random.RandomState) -> np.ndarray:
        from repro.data.synthetic import rotate_scale

        theta = rng.uniform(-self.max_angle, self.max_angle)
        s = rng.uniform(self.scale_min, self.scale_max)
        return rotate_scale(x, theta, s)


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One device tier: assignment mass + the (lr_i, e_i) draw ranges of
    clients pinned to it (paper eqs. 43-44, stratified instead of one
    uniform envelope)."""
    name: str
    weight: float
    lr_min: float
    lr_max: float
    epochs_min: int
    epochs_max: int


AVAILABILITY_KINDS = ("sine", "blocks", "markov")


@dataclasses.dataclass(frozen=True)
class AvailabilitySpec:
    """Round-varying client availability.

    * ``sine``   — diurnal: client i is up with probability p_min +
                   (p_max−p_min)·(1+sin(2π(rnd/period + i/n)))/2 (phase
                   staggered across clients, so the available set rotates);
    * ``blocks`` — timezones: clients are split into ``n_blocks`` contiguous
                   blocks; only block (rnd mod n_blocks) is up (deterministic);
    * ``markov`` — churn: per-client two-state chain, up→down w.p. p_drop,
                   down→up w.p. p_recover each round.
    """
    kind: str = "sine"
    period: int = 12
    p_min: float = 0.1
    p_max: float = 0.9
    n_blocks: int = 4
    p_drop: float = 0.1
    p_recover: float = 0.5


ARRIVAL_KINDS = ("poisson", "diurnal")


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Arrival-process client traces (the buffered server's workload model,
    DESIGN.md §10) — generalizes the availability traces: availability
    restricts *who* can show up, arrivals decide *how many* endpoints land
    each server tick.

    * ``poisson`` — homogeneous arrivals: the round's cohort size is
                    k ~ Poisson(rate), clipped to [1, |pool|];
    * ``diurnal`` — a sinusoidally modulated rate: λ(rnd) = rate_min +
                    (rate − rate_min)·(1 + sin(2π·rnd/period))/2, then
                    k ~ Poisson(λ) as above.

    Draws consume the sim's plan rng inside ``_draw_plan`` (one Poisson +
    one choice per round), so the trace is deterministic in the run seed
    and byte-identical across execution backends."""
    kind: str = "poisson"
    rate: float = 8.0
    period: int = 12
    rate_min: float = 1.0


@dataclasses.dataclass(frozen=True)
class DropoutSpec:
    """Mid-round dropout: with probability ``prob`` a participating client
    finishes only a U[min_frac, 1) prefix of its local window (>= 1 step)."""
    prob: float = 0.3
    min_frac: float = 0.25


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative heterogeneity scenario (both axes composed)."""
    name: str
    description: str = ""
    # --- statistical skew axis ---
    partition: PartitionSpec = PartitionSpec()
    feature_shift: Optional[FeatureShiftSpec] = None
    label_noise: float = 0.0
    drift_every: int = 0
    # --- systems axis ---
    profiles: Tuple[DeviceProfile, ...] = ()
    availability: Optional[AvailabilitySpec] = None
    arrivals: Optional[ArrivalSpec] = None
    dropout: Optional[DropoutSpec] = None

    def axes(self) -> str:
        """Short human tag of the active axes (sweep table headers)."""
        tags = [self.partition.kind]
        if self.feature_shift:
            tags.append("fshift")
        if self.label_noise:
            tags.append(f"noise{self.label_noise:g}")
        if self.drift_every:
            tags.append(f"drift{self.drift_every}")
        if self.profiles:
            tags.append(f"{len(self.profiles)}tier")
        if self.availability:
            tags.append(self.availability.kind)
        if self.arrivals:
            tags.append(f"arr-{self.arrivals.kind}")
        if self.dropout:
            tags.append("dropout")
        return "+".join(tags)


class ScenarioRuntime:
    """Mutable per-``FedSim`` execution state of one ``Scenario``.

    ``materialize`` owns its rng (derived from the sim seed + drift count);
    the per-round hooks (``draw_cohort``/``draw_rates``/``apply_dropout``)
    consume the rng that ``FedSim._draw_plan`` passes in, keeping the plan
    stream identical across execution backends.
    """

    def __init__(self, spec: Scenario):
        self.spec = spec
        self.drift_count = 0
        self._tier_seed: Optional[int] = None           # per-cid tier hashing
        self._markov_up: Optional[np.ndarray] = None    # (n,) bool chain state

    # ------------------------------------------------------ statistical --
    def materialize(
        self, data: Dict[str, np.ndarray], n_clients: int, seed: int
    ) -> Tuple[Dict[str, np.ndarray], List[np.ndarray]]:
        """Partition ``data`` and apply the per-client statistical
        transforms (feature shift, label noise) to the samples each client
        owns. Returns (data', partitions); ``data`` itself is never mutated
        — a NEW dict (fresh identity, so device-side data caches re-upload)
        is returned iff a transform is active. Each call advances the drift
        counter, so re-invoking under ``drift_every`` re-draws the partition
        from a deterministically advanced seed."""
        spec = self.spec
        pseed = (seed + 100003 * self.drift_count) % (1 << 31)
        parts = spec.partition.build(
            np.asarray(data["y"]), n_clients, pseed
        )
        rng = np.random.RandomState((seed + 7 + 31 * self.drift_count) % (1 << 31))
        out = data
        if spec.feature_shift is not None or spec.label_noise > 0:
            out = {
                k: (np.array(v, copy=True) if k in ("x", "y") else v)
                for k, v in data.items()
            }
            n_classes = int(np.asarray(data["y"]).max()) + 1
            for part in parts:
                if spec.feature_shift is not None:
                    out["x"][part] = spec.feature_shift.apply(out["x"][part], rng)
                if spec.label_noise > 0:
                    y = out["y"]
                    flip = rng.rand(len(part)) < spec.label_noise
                    y[part[flip]] = rng.randint(
                        0, n_classes, int(flip.sum())
                    ).astype(y.dtype)
        if spec.profiles and self._tier_seed is None:
            # pinned once from a dedicated seed: device identity persists
            # across drift re-draws (the data moves, the hardware doesn't).
            # The pinning itself is LAZY — ``tier_of`` hashes (seed, cid) on
            # demand, so no n-length profile array is ever materialized
            # (million-client engine, DESIGN.md §13).
            self._tier_seed = (seed + 9176) % (1 << 31)
        self.drift_count += 1
        return out, parts

    def tier_of(self, cids: np.ndarray) -> np.ndarray:
        """Device-tier index of each cid, by deterministic per-cid hashing
        against the pinned tier seed: client i lands in tier t with mass
        weight_t / Σ weights, independently per client, and the answer for
        a cid never depends on how many other clients exist or which subset
        is asked — the lazy replacement of the old materialized (n,) pin."""
        assert self._tier_seed is not None, "materialize() must run first"
        w = np.asarray([p.weight for p in self.spec.profiles], np.float64)
        cum = np.cumsum(w / w.sum())
        u = _mix01(self._tier_seed, 0, np.asarray(cids, np.int64))
        return np.minimum(
            np.searchsorted(cum, u, side="right"), len(w) - 1
        ).astype(np.int64)

    def drift_due(self, rnd: int) -> bool:
        return bool(self.spec.drift_every) and rnd > 0 and rnd % self.spec.drift_every == 0

    # ---------------------------------------------------------- systems --
    def draw_cohort(
        self, rng: np.random.RandomState, rnd: int, n: int, A: int
    ) -> np.ndarray:
        """Participating client ids for round ``rnd``: the availability
        trace restricts the candidate pool, then up to ``A`` clients are
        drawn uniformly from it. No trace => the uniform draw of the
        default plan path (same rng consumption). An arrival trace
        (``ArrivalSpec``) replaces the fixed cohort size with a
        round-varying Poisson arrival count over the (possibly
        availability-restricted) pool."""
        av = self.spec.availability
        ar = self.spec.arrivals
        if av is None and ar is None:
            return np.sort(rng.choice(n, A, replace=False))
        if av is not None and av.kind == "sine" and n > LAZY_N:
            return self._draw_cohort_lazy_sine(rng, rnd, n, A)
        if av is None:
            up = np.ones(n, bool)
        elif av.kind == "sine":
            phase = 2.0 * np.pi * (rnd / max(av.period, 1) + np.arange(n) / n)
            p = av.p_min + (av.p_max - av.p_min) * 0.5 * (1.0 + np.sin(phase))
            up = rng.rand(n) < p
        elif av.kind == "blocks":
            # contiguous-block membership in closed form: block b holds
            # exactly the cids in [ceil(b·n/nb), ceil((b+1)·n/nb)) — bitwise
            # the same set as the materialized ``arange(n)·nb//n == b`` mask
            # without ever allocating it (the subsequent rng consumption is
            # identical, so small-n trajectories are unchanged)
            nb = av.n_blocks
            b = rnd % nb
            lo, hi = -((-b * n) // nb), -((-(b + 1) * n) // nb)
            up = None
            ids = np.arange(lo, hi)
        elif av.kind == "markov":
            # the churn chain is inherently sequential per-round state: each
            # client's up/down bit depends on its whole history, so there is
            # no per-cid closed form to hash. Documented O(n) exception
            # (DESIGN.md §13) — one bool + one float draw per client per
            # round, host-side only.
            if self._markov_up is None:
                self._markov_up = np.ones(n, bool)
            u = rng.rand(n)
            self._markov_up = np.where(
                self._markov_up, u >= av.p_drop, u < av.p_recover
            )
            up = self._markov_up
        else:
            raise ValueError(
                f"unknown availability kind {av.kind!r}; "
                f"choose from {AVAILABILITY_KINDS}"
            )
        if up is not None:
            ids = np.where(up)[0]
        if len(ids) == 0:
            ids = np.arange(n)       # never stall the server on an empty round
        if ar is not None:
            if ar.kind == "poisson":
                lam = float(ar.rate)
            elif ar.kind == "diurnal":
                lam = ar.rate_min + (ar.rate - ar.rate_min) * 0.5 * (
                    1.0 + np.sin(2.0 * np.pi * rnd / max(ar.period, 1))
                )
            else:
                raise ValueError(
                    f"unknown arrival kind {ar.kind!r}; "
                    f"choose from {ARRIVAL_KINDS}"
                )
            k = int(np.clip(rng.poisson(lam), 1, len(ids)))
            return np.sort(rng.choice(ids, k, replace=False))
        return np.sort(rng.choice(ids, min(A, len(ids)), replace=False))

    def _sine_up(self, salt: int, rnd: int, n: int,
                 cids: np.ndarray) -> np.ndarray:
        """Hash-based diurnal availability of a cid subset: same p_i curve
        as the materialized path, Bernoulli via the per-cid hash instead of
        an n-length rng draw."""
        av = self.spec.availability
        cids = np.asarray(cids, np.int64)
        phase = 2.0 * np.pi * (rnd / max(av.period, 1) + cids / n)
        p = av.p_min + (av.p_max - av.p_min) * 0.5 * (1.0 + np.sin(phase))
        return _mix01(salt, rnd, cids) < p

    def _draw_cohort_lazy_sine(
        self, rng: np.random.RandomState, rnd: int, n: int, A: int
    ) -> np.ndarray:
        """O(cohort) sine-availability cohort draw for large populations:
        rejection-sample candidate cids uniformly and keep the up ones,
        instead of materializing the n-length availability mask. One salt
        scalar comes off the plan rng (so the trace stays a pure function
        of the run seed and identical on every backend); up-ness is then
        per-cid hashed. Expected cost O(A / p̄); if availability is so
        scarce that the try budget runs out, falls back to the exact
        materialized mask (rare, still correct)."""
        ar = self.spec.arrivals
        salt = int(rng.randint(1 << 31))
        k = A
        if ar is not None:
            if ar.kind == "poisson":
                lam = float(ar.rate)
            elif ar.kind == "diurnal":
                lam = ar.rate_min + (ar.rate - ar.rate_min) * 0.5 * (
                    1.0 + np.sin(2.0 * np.pi * rnd / max(ar.period, 1))
                )
            else:
                raise ValueError(
                    f"unknown arrival kind {ar.kind!r}; "
                    f"choose from {ARRIVAL_KINDS}"
                )
            k = int(np.clip(rng.poisson(lam), 1, n))
        chosen: set = set()
        budget = max(64, 60 * k)
        while len(chosen) < k and budget > 0:
            m = min(max(2 * (k - len(chosen)), 32), budget)
            budget -= m
            cand = rng.randint(0, n, size=m)
            for c in cand[self._sine_up(salt, rnd, n, cand)]:
                chosen.add(int(c))
                if len(chosen) >= k:
                    break
        if len(chosen) < k:
            # scarce availability: one exact pass over the same hash mask
            ids = np.flatnonzero(self._sine_up(salt, rnd, n, np.arange(n)))
            if len(ids) == 0:
                ids = np.arange(n)
            return np.sort(rng.choice(ids, min(k, len(ids)), replace=False))
        return np.sort(np.fromiter(chosen, np.int64, len(chosen)))

    def draw_rates(
        self, rng: np.random.RandomState, idx: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-client (lr_i, e_i) draws from each client's pinned device
        profile — the stratified replacement of ``HeteroConfig.sample``.
        Tier lookup is the lazy per-cid hash (``tier_of``), evaluated for
        the cohort only."""
        tiers = self.tier_of(idx)
        lrs = np.empty(len(idx), np.float32)
        eps = np.empty(len(idx), np.int64)
        for j, t in enumerate(tiers):
            p = self.spec.profiles[int(t)]
            lrs[j] = rng.uniform(p.lr_min, p.lr_max)
            eps[j] = rng.randint(p.epochs_min, p.epochs_max + 1)
        return lrs, eps

    def apply_dropout(
        self, rng: np.random.RandomState, n_steps: np.ndarray
    ) -> np.ndarray:
        """Truncate dropped clients' step counts to a prefix of their
        window (>= 1 step). Runs BEFORE the minibatch draw, so the plan's
        ``batch_idx`` and windows T_i = lr_i·n_steps_i are consistent on
        every backend."""
        d = self.spec.dropout
        hit = rng.rand(len(n_steps)) < d.prob
        fracs = rng.uniform(d.min_frac, 1.0, len(n_steps))
        cut = np.maximum(1, np.ceil(fracs * n_steps)).astype(n_steps.dtype)
        return np.where(hit, np.minimum(cut, n_steps), n_steps)

    def step_ceiling(self, steps_per_epoch: int) -> Optional[int]:
        """Config-stable per-client scan-length ceiling under device
        profiles (the vectorized backend pads to this so its runner
        compiles once); None when the scenario does not drive rates."""
        if not self.spec.profiles:
            return None
        return max(p.epochs_max for p in self.spec.profiles) * steps_per_epoch

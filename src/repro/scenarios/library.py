"""Built-in scenarios: the evaluation matrix's rows.

Each entry composes the skew/systems axes of ``Scenario`` (base.py) into a
named, registered heterogeneity regime. ``dirichlet01`` is the paper's §5.1
headline setting; ``hetero-devices`` reproduces the §5.2 computational-
heterogeneity envelope as three device tiers; the rest extend the matrix
along the taxonomy of non-IID regimes (label shards, quantity skew,
covariate shift, label noise, drift) and client dynamics (diurnal
availability, Markov churn, mid-round dropout).

Registering a new scenario is one ``register_scenario(Scenario(...))`` call
— every CLI (`--scenario` in examples/, launch/sweep.py's matrix) picks it
up with zero further edits, exactly like the fed/algorithms registry.
"""
from __future__ import annotations

from repro.scenarios.base import (
    ArrivalSpec,
    AvailabilitySpec,
    DeviceProfile,
    DropoutSpec,
    FeatureShiftSpec,
    PartitionSpec,
    Scenario,
)

# three device tiers spanning the paper's eqs. (43)-(44) envelope
# (lr in [1e-3, 1e-2], e in [1, 5]) — stratified instead of one uniform draw
THREE_TIERS = (
    DeviceProfile("fast", weight=0.3, lr_min=5e-3, lr_max=1e-2,
                  epochs_min=4, epochs_max=5),
    DeviceProfile("mid", weight=0.5, lr_min=2e-3, lr_max=6e-3,
                  epochs_min=2, epochs_max=4),
    DeviceProfile("slow", weight=0.2, lr_min=1e-3, lr_max=3e-3,
                  epochs_min=1, epochs_max=2),
)

BUILTIN_SCENARIOS = (
    Scenario(
        "iid",
        "uniform IID partition, homogeneous synchronous clients (control)",
    ),
    Scenario(
        "dirichlet01",
        "paper §5.1: Dir(0.1) label skew, fixed client compute",
        partition=PartitionSpec("dirichlet", alpha=0.1),
    ),
    Scenario(
        "dirichlet1",
        "mild Dir(1.0) label skew",
        partition=PartitionSpec("dirichlet", alpha=1.0),
    ),
    Scenario(
        "label-shard2",
        "pathological split: <= 2 classes per client",
        partition=PartitionSpec("label_shard", shards_per_client=2),
    ),
    Scenario(
        "quantity-zipf",
        "IID labels, Zipf(1.4) client sizes (unbalanced p_i)",
        partition=PartitionSpec("quantity_skew", zipf_a=1.4),
    ),
    Scenario(
        "feature-shift",
        "IID labels + per-client input rotation/scale (covariate shift)",
        feature_shift=FeatureShiftSpec(),
    ),
    Scenario(
        "label-noise",
        "Dir(0.3) label skew + 15% per-client uniform label flips",
        partition=PartitionSpec("dirichlet", alpha=0.3),
        label_noise=0.15,
    ),
    Scenario(
        "drift",
        "Dir(0.3) label skew, partition re-drawn every 10 rounds",
        partition=PartitionSpec("dirichlet", alpha=0.3),
        drift_every=10,
    ),
    Scenario(
        "hetero-devices",
        "paper §5.2 regime: IID data, three-tier device speeds (lr_i, e_i)",
        profiles=THREE_TIERS,
    ),
    Scenario(
        "diurnal",
        "Dir(0.3) skew + sine (diurnal) availability + device tiers",
        partition=PartitionSpec("dirichlet", alpha=0.3),
        profiles=THREE_TIERS,
        availability=AvailabilitySpec("sine", period=12, p_min=0.2, p_max=0.9),
    ),
    Scenario(
        "flaky-dropout",
        "device tiers + 30% mid-round dropout (prefix windows -> staleness)",
        profiles=THREE_TIERS,
        dropout=DropoutSpec(prob=0.3, min_frac=0.25),
    ),
    Scenario(
        "heavy-traffic",
        "buffered-server workload: Poisson endpoint arrivals + device tiers",
        profiles=THREE_TIERS,
        arrivals=ArrivalSpec("poisson", rate=8.0),
    ),
    Scenario(
        "diurnal-traffic",
        "Dir(0.3) skew + diurnally modulated Poisson arrivals + tiers",
        partition=PartitionSpec("dirichlet", alpha=0.3),
        profiles=THREE_TIERS,
        arrivals=ArrivalSpec("diurnal", rate=10.0, period=12, rate_min=2.0),
    ),
    Scenario(
        "worst-case",
        "Dir(0.1) + covariate shift + tiers + Markov churn + dropout",
        partition=PartitionSpec("dirichlet", alpha=0.1),
        feature_shift=FeatureShiftSpec(),
        profiles=THREE_TIERS,
        availability=AvailabilitySpec("markov", p_drop=0.2, p_recover=0.5),
        dropout=DropoutSpec(prob=0.2, min_frac=0.3),
    ),
)

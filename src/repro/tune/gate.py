"""BENCH_* regression gates: committed baselines become enforced floors.

``compare_engine`` matches engine-bench rows on (algorithm, backend,
n_clients) and flags any candidate whose machine-normalized rounds/sec
falls more than ``threshold`` below the committed baseline. Normalization
uses the ``machine.calibration`` block the shared emitter stamps on every
report (``repro.tune.bench_io``): a candidate measured on a slower machine
is scaled up by the ratio of the two machines' calibration scores before
the comparison, so the gate tracks code regressions, not hardware
differences. Baselines committed before calibration existed compare at
scale 1.0 and the report says so.

``compare_comm`` guards the bytes/accuracy frontier: wire bytes are
deterministic accounting (repro/comm counts payload bytes, it does not
time anything), so ANY per-round upstream-bytes growth for a matched
(algorithm, scenario, compress, level) cell is erosion and fails at
threshold 0; accuracy regressions use the rounds/sec-style threshold.

CLI (wired into ``benchmarks/run.py --gate`` and the CI perf-gate job):

    python -m repro.tune.gate --kind engine --baseline BENCH_engine.json \
        --candidate /tmp/cand.json [--threshold 0.5] [--warn-only] \
        [--report gate-report/engine.json]

Exit status: 0 = pass (or --warn-only), 1 = regression, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

from repro.tune.calibrate import calib_score

DEFAULT_THRESHOLD = 0.5   # CI machines are noisy; the gate is a floor,
                          # not a tight perf test (DESIGN.md §12)


def _machine_scale(baseline: Dict, candidate: Dict) -> Dict[str, Any]:
    """rps scale factor applied to CANDIDATE rows: >1 means the candidate
    ran on a slower machine than the baseline and gets credit for it."""
    b = calib_score((baseline.get("machine") or {}).get("calibration"))
    c = calib_score((candidate.get("machine") or {}).get("calibration"))
    calibrated = (
        b != 1.0 and c != 1.0
        and (baseline.get("machine") or {}).get("calibration") is not None
        and (candidate.get("machine") or {}).get("calibration") is not None
    )
    return {
        "scale": (b / c) if calibrated else 1.0,
        "calibrated": calibrated,
        "baseline_score": b,
        "candidate_score": c,
    }


def _row_key(r: Dict) -> tuple:
    # participation entered the schema at v6 (sparse-cohort rows); older
    # baselines default to 1.0 so fully-dense rows keep matching across
    # schema versions.
    return (
        r.get("algorithm"), r.get("backend"), int(r.get("n_clients", -1)),
        float(r.get("participation", 1.0)),
    )


def compare_engine(
    baseline: Dict, candidate: Dict, threshold: float = DEFAULT_THRESHOLD
) -> Dict[str, Any]:
    """-> report {ok, violations, checked, skipped, normalization, ...}."""
    norm = _machine_scale(baseline, candidate)
    scale = norm["scale"]
    cand_rows = {_row_key(r): r for r in candidate.get("results", [])}
    violations: List[Dict] = []
    checked: List[Dict] = []
    skipped: List[tuple] = []
    for base in baseline.get("results", []):
        key = _row_key(base)
        cand = cand_rows.get(key)
        if cand is None:
            skipped.append(key)
            continue
        base_rps = float(base.get("rounds_per_sec", 0.0))
        cand_rps = float(cand.get("rounds_per_sec", 0.0)) * scale
        floor = base_rps * (1.0 - threshold)
        row = {
            "key": list(key),
            "baseline_rps": base_rps,
            "candidate_rps_normalized": cand_rps,
            "floor": floor,
            "ok": cand_rps >= floor,
        }
        problems: List[str] = []
        if not row["ok"]:
            problems.append(
                f"rps {cand_rps:.3f} < floor {floor:.3f} "
                f"(baseline {base_rps:.3f})"
            )
        # Memory gate: peak_state_bytes is deterministic accounting (no
        # machine normalization). At a fixed (alg, backend, n, q) cell any
        # growth past 2x the committed baseline means per-client state
        # stopped scaling with the cohort — the exact regression the
        # client-state cache exists to prevent. Only enforced when BOTH
        # rows carry the column (schema >= 6).
        b_mem = base.get("peak_state_bytes")
        c_mem = cand.get("peak_state_bytes")
        if b_mem is not None and c_mem is not None and float(b_mem) > 0:
            row["baseline_state_bytes"] = float(b_mem)
            row["candidate_state_bytes"] = float(c_mem)
            if float(c_mem) > 2.0 * float(b_mem):
                row["ok"] = False
                problems.append(
                    f"peak_state_bytes grew >2x: "
                    f"{float(b_mem):.0f} -> {float(c_mem):.0f}"
                )
        if problems:
            row["problems"] = problems
        checked.append(row)
        if not row["ok"]:
            violations.append(row)
    return {
        "kind": "engine",
        "ok": not violations,
        "threshold": threshold,
        "normalization": norm,
        "n_checked": len(checked),
        "checked": checked,
        "violations": violations,
        "skipped_rows": [list(k) for k in skipped],
    }


def _comm_key(r: Dict) -> tuple:
    return (
        r.get("algorithm"), r.get("scenario"),
        r.get("compress"), r.get("level"),
    )


def compare_comm(
    baseline: Dict, candidate: Dict, threshold: float = DEFAULT_THRESHOLD
) -> Dict[str, Any]:
    """Bytes-frontier gate. Matched cells may never grow their PER-ROUND
    wire bytes (bytes are deterministic accounting — no machine
    normalization, no threshold; any growth is erosion; the per-round
    normalization lets a short CI slice compare against the full committed
    run). ``acc_ratio`` (accuracy relative to the run's own lossless
    baseline, so it is comparable across round counts) may not drop more
    than ``threshold``; losing the dirichlet01 acceptance criterion
    (``criterion.ok``) while the baseline held it is a violation too."""
    b_rounds = max(1, int(baseline.get("rounds", 1)))
    c_rounds = max(1, int(candidate.get("rounds", 1)))
    cand_rows = {_comm_key(r): r for r in candidate.get("results", [])}
    violations: List[Dict] = []
    checked: List[Dict] = []
    skipped: List[tuple] = []
    for base in baseline.get("results", []):
        key = _comm_key(base)
        cand = cand_rows.get(key)
        if cand is None:
            skipped.append(key)
            continue
        problems = []
        for byte_col in ("bytes_up", "bytes_down"):
            b, c = base.get(byte_col), cand.get(byte_col)
            if b is None or c is None:
                continue
            b_pr, c_pr = float(b) / b_rounds, float(c) / c_rounds
            if c_pr > b_pr * (1.0 + 1e-9):
                problems.append(
                    f"{byte_col}/round grew {b_pr:.1f} -> {c_pr:.1f}"
                )
        b_ar, c_ar = base.get("acc_ratio"), cand.get("acc_ratio")
        if b_ar is not None and c_ar is not None:
            if float(c_ar) < float(b_ar) * (1.0 - threshold):
                problems.append(
                    f"acc_ratio regressed {float(b_ar):.4f} -> "
                    f"{float(c_ar):.4f}"
                )
        row = {"key": list(key), "ok": not problems, "problems": problems}
        checked.append(row)
        if problems:
            violations.append(row)
    crit_base = (baseline.get("criterion") or {}).get("ok")
    crit_cand = (candidate.get("criterion") or {}).get("ok")
    criterion_regressed = bool(crit_base) and crit_cand is False
    if criterion_regressed:
        violations.append({
            "key": ["criterion", "dirichlet01"],
            "ok": False,
            "problems": [
                "dirichlet01 acceptance criterion regressed: baseline "
                "held >=95% accuracy at <=25% uplink bytes, candidate "
                "has no witness"
            ],
        })
    return {
        "kind": "comm",
        "ok": not violations,
        "threshold": threshold,
        "rounds": {"baseline": b_rounds, "candidate": c_rounds},
        "criterion_regressed": criterion_regressed,
        "n_checked": len(checked),
        "checked": checked,
        "violations": violations,
        "skipped_rows": [list(k) for k in skipped],
    }


COMPARATORS = {"engine": compare_engine, "comm": compare_comm}


def write_report(report: Dict, path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def run_gate(
    kind: str,
    baseline_path: str,
    candidate_path: str,
    threshold: float = DEFAULT_THRESHOLD,
    report_path: Optional[str] = None,
    warn_only: bool = False,
    out=sys.stdout,
) -> int:
    if kind not in COMPARATORS:
        print(
            f"unknown gate kind {kind!r}; choose from "
            f"{sorted(COMPARATORS)}", file=sys.stderr,
        )
        return 2
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
        with open(candidate_path) as f:
            candidate = json.load(f)
    except (OSError, ValueError) as e:
        print(f"gate: cannot load inputs: {e}", file=sys.stderr)
        return 2
    report = COMPARATORS[kind](baseline, candidate, threshold)
    report["warn_only"] = warn_only
    if report_path:
        write_report(report, report_path)
    status = "PASS" if report["ok"] else ("WARN" if warn_only else "FAIL")
    print(
        f"[gate:{kind}] {status}: {len(report['violations'])} violation(s) "
        f"over {report['n_checked']} matched row(s), "
        f"threshold {threshold:.0%}",
        file=out,
    )
    for v in report["violations"]:
        detail = v.get("problems") or (
            f"rps {v['candidate_rps_normalized']:.3f} < floor {v['floor']:.3f}"
            f" (baseline {v['baseline_rps']:.3f})"
        )
        print(f"  - {v['key']}: {detail}", file=out)
    if report["ok"] or warn_only:
        return 0
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="BENCH_* perf regression gate (repro.tune.gate)"
    )
    ap.add_argument("--kind", choices=sorted(COMPARATORS), required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--candidate", required=True)
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    ap.add_argument("--warn-only", action="store_true")
    ap.add_argument("--report", default=None)
    args = ap.parse_args(argv)
    return run_gate(
        args.kind, args.baseline, args.candidate,
        threshold=args.threshold, report_path=args.report,
        warn_only=args.warn_only,
    )


if __name__ == "__main__":
    sys.exit(main())

"""Cost-model-driven backend selection: ``FedSimConfig.backend = "auto"``.

``resolve_auto`` scores every candidate execution backend for the concrete
(algorithm, n_clients, model shape, participation, consensus config) the
user is about to run, using per-dispatch hot-path costs lowered from real
HLO (``repro.tune.costmodel``) plus the machine's measured dispatch
overhead and parallel efficiency (``repro.tune.calibrate``). The scoring
rule (DESIGN.md §12) predicts seconds/round:

  sequential  = (A+1)·d + Tc + Ts            (A per-client dispatches)
  vectorized  =     2·d + Tc + Ts            (one cohort dispatch)
  sharded     = 2·d/S_sh + (Tc + Ts)/E + Xs  (jit-resident segments,
                                              E = max(1, n_dev·eff))
  event       = 2·d/S_ev + Tc + Tf/W + Xe    (flow dynamics only; the
                                              wave loop's static bound W
                                              overcounts coalesced rounds)

with d = measured dispatch overhead, Tc = cohort client compute,
Ts = server aggregation (consensus BE round for the flow family, batched
aggregation for the averaging family), Tf = flight-table integrate,
S_sh/S_ev = the backends' jit-resident segment lengths, and Xs/Xe the
calibrated collective-traffic terms of the respective hot paths. The
decision — chosen backend, every candidate's score, the raw cost terms,
the calibration, and the agreement with the committed BENCH_engine.json
row when one matches — is recorded in the PR-6 run-log header under
``autotune`` so predicted-vs-measured gaps stay auditable.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.tune import costmodel
from repro.tune.calibrate import Calibration, measure_calibration

Pytree = Any

# jit-resident segment lengths (sim/sharded.py, sim/events.py class attrs;
# imported lazily in _segment_rounds to keep this module import-light)
_FALLBACK_SEGMENTS = {"sharded": 32, "event": 16}


def _segment_rounds(backend: str) -> int:
    try:
        if backend == "sharded":
            from repro.sim.sharded import ShardedBackend

            return int(ShardedBackend.max_segment_rounds)
        if backend == "event":
            from repro.sim.events import EventBackend

            return int(EventBackend.max_segment_rounds)
    except Exception:
        pass
    return _FALLBACK_SEGMENTS.get(backend, 1)


@dataclasses.dataclass
class TuneDecision:
    """What the autotuner picked and why — run-log header material."""

    chosen: str
    scores: Dict[str, float]            # backend -> predicted s/round
    terms: Dict[str, Dict[str, Any]]    # hot path -> cost dict
    method: str                         # worst cost method used: hlo|measured
    kernel_flags: Dict[str, bool]
    calibration: Dict[str, float]
    n_clients: int
    cohort: int
    algorithm: str
    bench_reference: Optional[Dict[str, Any]] = None
    # peak-memory honesty (DESIGN.md §13): per-backend resident-bytes
    # estimates, the machine budget they were judged against, and which
    # candidates were penalized as OOM-bound — run-log header material
    memory: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def candidate_backends(alg) -> list:
    """The backends this algorithm can legally run on: the event scheduler
    integrates flow dynamics, so the averaging family skips it."""
    from repro.sim.engine import BACKENDS

    return [
        b for b in BACKENDS
        if b != "event" or getattr(alg, "has_flow_dynamics", False)
    ]


def find_bench_baseline(path: Optional[str] = None) -> Optional[Dict]:
    """Locate a committed BENCH_engine.json: explicit path, then
    $REPRO_BENCH_DIR, then cwd, then the repo root above this file."""
    candidates = []
    if path:
        candidates.append(path)
    env = os.environ.get("REPRO_BENCH_DIR")
    if env:
        candidates.append(os.path.join(env, "BENCH_engine.json"))
    candidates.append("BENCH_engine.json")
    here = os.path.dirname(os.path.abspath(__file__))
    candidates.append(
        os.path.join(here, "..", "..", "..", "BENCH_engine.json")
    )
    for c in candidates:
        try:
            with open(c) as f:
                return json.load(f)
        except (OSError, ValueError):
            continue
    return None


def _bench_reference(
    algorithm: str, n: int, chosen: str, scores: Dict[str, float]
) -> Optional[Dict[str, Any]]:
    """Compare the model's pick with the committed measurement, when the
    baseline has a row for this (algorithm, n_clients). ``event_buffered``
    rows are a config variant, not a backend name, so they are excluded."""
    bench = find_bench_baseline()
    if not bench:
        return None
    rows = [
        r for r in bench.get("results", [])
        if r.get("algorithm") == algorithm
        and int(r.get("n_clients", -1)) == int(n)
        and r.get("backend") in scores
    ]
    if not rows:
        return None
    fastest = max(rows, key=lambda r: r.get("rounds_per_sec", 0.0))
    measured = {
        r["backend"]: float(r["rounds_per_sec"]) for r in rows
    }
    pred_rps = {
        b: (1.0 / s if s > 0 else float("inf")) for b, s in scores.items()
    }
    return {
        "fastest_measured": fastest["backend"],
        "agrees": fastest["backend"] == chosen,
        "measured_rounds_per_sec": measured,
        "predicted_rounds_per_sec": {
            b: v for b, v in pred_rps.items() if np.isfinite(v)
        },
        # predicted-vs-measured gap of the chosen backend, when measurable
        "chosen_gap_ratio": (
            pred_rps[chosen] / measured[chosen]
            if chosen in measured and np.isfinite(pred_rps.get(chosen, np.inf))
            and measured[chosen] > 0 else None
        ),
    }


def _phys_mem_bytes() -> Optional[int]:
    """Physical RAM of this host, or None when the platform hides it."""
    try:
        pages = os.sysconf("SC_PHYS_PAGES")
        page = os.sysconf("SC_PAGE_SIZE")
        if pages > 0 and page > 0:
            return int(pages) * int(page)
    except (ValueError, OSError, AttributeError):
        pass
    return None


def _pow2_capacity(count: int) -> int:
    from repro.sim.cache import MIN_CAPACITY

    cap = MIN_CAPACITY
    while cap < count:
        cap *= 2
    return cap


def estimate_memory(
    cfg, alg, params: Pytree, data: Dict[str, np.ndarray],
    n: int, A: int, flow: bool, candidates: list,
) -> Dict[str, int]:
    """Per-backend peak resident-bytes estimate for this concrete run —
    the memory-honesty half of the cost model (DESIGN.md §13). Terms:

      * the dataset, uploaded once, plus the fp32 params;
      * per-client state rows: FedECADO's I + gains (or the averaging
        family's client/comm rows) over ``state_rows`` — n materialized,
        or the projected eviction-free cache capacity under
        ``client_cache`` (expected distinct participants over the run,
        pow2-rounded with 1.5x safety — capacity is monotone, so the
        projection IS the peak);
      * cohort working set: endpoint stacks + vmap grad intermediates,
        ~4 param-rows per active client;
      * jit-resident segments (sharded/event): the densified
        ``StackedPlan`` minibatch tensor (R, A, S, bs);
      * the event backend's flight table: two anchor stacks over capacity.
    """
    param_bytes = sum(
        int(np.asarray(l.size)) * 4 for l in jax.tree.leaves(params)
    )
    data_bytes = sum(
        int(np.asarray(v).nbytes) for v in data.values()
        if isinstance(v, np.ndarray) or hasattr(v, "nbytes")
    )
    if cfg.client_cache and not alg.full_participation_only:
        # expected distinct participants after R rounds of A-of-n draws
        R = max(1, int(cfg.rounds))
        expect = n * (1.0 - (1.0 - min(1.0, A / max(n, 1))) ** R)
        floor = int(cfg.cache_capacity) or max(
            2 * A, int(cfg.event_buffer_size or 0)
        )
        state_rows = _pow2_capacity(
            min(n, max(floor, int(1.5 * expect) + 1))
        )
    else:
        state_rows = n
    # flow: I rows + scalar gains; averaging: client/comm rows when stateful
    rows = 1 if flow else int(
        getattr(alg, "has_client_state", False)
    ) + int(not getattr(cfg, "comm", None) is None)
    state_bytes = state_rows * param_bytes * max(rows, 0) + state_rows * 4
    epochs_max = (
        cfg.hetero.epochs_max if cfg.hetero is not None else cfg.epochs_fixed
    )
    s_pad = max(1, int(epochs_max) * int(cfg.steps_per_epoch))
    cohort_bytes = 4 * A * param_bytes          # endpoints + grad temps
    plan_row_bytes = A * s_pad * int(cfg.batch_size) * 8

    est: Dict[str, int] = {}
    for b in candidates:
        total = data_bytes + param_bytes + state_bytes
        if b == "sequential":
            total += 4 * param_bytes + plan_row_bytes
        elif b == "vectorized":
            total += cohort_bytes + plan_row_bytes
        elif b == "sharded":
            total += cohort_bytes + plan_row_bytes * _segment_rounds("sharded")
        elif b == "event":
            total += (
                cohort_bytes
                + plan_row_bytes * _segment_rounds("event")
                + 2 * state_rows * param_bytes   # flight-table anchors
            )
        est[b] = int(total)
    return est


def score_backends(
    candidates: list,
    costs: Dict[str, costmodel.HotPathCost],
    cal: Calibration,
    A: int,
    server_path: str,
) -> Dict[str, float]:
    """Predicted seconds/round per candidate (the DESIGN.md §12 rule)."""
    d = max(cal.dispatch_s, 1e-7)
    Tc = costs["client_cohort"].seconds
    Ts = costs[server_path].seconds
    eff = max(1.0, cal.n_devices * cal.parallel_eff)
    scores: Dict[str, float] = {}
    for b in candidates:
        if b == "sequential":
            scores[b] = (A + 1) * d + Tc + Ts
        elif b == "vectorized":
            scores[b] = 2 * d + Tc + Ts
        elif b == "sharded":
            xs = costs[server_path].collective_bytes / max(cal.bytes_per_s, 1.0)
            scores[b] = (
                2 * d / _segment_rounds("sharded") + (Tc + Ts) / eff + xs
            )
        elif b == "event":
            fc = costs["flight_integrate"]
            waves = max(1, int(costs.get("_event_waves", 1) or 1))
            xe = fc.collective_bytes / max(cal.bytes_per_s, 1.0)
            scores[b] = (
                2 * d / _segment_rounds("event")
                + Tc + fc.seconds / waves + xe
            )
    return scores


def resolve_auto(
    cfg,
    alg,
    loss_fn: Callable,
    params: Pytree,
    data: Dict[str, np.ndarray],
) -> tuple:
    """Resolve ``backend="auto"`` → (concrete cfg copy, TuneDecision).

    Pure with respect to the simulation: consumes no host rng, mutates
    nothing — FedSim calls it right before ``get_backend``.
    """
    cal = measure_calibration()
    n = cfg.n_clients
    A = n if alg.full_participation_only else max(
        1, int(round(cfg.participation * n))
    )
    epochs_max = (
        cfg.hetero.epochs_max if cfg.hetero is not None else cfg.epochs_fixed
    )
    s_pad = max(1, int(epochs_max) * int(cfg.steps_per_epoch))

    kind = alg.client_kind
    mu = float(alg.client_mu()) if hasattr(alg, "client_mu") else 0.0
    flow = bool(getattr(alg, "has_flow_dynamics", False))

    costs: Dict[str, Any] = {
        "client_cohort": costmodel.client_cohort_cost(
            loss_fn, kind, mu, params, data, A, s_pad, cfg.batch_size, cal
        ),
    }
    if flow:
        costs["consensus"] = costmodel.consensus_cost(
            params, n, A, cfg.consensus, cal
        )
        costs["flight_integrate"] = costmodel.flight_integrate_cost(
            params, n, cfg.consensus, cfg.event_horizon,
            cfg.event_max_waves, cal,
        )
        costs["anchor_rebase"] = costmodel.anchor_rebase_cost(params, n, cal)
        costs["_event_waves"] = int(cfg.event_max_waves)
        server_path = "consensus"
    else:
        costs["batch_agg"] = costmodel.batch_agg_cost(
            params, A, cal, use_kernel=cfg.agg_kernels
        )
        server_path = "batch_agg"

    candidates = candidate_backends(alg)
    scores = score_backends(candidates, costs, cal, A, server_path)

    # memory honesty: a backend predicted to blow past physical RAM cannot
    # be the right answer however fast its hot path scores. The penalty is
    # folded INTO the score (scaled by the overage) so ``chosen`` remains
    # exactly argmin(scores) — and when every candidate is over budget the
    # least-oversubscribed one still wins instead of an arbitrary refusal.
    mem_est = estimate_memory(cfg, alg, params, data, n, A, flow, candidates)
    phys = _phys_mem_bytes()
    budget = int(0.8 * phys) if phys else None
    refused = []
    if budget:
        for b, m in mem_est.items():
            if m > budget:
                refused.append(b)
                scores[b] = scores[b] + 1e6 * (m / budget)
    memory = {
        "budget_bytes": budget,
        "estimates_bytes": {b: int(m) for b, m in mem_est.items()},
        "refused": sorted(refused),
    }

    chosen = min(scores, key=scores.get)

    # Pallas kernels run in interpret mode off-accelerator, where they never
    # beat the fused jnp path — only keep user-requested kernels on cpu
    kernel_flags = {
        "agg_kernels": bool(cfg.agg_kernels) and cal.platform != "cpu",
    }

    methods = [
        c.method for c in costs.values()
        if isinstance(c, costmodel.HotPathCost)
    ]
    method = (
        "measured" if "measured" in methods
        else "unavailable" if all(m == "unavailable" for m in methods)
        else "hlo"
    )

    decision = TuneDecision(
        chosen=chosen,
        scores={b: float(s) for b, s in scores.items()},
        terms={
            k: v.to_dict() for k, v in costs.items()
            if isinstance(v, costmodel.HotPathCost)
        },
        method=method,
        kernel_flags=kernel_flags,
        calibration=cal.to_dict(),
        n_clients=int(n),
        cohort=int(A),
        algorithm=alg.name,
        bench_reference=_bench_reference(alg.name, n, chosen, scores),
        memory=memory,
    )
    new_cfg = dataclasses.replace(
        cfg, backend=chosen, agg_kernels=kernel_flags["agg_kernels"]
    )
    return new_cfg, decision

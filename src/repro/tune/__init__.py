"""repro.tune — the cost-model subsystem (DESIGN.md §12).

Four pieces, one story: measure the machine (``calibrate``), lower the
real fed hot paths to HLO and count what they cost (``hlocost``,
``roofline``, ``costmodel``), pick the execution backend from those costs
(``autotune`` — ``FedSimConfig.backend = "auto"``), and hold every future
speed claim to the committed BENCH_* baselines (``gate``, ``bench_io``).
"""
from repro.tune.autotune import (  # noqa: F401
    TuneDecision,
    candidate_backends,
    resolve_auto,
    score_backends,
)
from repro.tune.bench_io import machine_block, write_bench_report  # noqa: F401
from repro.tune.calibrate import (  # noqa: F401
    Calibration,
    calib_score,
    measure_calibration,
)
from repro.tune.dtypes import DTYPE_BYTES, SHAPE_RE  # noqa: F401
from repro.tune.gate import (  # noqa: F401
    DEFAULT_THRESHOLD,
    compare_comm,
    compare_engine,
    run_gate,
)
from repro.tune.roofline import roofline_terms  # noqa: F401

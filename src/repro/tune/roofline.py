"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. The compiled module is already SPMD-partitioned, so
cost_analysis FLOPs/bytes and HLO operand sizes are PER-CHIP values —
terms divide by the per-chip rates only.

  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = Σ collective operand bytes / ICI_BW

Lives in ``repro.tune`` (the cost-model subsystem, DESIGN.md §12);
``launch/roofline.py`` is a thin re-export shim for old call sites.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.tune.dtypes import DTYPE_BYTES, SHAPE_RE, shape_literal_bytes

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# back-compat aliases: owned by repro.tune.dtypes since the roofline and
# hlocost copies had already diverged (this one lacked s4/u4/token)
_DTYPE_BYTES = DTYPE_BYTES
_SHAPE_RE = SHAPE_RE


def _shape_bytes(dtype: str, dims: str) -> int:
    return shape_literal_bytes(dtype, dims)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-tensor bytes of every collective op in the (post-SPMD,
    per-device) HLO. Returns {collective_kind: bytes} (+ "total")."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # Match op assignments like: %x = f32[..] all-reduce(...), or tuples
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        shape_part, opname = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shape_part)
        )
        out[kind] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(
    flops: float, bytes_accessed: float, collective_bytes: float
) -> Dict[str, float]:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = collective_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


def model_flops(cfg, shape, n_tokens: int = None) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the useful-compute yardstick.

    For decode steps D = batch (one token per sequence); backward pass
    multiplies by 3 for training shapes (6ND already includes it: 2ND fwd +
    4ND bwd). For inference shapes we use 2·N_active·D.
    """
    n_active = cfg.active_param_count()
    if n_tokens is None:
        n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * n_tokens

"""Shared HLO dtype byte-width table — the ONE copy.

``launch/roofline.py`` and ``launch/hlocost.py`` historically carried two
hand-copied (and already diverging: roofline's lacked ``s4``/``u4``/
``token``) ``_DTYPE_BYTES`` tables. Both parsers now import this module, so
adding a dtype (or fixing a width) propagates to every HLO cost consumer at
once. ``SHAPE_RE`` is the companion shape-literal regex, built from the
table so the two can never disagree about which dtypes are parseable.
"""
from __future__ import annotations

import re
from typing import Dict

# sub-byte dtypes round up to one byte: HLO buffers are byte-addressed
DTYPE_BYTES: Dict[str, int] = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

# longest-first alternation so e.g. "s64" can never half-match as "s4";
# "token" has no shape-literal form (token, not token[...]) so it is
# excluded from the regex but kept in the table for completeness
_SHAPE_DTYPES = sorted(
    (k for k in DTYPE_BYTES if k != "token"), key=len, reverse=True
)

SHAPE_RE = re.compile(
    r"\b(" + "|".join(_SHAPE_DTYPES) + r")\[([\d,]*)\]"
)


def shape_literal_bytes(dtype: str, dims: str) -> int:
    """Bytes of one ``dtype[dims]`` HLO shape literal (dims comma-joined)."""
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def text_bytes(text: str) -> int:
    """Total bytes of every array-shape literal in ``text`` (tuples sum)."""
    return sum(
        shape_literal_bytes(dt, dims) for dt, dims in SHAPE_RE.findall(text)
    )

"""Shared schema-versioned bench-report emitter.

The three bench writers (``benchmarks/run.py`` engine + comm,
``launch/sweep.py`` scenarios) historically each open-coded their
``json.dump``; this is the one place a BENCH_*.json gets persisted now.
The emitter

  * refuses reports without the ``schema_version``/``benchmark`` envelope
    (the gate and the artifact tests key on them),
  * stamps a top-level ``machine`` block — platform, device count, jax
    version, and the measured calibration (``repro.tune.calibrate``) that
    ``tune/gate.py`` uses to normalize rounds/sec across machines,
  * writes deterministic ``indent=2`` JSON with a trailing newline.

The block is stamped into the SAME dict the bench returns, so the
``persisted == report`` pin in tests/test_bench_engine.py stays exact.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict

BENCH_ENVELOPE_KEYS = ("schema_version", "benchmark")


def machine_block(calibrate: bool = True) -> Dict[str, Any]:
    import jax

    block: Dict[str, Any] = {
        "platform": jax.default_backend(),
        "n_devices": jax.device_count(),
        "jax_version": jax.__version__,
        "python": sys.version.split()[0],
    }
    if calibrate:
        try:
            from repro.tune.calibrate import measure_calibration

            block["calibration"] = measure_calibration().to_dict()
        except Exception as e:  # never let calibration sink a bench write
            block["calibration"] = None
            block["calibration_error"] = f"{type(e).__name__}: {e}"
    else:
        block["calibration"] = None
    return block


def write_bench_report(
    report: Dict[str, Any], path: str, calibrate: bool = True
) -> Dict[str, Any]:
    """Stamp the machine block into ``report`` and persist it at ``path``.

    Returns the (mutated) report. Raises ``ValueError`` on a report that
    lacks the schema envelope — catching drift at the writer, not in CI.
    """
    missing = [k for k in BENCH_ENVELOPE_KEYS if k not in report]
    if missing:
        raise ValueError(
            f"bench report for {path!r} is missing envelope key(s) "
            f"{missing}; every persisted bench carries "
            f"{list(BENCH_ENVELOPE_KEYS)} (repro.tune.bench_io)"
        )
    report["machine"] = machine_block(calibrate=calibrate)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report

"""Machine micro-calibration for the cost model and the perf gate.

The roofline constants in ``repro.tune.roofline`` describe the production
target (TPU v5e). Dev boxes and CI runners are CPUs — often CPUs pretending
to be 8 XLA host devices on one physical core — so both the auto-backend
scorer and the BENCH_* regression gate need *measured* machine rates:

  * ``flops_per_s``   — sustained f32 matmul rate (512³ GEMM)
  * ``bytes_per_s``   — sustained HBM/DRAM rate (saxpy over 8 MiB)
  * ``dispatch_s``    — per-call overhead of an already-compiled trivial jit
  * ``parallel_eff``  — speedup fraction of spreading a saxpy over all
    devices vs one device. Forced host devices share one core, so this is
    ≈1/n_devices there and ≈1 on real multi-chip hardware; it keeps the
    scorer from crediting ``sharded`` with parallelism the machine lacks.

Measurements are cached per process (keyed by platform) because they cost
a few hundred ms; ``measure_calibration(force=True)`` re-runs them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Calibration:
    flops_per_s: float
    bytes_per_s: float
    dispatch_s: float
    parallel_eff: float
    platform: str
    n_devices: int

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


_CACHE: Dict[str, Calibration] = {}


def _bench(fn, *args, reps: int = 3) -> float:
    """Best-of-reps wall seconds for one already-compiled call."""
    fn(*args)  # warm / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_matmul() -> float:
    n = 512
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    dt = _bench(f, a)
    return (2.0 * n**3) / max(dt, 1e-9)


def _measure_saxpy() -> float:
    n = 1 << 21  # 8 MiB of f32 — larger than any sane L2
    x = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda v: 2.0 * v + 1.0)
    dt = _bench(f, x)
    return (2.0 * 4 * n) / max(dt, 1e-9)  # read + write


def _measure_dispatch() -> float:
    f = jax.jit(lambda v: v + 1.0)
    x = jnp.float32(0.0)
    return _bench(f, x, reps=5)


def _measure_parallel_eff() -> float:
    n_dev = jax.device_count()
    if n_dev <= 1:
        return 1.0
    try:
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        n = (1 << 18) * n_dev
        mesh = Mesh(jax.devices(), ("d",))
        sharded = NamedSharding(mesh, P("d"))
        x = jax.device_put(jnp.ones((n,), jnp.float32), sharded)
        x1 = jax.device_put(jnp.ones((n,), jnp.float32), jax.devices()[0])
        f = jax.jit(lambda v: 2.0 * v + 1.0)
        t_sharded = _bench(f, x)
        t_single = _bench(f, x1)
        # perfect scaling => t_sharded == t_single / n_dev => eff == 1
        eff = t_single / (t_sharded * n_dev)
        return float(min(max(eff, 1.0 / (4 * n_dev)), 1.0))
    except Exception:
        return 1.0 / n_dev  # conservative: assume no real parallelism


def measure_calibration(force: bool = False) -> Calibration:
    platform = jax.default_backend()
    if not force and platform in _CACHE:
        return _CACHE[platform]
    cal = Calibration(
        flops_per_s=_measure_matmul(),
        bytes_per_s=_measure_saxpy(),
        dispatch_s=_measure_dispatch(),
        parallel_eff=_measure_parallel_eff(),
        platform=platform,
        n_devices=jax.device_count(),
    )
    _CACHE[platform] = cal
    return cal


def calib_score(cal: Optional[Dict[str, float]]) -> float:
    """Scalar machine-speed score for gate normalization.

    Geometric mean of the two sustained rates — dispatch overhead is left
    out because the gate compares round *throughput*, which the bench rows
    already amortize. Returns 1.0 for missing/partial blocks so baselines
    committed before calibration existed compare at scale 1 (uncalibrated).
    """
    if not cal:
        return 1.0
    f = cal.get("flops_per_s")
    b = cal.get("bytes_per_s")
    if not f or not b or f <= 0 or b <= 0:
        return 1.0
    return float((f * b) ** 0.5)

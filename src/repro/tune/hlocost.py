"""Trip-count-aware cost analysis of compiled (post-SPMD, per-device) HLO.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE, but our
models scan over layer periods / KV chunks / SSM time chunks, so FLOPs,
bytes and collective traffic inside loops must be multiplied by trip counts.
XLA records ``backend_config={"known_trip_count":{"n":...}}`` on while ops,
which lets us attribute an execution multiplier to every computation.

Cost model per executed computation (multiplied through the while nesting):
  * flops: dot ops: 2·prod(output dims)·prod(lhs contracting dims);
    convolution: 2·prod(output)·prod(kernel)·C_in (not used by our models).
  * bytes (HBM traffic proxy): Σ over non-trivial instructions of
    (output bytes + operand bytes); fusion internals are excluded (their
    intermediates stay in registers/VMEM) — only fusion boundaries count.
    This approximates each materialized tensor as read+written once.
  * collective bytes: output bytes per collective op kind ("-done" halves
    of async pairs are skipped to avoid double counting).

Lives in ``repro.tune`` (the cost-model subsystem, DESIGN.md §12);
``launch/hlocost.py`` is a thin re-export shim for old call sites.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.tune.dtypes import DTYPE_BYTES, SHAPE_RE, text_bytes

# back-compat aliases: the dtype table and shape regex are owned by
# repro.tune.dtypes — one copy for every HLO cost consumer
_DTYPE_BYTES = DTYPE_BYTES
_SHAPE_RE = SHAPE_RE

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)

_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_OPCODES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
    # control flow: carried state is aliased in place; the bodies are
    # visited and costed separately
    "while", "call", "conditional",
}


def _shape_bytes(text: str) -> int:
    return text_bytes(text)


def _shape_dims(text: str) -> List[int]:
    """Dims of the FIRST array shape in text."""
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


class Instr:
    __slots__ = ("name", "shape_text", "opcode", "rest", "out_bytes")

    def __init__(self, name, shape_text, opcode, rest):
        self.name = name
        self.shape_text = shape_text
        self.opcode = opcode
        self.rest = rest
        self.out_bytes = _shape_bytes(shape_text)


def parse_module(text: str):
    """-> (computations: {name: [Instr]}, entry_name, root_ops {name: opcode})."""
    comps: Dict[str, List[Instr]] = {}
    root_ops: Dict[str, str] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                is_entry, name = m.group(1), m.group(2)
                cur = name
                comps[cur] = []
                if is_entry:
                    entry = name
            continue
        ls = line.strip()
        if ls == "}" or ls.startswith("} //"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
            if ls.startswith("ROOT"):
                root_ops[cur] = m.group(3)
    return comps, entry, root_ops


_CALLED_SINGLE_RE = re.compile(r"(?:condition|body|to_apply)=%?([\w.\-]+)")
_CALLED_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_comps(rest: str) -> List[str]:
    out = list(_CALLED_SINGLE_RE.findall(rest))
    for group in _CALLED_BRANCHES_RE.findall(rest):
        out.extend(n.strip().lstrip("%") for n in group.split(",") if n.strip())
    return out
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.shape_text)
    out_n = 1
    for d in out_dims:
        out_n *= d
    m = _CONTRACT_RE.search(instr.rest)
    # first operand = lhs
    ops = _OPERAND_RE.findall(instr.rest.split("),")[0])
    if not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_shape)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    return 2.0 * out_n * k


def analyze(text: str) -> Dict[str, float]:
    comps, entry, root_ops = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}

    # per-computation local shape tables
    shape_tables = {
        name: {i.name: i.shape_text for i in instrs} for name, instrs in comps.items()
    }

    # Build multipliers by walking the call graph from ENTRY.
    mult: Dict[str, float] = {}
    unknown_trips = 0

    def visit(name: str, m: float):
        nonlocal unknown_trips
        mult[name] = mult.get(name, 0.0) + m
        for instr in comps.get(name, []):
            called: List[Tuple[str, float]] = []
            if instr.opcode == "while":
                tm = _TRIP_RE.search(instr.rest)
                trip = float(tm.group(1)) if tm else 1.0
                if not tm:
                    unknown_trips += 1
                # condition runs trip+1 times, body trip times; use trip
                for cname in _called_comps(instr.rest):
                    called.append((cname, trip))
            elif instr.opcode in ("call", "conditional", "custom-call", "async-start"):
                for cname in _called_comps(instr.rest):
                    called.append((cname, 1.0))
            for cname, factor in called:
                if cname in comps:
                    visit(cname, m * factor)

    visit(entry, 1.0)

    # fusion bodies are NOT executed standalone: exclude them from the walk
    # (they're referenced via calls= on fusion instrs, which we don't visit).

    flops = 0.0
    bytes_traffic = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}

    for name, m in mult.items():
        table = shape_tables[name]
        for instr in comps[name]:
            op = instr.opcode
            if op in _SKIP_OPCODES:
                continue
            if op == "dot":
                flops += m * _dot_flops(instr, table)
            # collectives (skip -done halves of async pairs)
            if not op.endswith("-done"):
                for ck in COLLECTIVE_KINDS:
                    if op == ck or op.startswith(ck + "-"):
                        coll[ck] += m * instr.out_bytes
                        break
            # bytes: output + operands (operand shapes resolved locally)
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = 2x the update slice, not
                # the full buffer (operand 1 is the update)
                refs = _OPERAND_RE.findall(instr.rest)
                upd = _shape_bytes(table.get(refs[1], "")) if len(refs) > 1 else 0
                bytes_traffic += m * 2 * upd
                continue
            if op == "dynamic-slice":
                bytes_traffic += m * 2 * instr.out_bytes
                continue
            if op == "fusion":
                # fusions whose root is a dynamic-(update-)slice operate
                # in place: count slice traffic, not the carried buffer
                # (the scan-ys stacking pattern — dominates recurrent archs)
                fm = _CALLS_RE.search(instr.rest)
                root = root_ops.get(fm.group(1)) if fm else None
                if root == "dynamic-update-slice":
                    upd = sum(
                        _shape_bytes(table[r])
                        for r in _OPERAND_RE.findall(instr.rest)
                        if r in table
                        and 16 < _shape_bytes(table[r]) != instr.out_bytes
                    )
                    bytes_traffic += m * 2 * upd
                    continue
                if root == "dynamic-slice":
                    bytes_traffic += m * 2 * instr.out_bytes
                    continue
            ob = instr.out_bytes
            operand_bytes = 0
            for ref in _OPERAND_RE.findall(instr.rest):
                if ref in table:
                    operand_bytes += _shape_bytes(table[ref])
            bytes_traffic += m * (ob + operand_bytes)

    out = {
        "flops": flops,
        "bytes": bytes_traffic,
        "collective_bytes": sum(coll.values()),
        "unknown_trip_counts": unknown_trips,
    }
    out.update({f"coll_{k}": v for k, v in coll.items()})
    return out

"""Hot-path cost extraction: lower the fed engine's real programs to HLO.

The autotuner does not guess what a backend costs — it lowers the actual
jitted hot paths (the vmap-over-scan client cohort, the FedECADO consensus
BE round, the averaging-family batched aggregation, the event scheduler's
flight-table integrate, the Γ anchor rebase) through ``jax.jit(...).lower``
on ``ShapeDtypeStruct``s (no real data, no execution), feeds the compiled
module text through the trip-count-aware analyzer (``repro.tune.hlocost``),
and converts FLOPs/bytes into seconds with the *measured* machine rates
from ``repro.tune.calibrate``.

When HLO text is unavailable on a platform (or the analyzer chokes on an
exotic module), ``job.cost()`` falls back to compiling and timing one real
execution on zero-filled inputs — the measured micro-calibration fallback.

Costs are cached per process keyed by (job, shape fingerprint, platform):
lowering the consensus round for a given (model, n, A) happens once even
when the autotuner scores many algorithms/backends against it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.tune import hlocost
from repro.tune.calibrate import Calibration

Pytree = Any


@dataclasses.dataclass(frozen=True)
class HotPathCost:
    """One lowered hot path, costed per dispatch."""

    name: str
    flops: float
    bytes: float
    collective_bytes: float
    seconds: float          # calibrated wall-seconds estimate per dispatch
    method: str             # "hlo" | "measured" | "unavailable"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _seconds_from_counts(
    flops: float, nbytes: float, cal: Calibration
) -> float:
    """Roofline with the machine's measured rates: the path takes at least
    as long as its compute and at least as long as its memory traffic."""
    return max(
        flops / max(cal.flops_per_s, 1.0),
        nbytes / max(cal.bytes_per_s, 1.0),
    )


def _sds(tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(jnp.shape(l), jnp.result_type(l)), tree
    )


def _zeros_of(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def _fingerprint(tree: Pytree) -> Tuple:
    return tuple(
        (l.shape, str(l.dtype)) for l in jax.tree.leaves(_sds(tree))
    )


_COST_CACHE: Dict[Tuple, HotPathCost] = {}


def clear_cache() -> None:
    _COST_CACHE.clear()


def path_cost(
    name: str,
    fn: Callable,
    args: Tuple,
    cal: Calibration,
    extra_key: Tuple = (),
) -> HotPathCost:
    """Cost one hot path: lower+analyze, else compile+time, else zero."""
    key = (name, cal.platform, _fingerprint(args), extra_key)
    hit = _COST_CACHE.get(key)
    if hit is not None:
        return hit
    sds = _sds(args)
    cost: Optional[HotPathCost] = None
    try:
        compiled = jax.jit(fn).lower(*sds).compile()
        hc = hlocost.analyze(compiled.as_text())
        cost = HotPathCost(
            name=name,
            flops=float(hc["flops"]),
            bytes=float(hc["bytes"]),
            collective_bytes=float(hc["collective_bytes"]),
            seconds=_seconds_from_counts(hc["flops"], hc["bytes"], cal),
            method="hlo",
        )
        if cost.flops == 0.0 and cost.bytes == 0.0:
            cost = None  # analyzer found nothing it understands: measure
    except Exception:
        cost = None
    if cost is None:
        try:
            jfn = jax.jit(fn)
            z = _zeros_of(sds)
            jax.block_until_ready(jfn(*z))  # compile + warm
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*z))
            cost = HotPathCost(
                name=name, flops=0.0, bytes=0.0, collective_bytes=0.0,
                seconds=time.perf_counter() - t0, method="measured",
            )
        except Exception:
            cost = HotPathCost(
                name=name, flops=0.0, bytes=0.0, collective_bytes=0.0,
                seconds=0.0, method="unavailable",
            )
    _COST_CACHE[key] = cost
    return cost


# ---------------------------------------------------------------------------
# the four fed hot paths
# ---------------------------------------------------------------------------


def client_cohort_cost(
    loss_fn: Callable,
    kind: str,
    mu: float,
    params: Pytree,
    data: Dict[str, np.ndarray],
    A: int,
    s_pad: int,
    batch_size: int,
    cal: Calibration,
) -> HotPathCost:
    """One vmapped cohort dispatch: A clients × s_pad local steps."""
    from repro.sim.vectorized import cohort_vmap_fn

    fn = cohort_vmap_fn(loss_fn, kind, mu)
    batches = {
        k: jax.ShapeDtypeStruct(
            (A, s_pad, batch_size) + np.shape(v)[1:], jnp.result_type(v)
        )
        for k, v in data.items()
    }
    p32 = jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), params)
    I_a = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((A,) + jnp.shape(l), jnp.float32), p32
    )
    args = (
        _sds(p32), I_a, batches,
        jax.ShapeDtypeStruct((A,), jnp.float32),   # lrs
        jax.ShapeDtypeStruct((A,), jnp.float32),   # ps
        jax.ShapeDtypeStruct((A,), jnp.int32),     # n_valid
    )
    return path_cost(
        "client_cohort", fn, args, cal, extra_key=(kind, float(mu))
    )


def consensus_cost(
    params: Pytree, n_clients: int, A: int, ccfg, cal: Calibration
) -> HotPathCost:
    """One FedECADO server round (Algorithm 2 steps 12-16, adaptive BE)."""
    from repro.core import init_server_state
    from repro.core.fedecado import server_round

    p32 = jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), params)
    state = jax.eval_shape(
        lambda p: init_server_state(p, n_clients=n_clients), p32
    )
    x_new_a = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((A,) + jnp.shape(l), jnp.float32), p32
    )
    fn = lambda st, xn, T, idx: server_round(st, xn, T, idx, ccfg)
    args = (
        state, x_new_a,
        jax.ShapeDtypeStruct((A,), jnp.float32),
        jax.ShapeDtypeStruct((A,), jnp.int32),
    )
    return path_cost(
        "consensus", fn, args, cal,
        extra_key=(ccfg.max_substeps, ccfg.max_backtracks),
    )


def batch_agg_cost(
    params: Pytree, A: int, cal: Calibration, use_kernel: bool = False
) -> HotPathCost:
    """The averaging-family cohort aggregation x_c + scale·Σ w·(x_a − x_c)."""
    from repro.kernels.ops import batched_aggregate

    p32 = jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), params)
    x_new_a = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((A,) + jnp.shape(l), jnp.float32), p32
    )
    fn = lambda xc, xn, w: batched_aggregate(
        xc, xn, w, 1.0, use_kernel=use_kernel
    )
    args = (_sds(p32), x_new_a, jax.ShapeDtypeStruct((A,), jnp.float32))
    return path_cost("batch_agg", fn, args, cal, extra_key=(use_kernel,))


def anchor_rebase_cost(
    params: Pytree, capacity: int, cal: Calibration, use_kernel: bool = False
) -> HotPathCost:
    """The event scheduler's masked Γ anchor-rebase over the flight table."""
    from repro.kernels.ops import anchor_rebase_op

    p32 = jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), params)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            (capacity,) + jnp.shape(l), jnp.float32
        ),
        p32,
    )
    fn = lambda xp, xn, frac, mask: anchor_rebase_op(
        xp, xn, frac, mask, use_kernel=use_kernel
    )
    args = (
        stacked, stacked,
        jax.ShapeDtypeStruct((capacity,), jnp.float32),
        jax.ShapeDtypeStruct((capacity,), jnp.float32),
    )
    return path_cost("anchor_rebase", fn, args, cal, extra_key=(use_kernel,))


def flight_integrate_cost(
    params: Pytree,
    n_clients: int,
    ccfg,
    horizon_quantile: float,
    max_waves: int,
    cal: Calibration,
) -> HotPathCost:
    """One event round over a capacity-n flight table (multi-rate form)."""
    from repro.core import init_server_state
    from repro.core.multirate import init_flight_table, multirate_integrate

    p32 = jax.tree.map(lambda l: jnp.asarray(l, jnp.float32), params)
    state = jax.eval_shape(
        lambda p: init_server_state(p, n_clients=n_clients), p32
    )
    table = jax.eval_shape(
        lambda p: init_flight_table(p, capacity=n_clients), p32
    )

    def fn(x_c, I, g_inv, dt_last, t, tbl):
        return multirate_integrate(
            x_c, I, g_inv, dt_last, t, tbl, ccfg,
            horizon_quantile, max_waves,
        )

    args = (
        state.x_c, state.I, state.g_inv, state.dt_last, state.t, table,
    )
    return path_cost(
        "flight_integrate", fn, args, cal,
        extra_key=(
            ccfg.max_substeps, ccfg.max_backtracks,
            float(horizon_quantile), int(max_waves),
        ),
    )

"""Federated runtime tests: partitioning, client sims, baselines, and the
end-to-end ordering claim (FedECADO >= baselines on heterogeneous non-IID)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import (
    FedSim,
    FedSimConfig,
    HeteroConfig,
    data_fractions,
    dirichlet_partition,
    fedavg_aggregate,
    fednova_aggregate,
    fedecado_client_sim,
    iid_partition,
    sgd_client,
)


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n_clients=st.integers(2, 20),
    alpha=st.floats(0.05, 10.0),
    seed=st.integers(0, 1000),
)
def test_dirichlet_partition_is_a_partition(n_clients, alpha, seed):
    labels = np.random.RandomState(seed).randint(0, 7, size=500)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500  # disjoint and complete
    p = data_fractions(parts)
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)


def test_dirichlet_skew_increases_with_small_alpha():
    labels = np.random.RandomState(0).randint(0, 10, size=5000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        # mean per-client label entropy (lower = more skew)
        ents = []
        for part in parts:
            cnt = np.bincount(labels[part], minlength=10) + 1e-9
            q = cnt / cnt.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert skew(0.05) < skew(100.0)


# ---------------------------------------------------------------------------
# clients
# ---------------------------------------------------------------------------


def _quad_loss(p, batch):
    return 0.5 * jnp.sum(jnp.square(p["w"] - batch["c"]))


def test_fedecado_client_integrates_flow_term():
    """With zero gradient, the FE client step must integrate ẋ = −I."""
    x0 = {"w": jnp.zeros((3,))}
    I = {"w": jnp.ones((3,))}
    batches = {"c": jnp.zeros((5, 3))}  # c=0 -> grad = x; starts at 0
    out = fedecado_client_sim(
        lambda p, b: 0.0 * _quad_loss(p, b), x0, I, batches, lr=0.1, p_i=1.0
    )
    # x after 5 steps of x <- x - 0.1*I = -0.5
    np.testing.assert_allclose(out.x_new["w"], -0.5 * np.ones(3), rtol=1e-6)
    np.testing.assert_allclose(float(out.T), 0.5, rtol=1e-6)


def test_sgd_client_descends():
    x0 = {"w": jnp.ones((3,)) * 5.0}
    batches = {"c": jnp.zeros((30, 3))}
    x, loss = sgd_client(_quad_loss, x0, batches, lr=0.1)
    # 30 steps of x <- 0.9 x: ||x|| = 5*sqrt(3)*0.9^30 ~= 0.37
    assert float(jnp.linalg.norm(x["w"])) < 1.0


def test_hetero_sampling_ranges():
    h = HeteroConfig(1e-4, 1e-3, 1, 10)
    rng = np.random.RandomState(0)
    lr, ep = h.sample(rng, 1000)
    assert lr.min() >= 1e-4 and lr.max() <= 1e-3
    assert ep.min() >= 1 and ep.max() <= 10


# ---------------------------------------------------------------------------
# aggregation baselines
# ---------------------------------------------------------------------------


def test_fedavg_weighted_mean():
    x_c = {"w": jnp.zeros((2,))}
    x_new = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0]])}
    p = jnp.asarray([1.0, 3.0])
    out = fedavg_aggregate(x_c, x_new, p)
    np.testing.assert_allclose(out["w"], [2.5, 2.5], rtol=1e-6)


def test_fednova_normalizes_objective_inconsistency():
    """A client that took 10x more steps must NOT dominate the update."""
    x_c = {"w": jnp.zeros((1,))}
    # client 0 moved 10x further because it ran 10x longer
    x_new = {"w": jnp.asarray([[10.0], [1.0]])}
    tau = jnp.asarray([10.0, 1.0])
    p = jnp.asarray([1.0, 1.0])
    out = fednova_aggregate(x_c, x_new, p, tau)
    # normalized deltas are both 1.0; tau_eff = 5.5 -> update 5.5
    np.testing.assert_allclose(out["w"], [5.5], rtol=1e-6)
    # fedavg would have given 5.5 too here only by coincidence of mean;
    # check the normalized property instead: both clients contribute equally
    out2 = fednova_aggregate(x_c, {"w": jnp.asarray([[20.0], [1.0]])}, p, jnp.asarray([20.0, 1.0]))
    np.testing.assert_allclose(out2["w"], [10.5], rtol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end ordering (the paper's claim at miniature scale)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mlp_problem():
    data = make_classification(1536, dim=16, n_classes=4, seed=0)
    parts = dirichlet_partition(data["y"], 12, alpha=0.3, seed=0)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params0 = {
        "w0": jax.random.normal(k1, (16, 32)) / 4.0,
        "b0": jnp.zeros((32,)),
        "w1": jax.random.normal(k2, (32, 4)) / np.sqrt(32),
        "b1": jnp.zeros((4,)),
    }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    return data, parts, params0, loss_fn, eval_fn


@pytest.mark.slow
def test_fedecado_beats_fedavg_on_heterogeneous_noniid(mlp_problem):
    data, parts, params0, loss_fn, eval_fn = mlp_problem
    accs = {}
    for alg in ("fedecado", "fedavg"):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=12, participation=0.33, rounds=50,
            batch_size=32, steps_per_epoch=3,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 5), seed=3, eval_every=50,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        accs[alg] = hist.metrics[-1]["acc"]
    # the paper's qualitative claim: FedECADO >= FedAvg under heterogeneity.
    # 50 rounds, not fewer: pre-convergence (~25 rounds) the gap is inside
    # seed noise and the ordering flips seed to seed; by 50 rounds FedECADO
    # leads by ~0.05-0.10 accuracy across every seed probed, so the assert
    # pins the structural advantage rather than a lucky draw.
    assert accs["fedecado"] >= accs["fedavg"] - 0.02, accs


def test_all_algorithms_run_one_round(mlp_problem):
    from repro.fed import available_algorithms

    data, parts, params0, loss_fn, eval_fn = mlp_problem
    for alg in available_algorithms():
        cfg = FedSimConfig(
            algorithm=alg, n_clients=12, participation=0.25, rounds=2,
            batch_size=16, steps_per_epoch=2, seed=0, eval_every=2,
            consensus=ConsensusConfig(max_substeps=8),
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        assert len(hist.loss) == 2
        assert np.isfinite(hist.loss[-1])


def test_diag_sensitivity_and_gain_refresh(mlp_problem):
    """eq. 42 variants: per-parameter (diagonal) gains and periodic Ḡ_th
    refresh both run and learn."""
    from repro.core import ConsensusConfig

    data, parts, params0, loss_fn, eval_fn = mlp_problem
    for sens, refresh in (("diag", 0), ("scalar", 3)):
        cfg = FedSimConfig(
            algorithm="fedecado", n_clients=12, participation=0.25, rounds=6,
            batch_size=16, steps_per_epoch=2, seed=0, eval_every=6,
            consensus=ConsensusConfig(L=0.01, max_substeps=8),
            sensitivity=sens, gain_update_every=refresh,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        assert np.isfinite(hist.loss[-1])
        if sens == "diag":
            # diag gains live as a pytree of (n, ...) leaves
            import jax as _jax
            assert not isinstance(sim.state.g_inv, _jax.Array)

"""Client-state cache equivalence + unit properties (DESIGN.md §13).

The million-client engine packs every per-client state row (FedECADO flow
variables/gains, FedADMM duals, EF residuals, the event flight table) into
``(capacity, ...)`` pytrees owned by ``sim/cache.py``. The load-bearing
guarantee — what makes the cache safe to turn on for ANY registered
algorithm — is **bitwise** equality with the materialized run: sorted
slots + exact-zero padding + the strict left-fold reductions
(``tree_sum_clients``, ``fold=True`` in consensus/multirate) mean the same
nonzero rows are visited in the same order with ``+0.0`` no-ops
interleaved, so not a single ULP may differ. This suite pins that across
the full algorithm registry × backend matrix at sparse participation,
through forced capacity growth (a mid-run repack), through the buffered
event server (repack with live flights), and pins the streaming plan
generator against the historical eager draw.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig, HeteroConfig, iid_partition
from repro.fed.algorithms import available_algorithms, get_algorithm
from repro.sim.cache import (
    MIN_CAPACITY, ClientStateCache, RepackPlan, repack_rows, state_nbytes,
)

ALGS = available_algorithms()
FLOW_ALGS = [a for a in ALGS if get_algorithm(a).has_flow_dynamics]
BACKENDS = ("sequential", "vectorized", "sharded", "event")

_PROBLEMS = {}


def _problem(n_clients=40):
    """Tiny shared problem with a real population (n_clients partitions),
    sized so sparse cohorts leave most clients untouched — the regime the
    cache exists for."""
    if n_clients not in _PROBLEMS:
        data = make_classification(max(384, 8 * n_clients), dim=6,
                                   n_classes=3, seed=11)
        parts = iid_partition(len(data["y"]), n_clients, seed=11)
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        params0 = {
            "w0": jax.random.normal(k1, (6, 8)) / 3.0,
            "b0": jnp.zeros((8,)),
            "w1": jax.random.normal(k2, (8, 3)) / np.sqrt(8),
            "b1": jnp.zeros((3,)),
        }

        def loss_fn(p, batch):
            h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
            lp = jax.nn.log_softmax(h)
            return -jnp.mean(
                jnp.take_along_axis(
                    lp, batch["y"][:, None].astype(jnp.int32), -1
                )
            )

        _PROBLEMS[n_clients] = (data, parts, params0, loss_fn)
    return _PROBLEMS[n_clients]


def _run(alg, backend, cached, n=40, participation=0.15, rounds=5, seed=7,
         **extra):
    data, parts, params0, loss_fn = _problem(n)
    cfg = FedSimConfig(
        algorithm=alg, n_clients=n, participation=participation,
        rounds=rounds, batch_size=4, steps_per_epoch=1,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 2), seed=seed, backend=backend,
        consensus=ConsensusConfig(max_substeps=6),
        client_cache=cached, **extra,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    hist = sim.run()
    return sim, hist


def _assert_bitwise(alg, backend, ref, got):
    sim_r, hist_r = ref
    sim_c, hist_c = got
    np.testing.assert_array_equal(
        np.asarray(hist_r.loss), np.asarray(hist_c.loss),
        err_msg=f"{alg}/{backend}: cached loss history not bitwise",
    )
    np.testing.assert_array_equal(
        hist_r.participation, hist_c.participation,
        err_msg=f"{alg}/{backend}: cached participation counts differ",
    )
    for (ka, a), (kb, b) in zip(
        sorted(jax.device_get(sim_r.current_params()).items()),
        sorted(jax.device_get(sim_c.current_params()).items()),
    ):
        assert ka == kb
        np.testing.assert_array_equal(
            a, b, err_msg=f"{alg}/{backend}: cached params[{ka}] not bitwise"
        )


# ---------------------------------------------------------------------------
# cached == materialized, bitwise, over the full registry × backend matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("alg", ALGS)
def test_cached_matches_materialized_bitwise(alg, backend):
    if backend == "event" and alg not in FLOW_ALGS:
        pytest.skip("event scheduler is flow-only")
    ref = _run(alg, backend, cached=False)
    got = _run(alg, backend, cached=True)
    assert got[0].cache is not None
    # participants-only witness: the packed capacity stays at/near the
    # cohort scale (full-participation algorithms admit everybody)
    assert got[0].cache.capacity >= got[0].cache.n_admitted
    _assert_bitwise(alg, backend, ref, got)


def test_forced_growth_repack_stays_bitwise():
    """n > MIN_CAPACITY with a cohort big enough that admissions cross the
    capacity boundary mid-run: the repack (gather + zero-fill + gain
    backfill for late admissions) must leave the trajectory untouched."""
    kw = dict(n=80, participation=0.3, rounds=6)
    ref = _run("fedecado", "vectorized", cached=False, **kw)
    got = _run("fedecado", "vectorized", cached=True, **kw)
    # the point of this test: capacity actually grew (a repack ran)
    assert got[0].cache.capacity > MIN_CAPACITY
    _assert_bitwise("fedecado", "vectorized", ref, got)


def test_event_buffered_repack_with_live_flights_stays_bitwise():
    """Buffered event server: flights survive across rounds, so a mid-run
    repack moves a flight table with LIVE rows (x_prev/x_new anchors,
    T_rem) to the new slot layout and rewrites the cid column. Still
    bitwise."""
    kw = dict(n=80, participation=0.3, rounds=6,
              event_buffered=True, event_buffer_size=8)
    ref = _run("fedecado", "event", cached=False, **kw)
    got = _run("fedecado", "event", cached=True, **kw)
    assert got[0].cache.capacity > MIN_CAPACITY
    _assert_bitwise("fedecado", "event", ref, got)


def test_peak_state_bytes_scales_with_cohort_not_population():
    # n must sit well above MIN_CAPACITY (tiny populations pack into the
    # 64-row floor, which is BIGGER than materializing n=40 rows)
    kw = dict(n=200, participation=0.1, rounds=5)
    sim_m, _ = _run("fedecado", "vectorized", cached=False, **kw)
    sim_c, _ = _run("fedecado", "vectorized", cached=True, **kw)
    assert 0 < state_nbytes(sim_c) < state_nbytes(sim_m)
    assert sim_c.state_rows < sim_m.state_rows == sim_m.n


# ---------------------------------------------------------------------------
# streaming plan generation == the historical eager draw
# ---------------------------------------------------------------------------


def test_plan_stream_matches_eager_draw():
    data, parts, params0, loss_fn = _problem(40)
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=40, participation=0.2, rounds=4,
        batch_size=4, steps_per_epoch=1, hetero=HeteroConfig(1e-3, 1e-2, 1, 2),
        seed=3, backend="vectorized",
    )
    A = max(1, int(round(cfg.participation * cfg.n_clients)))
    stream_sim = FedSim(loss_fn, params0, data, parts, cfg)
    streamed = list(stream_sim._plan_stream(0, 4, A))
    eager_sim = FedSim(loss_fn, params0, data, parts, cfg)
    eager = [eager_sim._draw_plan(r, A) for r in range(4)]
    assert len(streamed) == len(eager) == 4
    for s, e in zip(streamed, eager):
        assert s.rnd == e.rnd
        np.testing.assert_array_equal(s.idx, e.idx)
        np.testing.assert_array_equal(s.lrs, e.lrs)
        np.testing.assert_array_equal(s.epochs, e.epochs)
        np.testing.assert_array_equal(s.n_steps, e.n_steps)
        for sb, eb in zip(s.batch_idx, e.batch_idx):
            np.testing.assert_array_equal(sb, eb)


# ---------------------------------------------------------------------------
# hierarchical (tree-psum) aggregation on a 2-D mesh
# ---------------------------------------------------------------------------


def test_hierarchical_groups_matches_flat_sharded():
    """groups=2 over 4 forced host devices vs the flat 1-D mesh: the
    two-stage psum re-associates the cross-device Σ_a, so the pin is
    rtol 1e-6 (not bitwise — DESIGN.md §13). Runs in a subprocess because
    the forced device count must precede jax initialization."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ConsensusConfig
        from repro.data import make_classification
        from repro.fed import FedSim, FedSimConfig, HeteroConfig, iid_partition

        data = make_classification(384, dim=6, n_classes=3, seed=11)
        parts = iid_partition(len(data["y"]), 24, seed=11)
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        params0 = {
            "w0": jax.random.normal(k1, (6, 8)) / 3.0,
            "b0": jnp.zeros((8,)),
            "w1": jax.random.normal(k2, (8, 3)) / np.sqrt(8),
            "b1": jnp.zeros((3,)),
        }

        def loss_fn(p, batch):
            h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
            lp = jax.nn.log_softmax(h)
            return -jnp.mean(jnp.take_along_axis(
                lp, batch["y"][:, None].astype(jnp.int32), -1))

        runs = {}
        for groups in (None, 2):
            cfg = FedSimConfig(
                algorithm="fedecado", n_clients=24, participation=0.5,
                rounds=3, batch_size=4, steps_per_epoch=1,
                hetero=HeteroConfig(1e-3, 1e-2, 1, 2), seed=5,
                backend="sharded", consensus=ConsensusConfig(max_substeps=6),
                sharded_groups=groups,
            )
            sim = FedSim(loss_fn, params0, data, parts, cfg)
            hist = sim.run()
            runs[groups] = (np.asarray(hist.loss),
                            jax.device_get(sim.current_params()))
        flat_l, flat_p = runs[None]
        tree_l, tree_p = runs[2]
        np.testing.assert_allclose(tree_l, flat_l, rtol=1e-6, atol=1e-7)
        for k in flat_p:
            np.testing.assert_allclose(
                tree_p[k], flat_p[k], rtol=1e-6, atol=1e-7)
        print("HIERARCHICAL_OK", len(jax.devices()))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), os.pardir, "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    assert "HIERARCHICAL_OK 4" in proc.stdout


def test_sharded_groups_must_divide_devices():
    with pytest.raises(ValueError, match="must divide"):
        _run("fedecado", "sharded", cached=False, sharded_groups=3)


# ---------------------------------------------------------------------------
# ClientStateCache unit properties
# ---------------------------------------------------------------------------


def test_cache_admit_sorted_slots_and_growth():
    c = ClientStateCache(1000)
    assert c.capacity == MIN_CAPACITY and c.n_admitted == 0
    plan = c.admit(np.asarray([7, 3, 900, 3]))   # dupes collapse
    assert isinstance(plan, RepackPlan)
    np.testing.assert_array_equal(c.cids, [3, 7, 900])
    np.testing.assert_array_equal(c.slots_of([900, 3]), [2, 0])
    # everything was fresh: slots in increasing-cid order, src all -1
    np.testing.assert_array_equal(plan.fresh_cids, [3, 7, 900])
    assert (plan.src == -1).all() and plan.capacity == MIN_CAPACITY

    # re-admitting cached cids is a no-op
    assert c.admit(np.asarray([3, 900])) is None

    # crossing capacity doubles it and emits a full repack plan whose src
    # maps every surviving cid's old slot to its new (still sorted) slot
    plan2 = c.admit(np.arange(100, 100 + MIN_CAPACITY))
    assert c.capacity == 2 * MIN_CAPACITY
    assert plan2.n_admitted == 3 + MIN_CAPACITY
    old = [3, 7, 900]
    for old_slot, cid in enumerate(old):
        new_slot = int(np.searchsorted(c.cids, cid))
        assert plan2.src[new_slot] == old_slot


def test_cache_rejects_out_of_range_cids():
    c = ClientStateCache(10)
    with pytest.raises(ValueError, match="out of range"):
        c.admit(np.asarray([0, 10]))
    with pytest.raises(ValueError, match="out of range"):
        c.admit(np.asarray([-1]))


def test_cache_floor_capacity_is_live_from_construction():
    c = ClientStateCache(10_000, capacity=200)
    assert c.capacity == 256           # pow2 >= floor, before any admit
    c.admit(np.arange(10))
    assert c.capacity == 256           # floor sticks


def test_repack_rows_gathers_and_zero_fills():
    plan = RepackPlan(
        src=np.asarray([1, -1, 0, -1]), fresh=np.asarray([1]),
        fresh_cids=np.asarray([42]), capacity=4, n_admitted=3,
    )
    tree = {"a": jnp.asarray([[1.0, 2.0], [3.0, 4.0]]),
            "b": jnp.asarray([5, 6], jnp.int32)}
    out = repack_rows(tree, plan)
    np.testing.assert_array_equal(
        out["a"], [[3.0, 4.0], [0.0, 0.0], [1.0, 2.0], [0.0, 0.0]]
    )
    np.testing.assert_array_equal(out["b"], [6, 0, 5, 0])
    assert repack_rows(None, plan) is None

"""Multi-rate execution engine tests (repro/sim, DESIGN.md §5).

* backend equivalence: on the same seed (hence the same CohortPlan
  stream), the vectorized backend must reproduce the sequential reference
  oracle's histories and final central state for all four client kinds —
  fedecado, ecado, fedprox, and sgd (fedavg/fednova) — down to
  reduction-order ulps;
* event scheduler: staleness slicing must preserve the Σ_i I_i = 0
  fixed-point invariant of the consensus dynamics (DESIGN.md §5.3);
* batched-aggregation kernel path agrees with the jnp baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition
from repro.sim import CohortPlan, EventBackend, SequentialBackend, VectorizedBackend


@pytest.fixture(scope="module")
def mlp_problem():
    data = make_classification(1024, dim=12, n_classes=4, seed=1)
    # alpha small enough that some partitions are < batch_size -> exercises
    # the ragged-batch grouping of the vectorized runner
    parts = dirichlet_partition(data["y"], 10, alpha=0.3, seed=1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    params0 = {
        "w0": jax.random.normal(k1, (12, 24)) / 4.0,
        "b0": jnp.zeros((24,)),
        "w1": jax.random.normal(k2, (24, 4)) / np.sqrt(24),
        "b1": jnp.zeros((4,)),
    }

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
        lp = jax.nn.log_softmax(h)
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    return data, parts, params0, loss_fn


def _run(loss_fn, params0, data, parts, alg, backend, rounds=3, **kw):
    cfg = FedSimConfig(
        algorithm=alg, n_clients=len(parts), participation=0.4, rounds=rounds,
        batch_size=16, steps_per_epoch=2, hetero=HeteroConfig(1e-3, 1e-2, 1, 4),
        seed=7, backend=backend, consensus=ConsensusConfig(max_substeps=8), **kw,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    hist = sim.run()
    return sim, hist


# ---------------------------------------------------------------------------
# vectorized == sequential, all four client kinds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["fedecado", "ecado", "fedprox", "fedavg"])
def test_vectorized_matches_sequential(mlp_problem, alg):
    data, parts, params0, loss_fn = mlp_problem
    sim_s, hist_s = _run(loss_fn, params0, data, parts, alg, "sequential")
    sim_v, hist_v = _run(loss_fn, params0, data, parts, alg, "vectorized")

    # same plan stream -> same rounds; histories agree to reduction-order ulps
    np.testing.assert_allclose(hist_v.loss, hist_s.loss, rtol=1e-6, atol=1e-7)
    for a, b in zip(
        jax.tree.leaves(sim_s.current_params()),
        jax.tree.leaves(sim_v.current_params()),
        strict=True,
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_vectorized_cohort_bitwise_on_shared_plan(mlp_problem):
    """On ONE explicit plan the two backends' local integrations agree at
    fp32 resolution — per-client endpoints, windows, and step counts."""
    data, parts, params0, loss_fn = mlp_problem
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=len(parts), participation=0.5, rounds=1,
        batch_size=16, steps_per_epoch=2, hetero=HeteroConfig(1e-3, 1e-2, 1, 4),
        seed=11, backend="sequential",
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    plan = sim._draw_plan(0, 5)
    res_s = SequentialBackend().run_cohort(sim, plan)
    res_v = VectorizedBackend().run_cohort(sim, plan)

    assert res_s.Ts == res_v.Ts
    assert res_s.taus == res_v.taus
    np.testing.assert_allclose(res_v.losses, res_s.losses, rtol=1e-6, atol=1e-7)
    for a, b in zip(
        jax.tree.leaves(res_s.x_new_a), jax.tree.leaves(res_v.x_new_a), strict=True
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7
        )


def test_plan_is_deterministic_per_seed(mlp_problem):
    data, parts, params0, loss_fn = mlp_problem
    plans = []
    for _ in range(2):
        cfg = FedSimConfig(
            algorithm="fedavg", n_clients=len(parts), participation=0.4, rounds=1,
            batch_size=16, steps_per_epoch=2, seed=5,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        plans.append(sim._draw_plan(0, 4))
    a, b = plans
    assert isinstance(a, CohortPlan)
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.lrs, b.lrs)
    for x, y in zip(a.batch_idx, b.batch_idx, strict=True):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# event scheduler
# ---------------------------------------------------------------------------


def test_event_staleness_preserves_flow_invariant():
    """At the consensus fixed point (x_i = x_c*, I_i = −p̂_i∇f_i(x_c*),
    Σ_i I_i = 0) the event scheduler must leave the state stationary no
    matter how arrivals are sliced into waves or delayed by staleness
    (DESIGN.md §5.3)."""
    n, dim = 4, 3
    # one data point per client, centred so the optimum is x* = 0 and the
    # per-client gradients at x* sum to zero
    cs = np.asarray(
        [[1.0, -2.0, 0.5], [-1.0, 2.0, -0.5], [2.0, 1.0, -1.0], [-2.0, -1.0, 1.0]],
        np.float32,
    )
    assert np.abs(cs.sum(0)).max() == 0.0
    data = {"x": cs, "y": np.zeros((n,), np.int64)}
    parts = [np.asarray([i]) for i in range(n)]

    def loss_fn(p, batch):
        return 0.5 * jnp.mean(jnp.sum(jnp.square(p["w"][None] - batch["x"]), -1))

    params0 = {"w": jnp.zeros((dim,), jnp.float32)}
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=n, participation=1.0, rounds=6,
        batch_size=4, steps_per_epoch=3, lr_fixed=5e-3, epochs_fixed=2,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 5),    # heterogeneous windows
        seed=0, backend="event", event_horizon=0.5, event_max_waves=3,
        consensus=ConsensusConfig(L=0.1, max_substeps=16),
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    # place the server exactly at the fixed point: ∇f_i(0) = −c_i and
    # p̂_i = 1, so I_i = −p̂_i·∇f_i(x*) = c_i with Σ_i I_i = 0
    sim.state = sim.state._replace(I={"w": jnp.asarray(cs, jnp.float32)})

    hist = sim.run()
    x_c = np.asarray(sim.state.x_c["w"])
    I_sum = np.asarray(jnp.sum(sim.state.I["w"], axis=0))
    np.testing.assert_allclose(x_c, np.zeros(dim), atol=1e-5)
    np.testing.assert_allclose(I_sum, np.zeros(dim), atol=1e-5)
    assert np.isfinite(hist.loss).all()


def test_event_backend_exercises_staleness():
    """With a sub-1 horizon quantile and heterogeneous windows, some client
    must actually be carried across a round boundary."""
    data = make_classification(256, dim=6, n_classes=3, seed=2)
    parts = dirichlet_partition(data["y"], 6, alpha=0.5, seed=2)
    params0 = {"w": jax.random.normal(jax.random.PRNGKey(2), (6, 3)) / 3.0}

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(batch["x"] @ p["w"])
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=6, participation=0.5, rounds=5,
        batch_size=16, steps_per_epoch=2, hetero=HeteroConfig(1e-3, 1e-2, 1, 5),
        seed=3, backend="event", event_horizon=0.5, event_max_waves=2,
        consensus=ConsensusConfig(max_substeps=8),
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    stale_seen = 0
    for _ in range(cfg.rounds):
        plan = sim._draw_plan(0, 3)
        sim.backend.run_round(sim, plan)
        stale_seen += sim.backend.last_round_stats["stale"]
    assert stale_seen > 0
    assert isinstance(sim.backend, EventBackend)


def test_event_backend_rejects_averaging_algorithms():
    data = make_classification(64, dim=4, n_classes=2, seed=0)
    parts = dirichlet_partition(data["y"], 4, alpha=1.0, seed=0)
    params0 = {"w": jnp.zeros((4, 2))}
    loss_fn = lambda p, b: jnp.mean(jnp.square(b["x"] @ p["w"]))
    cfg = FedSimConfig(
        algorithm="fedavg", n_clients=4, participation=0.5, rounds=1,
        batch_size=8, steps_per_epoch=1, seed=0, backend="event",
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    with pytest.raises(ValueError, match="event backend"):
        sim.run()


# ---------------------------------------------------------------------------
# sharded backend
# ---------------------------------------------------------------------------


def test_sharded_uneven_padding_preserves_flow_invariant():
    """At the consensus fixed point (x_i = x_c*, I_i = −p̂_i∇f_i(x_c*),
    Σ_i I_i = 0) the sharded backend must leave the state stationary even
    when the cohort does not divide the padding unit — the padded rows'
    masked u_a/w_a contributions and the out-of-bounds flow scatter must be
    exact no-ops (DESIGN.md §5.5). ``sharded_pad_multiple=3`` forces A=4 →
    A_pad=6 so uneven client→device padding is exercised regardless of the
    host's device count (the CI multi-device job re-runs this on 8)."""
    n, dim = 4, 3
    cs = np.asarray(
        [[1.0, -2.0, 0.5], [-1.0, 2.0, -0.5], [2.0, 1.0, -1.0], [-2.0, -1.0, 1.0]],
        np.float32,
    )
    assert np.abs(cs.sum(0)).max() == 0.0
    data = {"x": cs, "y": np.zeros((n,), np.int64)}
    parts = [np.asarray([i]) for i in range(n)]

    def loss_fn(p, batch):
        return 0.5 * jnp.mean(jnp.sum(jnp.square(p["w"][None] - batch["x"]), -1))

    params0 = {"w": jnp.zeros((dim,), jnp.float32)}
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=n, participation=1.0, rounds=6,
        batch_size=4, steps_per_epoch=3, lr_fixed=5e-3, epochs_fixed=2,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 5),    # heterogeneous windows
        seed=0, backend="sharded", sharded_pad_multiple=3,
        consensus=ConsensusConfig(L=0.1, max_substeps=16),
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    from repro.sim import ShardedBackend

    assert isinstance(sim.backend, ShardedBackend)
    assert sim.backend._a_pad(n) > n     # genuinely uneven padding
    # place the server exactly at the fixed point (see the event-backend
    # invariant test above for the derivation)
    sim.state = sim.state._replace(I={"w": jnp.asarray(cs, jnp.float32)})

    hist = sim.run()
    x_c = np.asarray(sim.state.x_c["w"])
    I_sum = np.asarray(jnp.sum(sim.state.I["w"], axis=0))
    np.testing.assert_allclose(x_c, np.zeros(dim), atol=1e-5)
    np.testing.assert_allclose(I_sum, np.zeros(dim), atol=1e-5)
    assert np.isfinite(hist.loss).all()


def test_sharded_matches_sequential(mlp_problem):
    """Same plan stream → the sharded backend reproduces the sequential
    oracle's histories and central state (the ragged partitions of the
    fixture also route some rounds through the grouped fallback path)."""
    data, parts, params0, loss_fn = mlp_problem
    sim_s, hist_s = _run(loss_fn, params0, data, parts, "fedecado", "sequential")
    sim_x, hist_x = _run(
        loss_fn, params0, data, parts, "fedecado", "sharded",
        sharded_pad_multiple=3,
    )
    np.testing.assert_allclose(hist_x.loss, hist_s.loss, rtol=1e-6, atol=1e-7)
    for a, b in zip(
        jax.tree.leaves(sim_s.current_params()),
        jax.tree.leaves(sim_x.current_params()),
        strict=True,
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_sharded_rejects_diag_gains(mlp_problem):
    data, parts, params0, loss_fn = mlp_problem
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=len(parts), participation=0.4, rounds=1,
        batch_size=16, steps_per_epoch=2, seed=7, backend="sharded",
        sensitivity="diag",
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    with pytest.raises(NotImplementedError, match="scalar sensitivity gains"):
        sim.run()


# ---------------------------------------------------------------------------
# batched-aggregation kernel path
# ---------------------------------------------------------------------------


def test_agg_kernels_match_baseline_aggregation(mlp_problem):
    data, parts, params0, loss_fn = mlp_problem
    for alg in ("fedavg", "fednova"):
        sim_a, hist_a = _run(loss_fn, params0, data, parts, alg, "vectorized")
        sim_b, hist_b = _run(
            loss_fn, params0, data, parts, alg, "vectorized", agg_kernels=True
        )
        np.testing.assert_allclose(hist_b.loss, hist_a.loss, rtol=1e-5)
        for a, b in zip(
            jax.tree.leaves(sim_a.current_params()),
            jax.tree.leaves(sim_b.current_params()),
            strict=True,
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6
            )

import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 placeholders.

# hypothesis is an optional test dependency (pyproject.toml [test] extras).
# When absent, install the deterministic fallback so the property suites
# still execute instead of killing collection with ModuleNotFoundError.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util
    import os

    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)

"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(<=2 layers, d_model<=256, <=4 experts) runs one forward/train step and one
decode step on CPU; output shapes + no NaNs asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (
    build_cross_cache,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_batch,
)
from repro.models.transformer import _encode

B, S = 2, 64


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            key = jax.random.PRNGKey(0)
            params = init_params(key, cfg)
            batch = make_batch(key, cfg, B, S)
            cache[arch] = (cfg, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_config_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, arch_setup):
    cfg, params, batch = arch_setup(arch)
    logits, aux = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch, arch_setup):
    cfg, params, batch = arch_setup(arch)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # one FE step with a zero flow variable == plain SGD step; params change
    new = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    delta = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(new), jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, arch_setup):
    cfg, params, batch = arch_setup(arch)
    W = 128
    cache = init_cache(cfg, B, W)
    if cfg.encoder_layers:
        enc = _encode(params, batch["frames"], cfg)
        cache["cross"] = build_cross_cache(params, enc, cfg)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = decode_step(params, cache, tok, jnp.int32(0), cfg, max_len=W)
    assert logits.shape == (B, cfg.vocab_size)
    logits, _ = decode_step(params, cache, tok + 1, jnp.int32(1), cfg, max_len=W)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_spec(arch):
    """The FULL configs match the assignment table exactly."""
    cfg = get_config(arch)
    spec = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    L, d, H, kv, dff, V = spec
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.attention.num_heads == H
    assert cfg.attention.num_kv_heads == kv
    assert cfg.vocab_size == V
    if cfg.has_moe and arch != "jamba-v0.1-52b":
        assert cfg.moe.expert_d_ff == dff
    else:
        assert cfg.d_ff == dff or (cfg.d_ff == 0 and dff == 0)


def test_moe_expert_counts():
    assert get_config("jamba-v0.1-52b").moe.num_experts == 16
    assert get_config("jamba-v0.1-52b").moe.top_k == 2
    assert get_config("moonshot-v1-16b-a3b").moe.num_experts == 64
    assert get_config("moonshot-v1-16b-a3b").moe.top_k == 6
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("mixtral-8x7b").moe.num_experts == 8

"""Flight-table multi-rate integrator invariants (core/multirate.py,
DESIGN.md §8).

* ``FlightTable`` mechanics: one-hot insert exactness (masked rows and
  untouched slots bitwise identical), capacity-overflow refusal, busy-slot
  refusal, masked-quantile parity with np.quantile;
* the Σ_i I_i = 0 consensus fixed point is stationary under every event
  slicing the new table supports — sub-1.0 horizons, multi-wave rounds, the
  sharded event mode with uneven capacity padding, and the anchored-masked
  fused-kernel path (``use_kernels`` no longer forced off);
* nan-aware history handling: an all-busy cohort dispatches nothing, its
  round reports ``loss = nan`` + a ``dropped`` count, and the fed/server.py
  helpers summarize such histories without poisoning the endpoint.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConsensusConfig,
    FlightTable,
    flight_insert_checked,
    init_flight_table,
)
from repro.core.multirate import flight_insert, masked_quantile, multirate_integrate
from repro.data import make_classification
from repro.fed import (
    FedSim,
    FedSimConfig,
    HeteroConfig,
    dirichlet_partition,
    last_finite_loss,
    mean_finite_loss,
)
from repro.sim import CohortPlan, EventBackend


# ---------------------------------------------------------------------------
# FlightTable mechanics
# ---------------------------------------------------------------------------


def _rows(rng, A, shape=(3,)):
    return {
        "w": jnp.asarray(rng.randn(A, *shape), jnp.float32),
        "b": jnp.asarray(rng.randn(A, 2), jnp.float32),
    }


def test_flight_insert_one_hot_exactness():
    """Inserted rows land exactly; masked rows and untouched slots stay
    bitwise identical (the scatter is one-hot into zeros + select, never a
    read-modify-write)."""
    rng = np.random.RandomState(0)
    params = {"w": jnp.zeros((3,)), "b": jnp.zeros((2,))}
    tab = init_flight_table(params, capacity=6)
    # pre-populate slots 1 and 4
    pre = flight_insert(
        tab, jnp.asarray([1, 4], jnp.int32), _rows(rng, 2), _rows(rng, 2),
        jnp.asarray([0.3, 0.7], jnp.float32), jnp.ones((2,), jnp.float32),
    )
    before = jax.tree.map(np.asarray, pre)

    xp, xn = _rows(rng, 3), _rows(rng, 3)
    T = jnp.asarray([0.1, 0.2, 0.9], jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 1.0], jnp.float32)   # middle row masked out
    new = flight_insert(pre, jnp.asarray([0, 2, 5], jnp.int32), xp, xn, T, mask)

    assert float(new.alive[0]) == 1.0 and float(new.alive[5]) == 1.0
    assert float(new.alive[2]) == 0.0                    # masked: not inserted
    np.testing.assert_array_equal(np.asarray(new.cid)[[0, 5]], [0, 5])
    for k in ("w", "b"):
        np.testing.assert_array_equal(
            np.asarray(new.x_new[k][0]), np.asarray(xn[k][0])
        )
        np.testing.assert_array_equal(
            np.asarray(new.x_new[k][5]), np.asarray(xn[k][2])
        )
        # pre-existing and masked slots: bitwise untouched
        for slot in (1, 2, 3, 4):
            np.testing.assert_array_equal(
                np.asarray(new.x_new[k][slot]), before.x_new[k][slot]
            )
    np.testing.assert_array_equal(
        np.asarray(new.T_rem)[[1, 4]], before.T_rem[[1, 4]]
    )


def test_flight_insert_refuses_capacity_overflow():
    params = {"w": jnp.zeros((3,))}
    tab = init_flight_table(params, capacity=4)
    rng = np.random.RandomState(1)
    rows = {"w": jnp.asarray(rng.randn(1, 3), jnp.float32)}
    with pytest.raises(ValueError, match="overflow"):
        flight_insert(
            tab, jnp.asarray([4], jnp.int32), rows, rows,
            jnp.asarray([0.5], jnp.float32), jnp.ones((1,), jnp.float32),
        )


def test_flight_insert_refuses_busy_slot():
    """A client has at most one flight: inserting into an alive slot is a
    scheduler bug (the backend masks busy draws out) and must refuse."""
    params = {"w": jnp.zeros((3,))}
    tab = init_flight_table(params, capacity=4)
    rng = np.random.RandomState(2)
    rows = lambda: {"w": jnp.asarray(rng.randn(1, 3), jnp.float32)}
    tab = flight_insert(
        tab, jnp.asarray([2], jnp.int32), rows(), rows(),
        jnp.asarray([0.5], jnp.float32), jnp.ones((1,), jnp.float32),
    )
    with pytest.raises(ValueError, match="busy"):
        flight_insert(
            tab, jnp.asarray([2], jnp.int32), rows(), rows(),
            jnp.asarray([0.5], jnp.float32), jnp.ones((1,), jnp.float32),
        )
    # masked re-draw of the same client is the legal path: a no-op
    out = flight_insert(
        tab, jnp.asarray([2], jnp.int32), rows(), rows(),
        jnp.asarray([0.9], jnp.float32), jnp.zeros((1,), jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(out.T_rem), np.asarray(tab.T_rem))


@pytest.mark.parametrize("q", [0.0, 0.25, 0.5, 0.75, 1.0])
def test_masked_quantile_matches_numpy(q):
    rng = np.random.RandomState(int(q * 100))
    vals = rng.uniform(0.01, 1.0, 17).astype(np.float32)
    mask = (rng.rand(17) > 0.4).astype(np.float32)
    if mask.sum() == 0:
        mask[3] = 1.0
    got = float(masked_quantile(jnp.asarray(vals), jnp.asarray(mask), q))
    want = float(np.quantile(vals[mask > 0].astype(np.float64), q))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_masked_quantile_empty_mask_is_nan():
    v = jnp.asarray([1.0, 2.0], jnp.float32)
    assert math.isnan(float(masked_quantile(v, jnp.zeros((2,)), 0.5)))


# ---------------------------------------------------------------------------
# Σ_i I_i = 0 fixed point under the new table (port + extensions of
# tests/test_engine.py::test_event_staleness_preserves_flow_invariant)
# ---------------------------------------------------------------------------


def _fixed_point_problem():
    n, dim = 4, 3
    cs = np.asarray(
        [[1.0, -2.0, 0.5], [-1.0, 2.0, -0.5], [2.0, 1.0, -1.0], [-2.0, -1.0, 1.0]],
        np.float32,
    )
    assert np.abs(cs.sum(0)).max() == 0.0
    data = {"x": cs, "y": np.zeros((n,), np.int64)}
    parts = [np.asarray([i]) for i in range(n)]

    def loss_fn(p, batch):
        return 0.5 * jnp.mean(jnp.sum(jnp.square(p["w"][None] - batch["x"]), -1))

    params0 = {"w": jnp.zeros((dim,), jnp.float32)}
    return n, dim, cs, data, parts, loss_fn, params0


@pytest.mark.parametrize(
    "mode,kw",
    [
        ("dense-q0.5-w3", dict(event_horizon=0.5, event_max_waves=3)),
        ("dense-q0.3-w1", dict(event_horizon=0.3, event_max_waves=1)),
        ("dense-kernels", dict(
            event_horizon=0.5, event_max_waves=2,
            consensus=ConsensusConfig(L=0.1, max_substeps=16, use_kernels=True),
        )),
        ("sharded-q0.5", dict(
            event_horizon=0.5, event_max_waves=3, event_sharded=True,
            sharded_pad_multiple=3,      # uneven capacity/cohort padding
        )),
    ],
)
def test_flight_table_preserves_flow_invariant(mode, kw):
    """At the consensus fixed point (x_i = x_c*, I_i = −p̂_i∇f_i(x_c*),
    Σ_i I_i = 0) the flight-table integrator must leave the state
    stationary no matter how arrivals are sliced into waves, delayed by
    staleness, run through the anchored-masked fused kernel, or sharded
    over the mesh with uneven padding (DESIGN.md §8)."""
    n, dim, cs, data, parts, loss_fn, params0 = _fixed_point_problem()
    kw.setdefault("consensus", ConsensusConfig(L=0.1, max_substeps=16))
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=n, participation=1.0, rounds=6,
        batch_size=4, steps_per_epoch=3, lr_fixed=5e-3, epochs_fixed=2,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 5),    # heterogeneous windows
        seed=0, backend="event", **kw,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    # place the server exactly at the fixed point: ∇f_i(0) = −c_i and
    # p̂_i = 1, so I_i = −p̂_i·∇f_i(x*) = c_i with Σ_i I_i = 0
    sim.state = sim.state._replace(I={"w": jnp.asarray(cs, jnp.float32)})

    hist = sim.run()
    x_c = np.asarray(sim.state.x_c["w"])
    I_sum = np.asarray(jnp.sum(sim.state.I["w"], axis=0))
    np.testing.assert_allclose(x_c, np.zeros(dim), atol=1e-5)
    np.testing.assert_allclose(I_sum, np.zeros(dim), atol=1e-5)
    assert np.isfinite(hist.loss).all()
    # the table really carried flights across rounds in the sub-1 settings
    assert sum(s["stale"] for s in sim.backend.round_stats) > 0


def test_event_kernels_match_reference_path():
    """Dense event rounds with ``use_kernels=True`` (the anchored-masked
    fused Pallas path) reproduce the explicit be_step path."""
    data = make_classification(256, dim=6, n_classes=3, seed=2)
    parts = dirichlet_partition(data["y"], 6, alpha=0.5, seed=2)
    params0 = {"w": jax.random.normal(jax.random.PRNGKey(2), (6, 3)) / 3.0}

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(batch["x"] @ p["w"])
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    hists = {}
    for uk in (False, True):
        cfg = FedSimConfig(
            algorithm="fedecado", n_clients=6, participation=0.5, rounds=4,
            batch_size=4, steps_per_epoch=2, hetero=HeteroConfig(1e-3, 1e-2, 1, 4),
            seed=3, backend="event", event_horizon=0.6, event_max_waves=2,
            consensus=ConsensusConfig(max_substeps=8, use_kernels=uk),
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hists[uk] = (sim.run().loss, sim.current_params())
    np.testing.assert_allclose(
        hists[True][0], hists[False][0], rtol=1e-4, atol=1e-6
    )
    for a, b in zip(
        jax.tree.leaves(hists[False][1]), jax.tree.leaves(hists[True][1]),
        strict=True,
    ):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-6
        )


# ---------------------------------------------------------------------------
# event-path edge cases: empty-table horizon guard, jit-safe checked insert,
# buffered K-trigger semantics (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _integrator_fixture(capacity=6, dim=3, seed=3):
    rng = np.random.RandomState(seed)
    params0 = {"w": jnp.zeros((dim,), jnp.float32)}
    tab = init_flight_table(params0, capacity=capacity)
    rows = lambda A: {"w": jnp.asarray(rng.randn(A, dim), jnp.float32)}
    I = {"w": jnp.asarray(rng.randn(capacity, dim) * 0.01, jnp.float32)}
    x_c = {"w": jnp.asarray(rng.randn(dim), jnp.float32)}
    g = jnp.full((capacity,), 0.1, jnp.float32)
    ccfg = ConsensusConfig(L=0.1, max_substeps=8)
    return tab, rows, I, x_c, g, ccfg


def test_multirate_empty_table_round_is_nan_free():
    """Regression (DESIGN.md §10 hardening): an empty flight table makes the
    masked horizon quantile all-NaN; the guard must sanitize it BEFORE wave
    activation so the round is an exact no-op — zero horizon, no arrivals,
    bitwise-unchanged state, and no NaN in any stat — including under jit."""
    tab, _, I, x_c, g, ccfg = _integrator_fixture()

    fn = jax.jit(lambda xc, ii, tb: multirate_integrate(
        xc, ii, g, jnp.float32(0.01), jnp.float32(0.0), tb, ccfg, 0.5, 2
    ))
    x2, I2, dt2, t2, tab2, st = fn(x_c, I, tab)

    assert float(st.horizon) == 0.0 and float(st.tau_end) == 0.0
    assert int(st.arrived) == 0 and int(st.stale) == 0
    assert int(st.max_stale) == 0
    np.testing.assert_array_equal(np.asarray(x2["w"]), np.asarray(x_c["w"]))
    np.testing.assert_array_equal(np.asarray(I2["w"]), np.asarray(I["w"]))
    for leaf in (st.horizon, st.tau_end, st.dt_min, st.dt_max, st.dt_sum,
                 dt2, t2):
        assert np.isfinite(np.asarray(leaf)).all()
    assert int(jnp.sum(tab2.alive)) == 0


def test_all_busy_round_leaves_server_state_finite():
    """Companion regression to the nan-loss record test: after an all-busy
    round (no inserts, pending arrivals only) every piece of server state
    the next round consumes must be finite."""
    sim = _small_event_sim(event_horizon=0.25, event_max_waves=2)
    plan1 = sim._draw_plan(0, 4)
    sim.backend.run_round(sim, plan1)
    stale_cids = [
        c for c in range(sim.n)
        if float(np.asarray(sim.backend._table.alive)[c]) > 0
    ]
    assert stale_cids
    j = [int(i) for i, c in enumerate(plan1.idx) if int(c) in stale_cids]
    plan2 = CohortPlan(
        rnd=1, idx=plan1.idx[j], lrs=plan1.lrs[j], epochs=plan1.epochs[j],
        n_steps=plan1.n_steps[j], batch_idx=[plan1.batch_idx[k] for k in j],
    )
    sim.backend.run_round(sim, plan2)
    assert np.isfinite(np.asarray(sim.state.x_c["w"])).all()
    assert np.isfinite(np.asarray(sim.state.I["w"])).all()
    assert np.isfinite(np.asarray(sim.backend._table.T_rem)).all()
    rec = sim.backend.round_stats[-1]
    assert np.isfinite(rec["horizon"])


def test_flight_insert_checked_is_jit_safe_with_drop_accounting():
    """Under a jit trace ``flight_insert``'s concrete busy/overflow refusals
    cannot fire; the checked variant must mask busy rows out of the scatter
    (busy slot bitwise untouched), count them in ``dropped``, and leave
    out-of-range rows (another shard's slots) masked but UNcounted."""
    rng = np.random.RandomState(4)
    params = {"w": jnp.zeros((3,))}
    rows = lambda A: {"w": jnp.asarray(rng.randn(A, 3), jnp.float32)}
    tab = init_flight_table(params, capacity=4)
    tab = flight_insert(
        tab, jnp.asarray([1], jnp.int32), rows(1), rows(1),
        jnp.asarray([0.5], jnp.float32), jnp.ones((1,), jnp.float32),
    )
    before = jax.tree.map(np.asarray, tab)

    step = jax.jit(flight_insert_checked)
    xp, xn = rows(2), rows(2)
    T = jnp.asarray([0.9, 0.2], jnp.float32)
    cid = jnp.asarray([1, 3], jnp.int32)

    out, dropped = step(tab, cid, xp, xn, T, jnp.ones((2,), jnp.float32))
    assert float(dropped) == 1.0
    # busy slot 1: bitwise untouched (no silent wrong-slot write)
    np.testing.assert_array_equal(
        np.asarray(out.x_new["w"][1]), before.x_new["w"][1]
    )
    np.testing.assert_array_equal(np.asarray(out.T_rem)[1], before.T_rem[1])
    assert int(out.cid[1]) == 1
    # free slot 3: inserted exactly
    assert float(out.alive[3]) == 1.0
    np.testing.assert_array_equal(
        np.asarray(out.x_new["w"][3]), np.asarray(xn["w"][1])
    )

    # pre-masked call: dropped == 0 and bitwise equal to plain flight_insert
    mask = jnp.asarray([0.0, 1.0], jnp.float32)
    got, d0 = step(tab, cid, xp, xn, T, mask)
    assert float(d0) == 0.0
    want = flight_insert(tab, cid, xp, xn, T, mask)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # out-of-range row (another shard's slot in sharded mode): not counted,
    # not written
    far, d_far = step(
        tab, jnp.asarray([7], jnp.int32), rows(1), rows(1),
        jnp.asarray([0.4], jnp.float32), jnp.ones((1,), jnp.float32),
    )
    assert float(d_far) == 0.0
    for a, b in zip(
        jax.tree.leaves(far), jax.tree.leaves(tab), strict=True
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_buffered_no_trigger_round_ages_flights_bitwise():
    """Buffered server, fewer than K flights queued: the round must be a
    pure ageing step — zero horizon, no arrivals, bitwise-unchanged x_c/I
    and windows, stale_rounds incremented — until the K-th flight lands,
    at which point all K drain together."""
    tab, rows, I, x_c, g, ccfg = _integrator_fixture()
    tab = flight_insert(
        tab, jnp.asarray([0, 2], jnp.int32), rows(2), rows(2),
        jnp.asarray([0.2, 0.4], jnp.float32), jnp.ones((2,), jnp.float32),
    )

    x2, I2, dt2, t2, tab2, st = multirate_integrate(
        x_c, I, g, jnp.float32(0.01), jnp.float32(0.0), tab, ccfg,
        1.0, 2, buffer_k=3,
    )
    assert int(st.arrived) == 0 and float(st.horizon) == 0.0
    np.testing.assert_array_equal(np.asarray(x2["w"]), np.asarray(x_c["w"]))
    np.testing.assert_array_equal(np.asarray(I2["w"]), np.asarray(I["w"]))
    np.testing.assert_array_equal(
        np.asarray(tab2.T_rem)[[0, 2]], np.asarray(tab.T_rem)[[0, 2]]
    )
    assert [int(s) for s in np.asarray(tab2.stale_rounds)[[0, 2]]] == [1, 1]
    assert int(st.max_stale) == 1
    assert int(st.stale) == 2

    # K-th flight lands: the trigger fires and the whole buffer drains
    tab3 = flight_insert(
        tab2, jnp.asarray([4], jnp.int32), rows(1), rows(1),
        jnp.asarray([0.3], jnp.float32), jnp.ones((1,), jnp.float32),
    )
    x3, I3, dt3, t3, tab4, st2 = multirate_integrate(
        x2, I2, g, dt2, t2, tab3, ccfg, 1.0, 4, buffer_k=3,
    )
    assert int(st2.arrived) == 3
    assert int(jnp.sum(tab4.alive)) == 0
    assert int(st2.max_stale) == 0
    np.testing.assert_allclose(float(st2.horizon), 0.4, rtol=1e-6)


def test_buffered_stale_gamma_damps_toward_anchor():
    """γ > 0: an arrived flight that waited s rounds contributes its
    endpoint damped toward the Γ anchor with w = 1/(1 + γ·s); fresh flights
    (s = 0) are bitwise untouched, so γ only changes history-bearing rows."""
    tab, rows, I, x_c, g, ccfg = _integrator_fixture()
    tab = flight_insert(
        tab, jnp.asarray([0, 2], jnp.int32), rows(2), rows(2),
        jnp.asarray([0.2, 0.4], jnp.float32), jnp.ones((2,), jnp.float32),
    )
    # age the buffer one round (no trigger), then land the K-th flight
    _, _, _, _, aged, _ = multirate_integrate(
        x_c, I, g, jnp.float32(0.01), jnp.float32(0.0), tab, ccfg,
        1.0, 2, buffer_k=3,
    )
    full = flight_insert(
        aged, jnp.asarray([4], jnp.int32), rows(1), rows(1),
        jnp.asarray([0.3], jnp.float32), jnp.ones((1,), jnp.float32),
    )
    out0 = multirate_integrate(
        x_c, I, g, jnp.float32(0.01), jnp.float32(0.0), full, ccfg,
        1.0, 4, buffer_k=3, stale_gamma=0.0,
    )
    out1 = multirate_integrate(
        x_c, I, g, jnp.float32(0.01), jnp.float32(0.0), full, ccfg,
        1.0, 4, buffer_k=3, stale_gamma=0.5,
    )
    assert int(out0[5].arrived) == int(out1[5].arrived) == 3
    # the damped run integrates a genuinely different trajectory
    assert not np.array_equal(
        np.asarray(out0[0]["w"]), np.asarray(out1[0]["w"])
    )
    # both stay finite (the damping is a convex combination)
    assert np.isfinite(np.asarray(out1[0]["w"])).all()
    assert np.isfinite(np.asarray(out1[1]["w"])).all()


# ---------------------------------------------------------------------------
# busy-drop reporting + nan-aware history handling
# ---------------------------------------------------------------------------


def _small_event_sim(rounds=1, **kw):
    data = make_classification(128, dim=4, n_classes=2, seed=5)
    parts = [np.arange(i, 128, 4) for i in range(4)]
    params0 = {"w": jnp.zeros((4, 2), jnp.float32)}

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(batch["x"] @ p["w"])
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=4, participation=1.0, rounds=rounds,
        batch_size=8, steps_per_epoch=2, hetero=HeteroConfig(1e-3, 1e-2, 1, 5),
        seed=11, backend="event",
        consensus=ConsensusConfig(max_substeps=4), **kw,
    )
    return FedSim(loss_fn, params0, data, parts, cfg)


def test_all_busy_cohort_reports_nan_and_dropped():
    """A cohort drawn entirely from in-flight clients dispatches no local
    work: the round advances the server on pending arrivals, reports every
    draw in ``dropped``, and marks the loss gap with nan instead of
    pretending a loss was observed."""
    sim = _small_event_sim(event_horizon=0.25, event_max_waves=2)
    plan1 = sim._draw_plan(0, 4)
    rec1 = sim.backend.run_round(sim, plan1)
    assert np.isfinite(rec1["loss"]) and rec1["stale"] > 0

    stale_cids = [
        c for c in range(sim.n)
        if float(np.asarray(sim.backend._table.alive)[c]) > 0
    ]
    assert stale_cids
    j = [int(i) for i, c in enumerate(plan1.idx) if int(c) in stale_cids]
    plan2 = CohortPlan(
        rnd=1, idx=plan1.idx[j], lrs=plan1.lrs[j], epochs=plan1.epochs[j],
        n_steps=plan1.n_steps[j], batch_idx=[plan1.batch_idx[k] for k in j],
    )
    x_before = np.asarray(sim.state.x_c["w"]).copy()
    rec2 = sim.backend.run_round(sim, plan2)
    assert math.isnan(rec2["loss"])
    assert rec2["dropped"] == len(stale_cids)
    assert sim.backend.total_dropped >= len(stale_cids)
    # pending arrivals still advanced the server
    assert rec2["arrived"] > 0
    assert not np.array_equal(np.asarray(sim.state.x_c["w"]), x_before)


def test_history_helpers_are_nan_aware():
    assert last_finite_loss([0.5, float("nan")]) == 0.5
    assert last_finite_loss([0.5, float("nan"), 0.25]) == 0.25
    assert math.isnan(last_finite_loss([float("nan")]))
    assert math.isnan(last_finite_loss([]))
    np.testing.assert_allclose(
        mean_finite_loss([1.0, float("nan"), 3.0]), 2.0
    )
    assert math.isnan(mean_finite_loss([float("nan")]))


def test_fedsim_history_survives_loss_gaps():
    """End-to-end: with a tight horizon the history may contain nan gap
    markers; the nan-aware helpers must still summarize it, and FedSim must
    not crash or mangle the finite entries."""
    sim = _small_event_sim(rounds=8, event_horizon=0.25, event_max_waves=1)
    hist = sim.run()
    losses = np.asarray(hist.loss, np.float64)
    assert len(losses) == 8
    assert np.isfinite(losses).any()
    assert np.isfinite(last_finite_loss(hist.loss))
    assert np.isfinite(mean_finite_loss(hist.loss))
    # every round produced an observable stats record (arrived/stale/...)
    assert len(sim.backend.round_stats) == 8
    assert all("dropped" in s for s in sim.backend.round_stats)

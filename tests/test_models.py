"""Model-layer tests: flash attention vs naive oracle, prefill/decode
consistency, MoE dispatch invariants, Mamba/xLSTM state equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.layers import _flash_attention, chunked_attention
from repro.models.moe import apply_moe, capacity, init_moe
from repro.models.transformer import prefill_step


def _naive_attention(q, k, v, causal, window, softcap):
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qh = q.reshape(B, Sq, Hkv, G, dh)
    lg = jnp.einsum("bqhgk,bshk->bhgqs", qh, k) / np.sqrt(dh)
    if softcap:
        lg = softcap * jnp.tanh(lg / softcap)
    qpos, kpos = jnp.arange(Sq), jnp.arange(k.shape[1])
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    lg = jnp.where(m[None, None, None], lg, -1e30)
    p = jax.nn.softmax(lg, -1)
    return jnp.einsum("bhgqs,bshk->bqhgk", p, v).reshape(B, Sq, Hq, dh)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 1000),
    causal=st.booleans(),
    window=st.sampled_from([0, 16, 48]),
    softcap=st.sampled_from([0.0, 30.0]),
)
def test_flash_attention_property(seed, causal, window, softcap):
    rng = np.random.RandomState(seed)
    B, S, Hkv, G, dh = 1, 96, 2, 2, 8
    q = jnp.asarray(rng.randn(B, S, Hkv * G, dh), jnp.float32) * 0.4
    k = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32) * 0.4
    v = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32) * 0.4
    out = chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_chunk=32, kv_chunk=16,
    )
    ref = _naive_attention(q, k, v, causal, window, softcap)
    np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-5)


def test_flash_gradients_match_naive():
    rng = np.random.RandomState(0)
    B, S, Hkv, G, dh = 2, 64, 2, 3, 8
    q = jnp.asarray(rng.randn(B, S, Hkv * G, dh), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, Hkv, dh), jnp.float32) * 0.3
    f1 = lambda q, k, v: jnp.sum(
        jnp.sin(_flash_attention(q, k, v, True, 0, 0.0, 0, 32, 32))
    )
    f2 = lambda q, k, v: jnp.sum(jnp.sin(_naive_attention(q, k, v, True, 0, 0.0)))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(E=4, k=2, cf=2.0):
    return ArchConfig(
        name="t", family="moe", num_layers=2, d_model=32, d_ff=64,
        vocab_size=64,
        attention=AttentionConfig(num_heads=2, num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=E, top_k=k, expert_d_ff=48, capacity_factor=cf),
        moe_pattern="all",
    )


def test_moe_output_shape_and_aux():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) >= 0


def test_moe_capacity_drop():
    """With capacity_factor << 1 some tokens are dropped, none corrupted."""
    cfg = _moe_cfg(cf=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y, _ = apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_dense_equivalence_top1_single_expert():
    """1 expert, top-1, ample capacity == plain MLP through that expert."""
    cfg = _moe_cfg(E=1, k=1, cf=4.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32)) * 0.5
    y, _ = apply_moe(p, x, cfg)
    up = x.reshape(8, 32) @ p["w_up"][0]
    gate = jax.nn.silu(x.reshape(8, 32) @ p["w_gate"][0])
    ref = (gate * up) @ p["w_down"][0]
    np.testing.assert_allclose(y.reshape(8, 32), ref, rtol=2e-3, atol=1e-4)


def test_moe_grads_flow_to_router():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_up"]))) > 0


def test_capacity_rounding():
    cfg = _moe_cfg(E=4, k=2, cf=1.25)
    c = capacity(1000, cfg)
    assert c % 8 == 0 and c >= 1000 * 2 * 1.25 / 4


# ---------------------------------------------------------------------------
# recurrent blocks: chunked-scan == single-shot decode chain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [24, 64, 200])
def test_mlstm_chunkwise_equals_sequential(S):
    """H1 hillclimb: the chunkwise-parallel (matmul-form) mLSTM is an exact
    algebraic regrouping of the sequential scan."""
    from repro.models.xlstm import apply_mlstm, init_mlstm

    cfg = get_smoke_config("xlstm-1.3b")
    p = init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model)) * 0.5
    y1, s1 = apply_mlstm(p, x, cfg, return_state=True, impl="sequential")
    y2, s2 = apply_mlstm(p, x, cfg, return_state=True, impl="chunkwise")
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(s1[k], s2[k], rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "xlstm-1.3b"])
def test_recurrent_prefill_equals_decode_chain(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    full, _ = forward(params, {"tokens": toks}, cfg)
    cache = init_cache(cfg, B, 32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t], jnp.int32(t), cfg, max_len=32)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(dec, full, rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "gemma2-9b", "whisper-base"])
def test_prefill_then_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S, W = 2, 16, 32
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks[:, :S]}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(key, (B, 8, cfg.d_model)) * 0.02
    lg, cache = prefill_step(params, batch, cfg, max_len=W)
    if cfg.encoder_layers:
        from repro.models import build_cross_cache
        from repro.models.transformer import _encode
        cache["cross"] = build_cross_cache(params, _encode(params, batch["frames"], cfg), cfg)
    # continue decoding; cross-check against scratch decode
    cache2 = init_cache(cfg, B, W)
    if cfg.encoder_layers:
        cache2["cross"] = cache["cross"]
    for t in range(S):
        lg2, cache2 = decode_step(params, cache2, toks[:, t], jnp.int32(t), cfg, max_len=W)
    np.testing.assert_allclose(lg, lg2, rtol=1e-3, atol=2e-3)


def test_sliding_window_restricts_context():
    """With window W, logits at position t >= W must not depend on token 0."""
    cfg = get_smoke_config("mixtral-8x7b")
    assert cfg.attention.sliding_window > 0
    W = cfg.attention.sliding_window  # 64 in the smoke config
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    S = W + 16
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size, jnp.int32)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    l1, _ = forward(params, {"tokens": toks}, cfg)
    l2, _ = forward(params, {"tokens": toks2}, cfg)
    # positions beyond the window (plus depth-L propagation margin: 2 layers
    # of window-W attention can reach back 2W) — use the last position with
    # S = W+16 < 2W so depth propagation CAN reach; instead check a pure
    # 1-layer property via direct attention call:
    from repro.models.layers import chunked_attention
    q = jax.random.normal(key, (1, S, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, S, 2, 8))
    v2 = v.at[0, 0].set(v[0, 0] + 10.0)
    o1 = chunked_attention(q, k, v, causal=True, window=W, q_chunk=32, kv_chunk=32)
    o2 = chunked_attention(q, k, v2, causal=True, window=W, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(o1[0, W:], o2[0, W:], rtol=1e-5, atol=1e-6)
    assert float(jnp.max(jnp.abs(o1[0, 0] - o2[0, 0]))) > 1e-3


def test_mamba_kernel_impl_matches_scan():
    """The Pallas VMEM-resident selective scan == the chunked lax.scan."""
    from repro.models.mamba import apply_mamba, init_mamba

    cfg = get_smoke_config("jamba-v0.1-52b")
    p = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5
    y1, s1 = apply_mamba(p, x, cfg, return_state=True, impl="scan")
    y2, s2 = apply_mamba(p, x, cfg, return_state=True, impl="kernel")
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s1["ssm"], s2["ssm"], rtol=1e-5, atol=1e-6)

"""FedADMM plugin tests — the plugin-API acceptance proof.

The backend-equivalence checks themselves live in tests/test_backend_equiv
(fedadmm is in the registry, so the fuzz and the deterministic registry
sweep cover it with zero edits there); here we pin the plugin's own
semantics: registration + capabilities, dual-variable bookkeeping across
backends, and convergence on the synthetic non-IID task.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition
from repro.fed.algorithms import available_algorithms, get_algorithm


@pytest.fixture(scope="module")
def problem():
    data = make_classification(1024, dim=12, n_classes=4, seed=5)
    parts = dirichlet_partition(data["y"], 10, alpha=0.3, seed=5)
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    params0 = {
        "w0": jax.random.normal(k1, (12, 24)) / 4.0,
        "b0": jnp.zeros((24,)),
        "w1": jax.random.normal(k2, (24, 4)) / np.sqrt(24),
        "b1": jnp.zeros((4,)),
    }

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
        lp = jax.nn.log_softmax(h)
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
        )

    return data, parts, params0, loss_fn


def test_fedadmm_registered_with_expected_capabilities():
    assert "fedadmm" in available_algorithms()
    cls = get_algorithm("fedadmm")
    assert cls.has_client_state          # duals λ_i
    assert not cls.has_flow_dynamics     # averaging family, no event backend
    assert cls.supports_hetero
    assert cls.client_kind == "admm"
    from repro.fed.client import client_kind_spec

    assert client_kind_spec("admm").takes_flow


def test_fedadmm_duals_update_only_for_participants(problem):
    data, parts, params0, loss_fn = problem
    cfg = FedSimConfig(
        algorithm="fedadmm", n_clients=len(parts), participation=0.4,
        rounds=2, batch_size=16, steps_per_epoch=2, seed=3, mu=0.1,
        backend="vectorized",
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    lam0 = jax.tree.map(np.asarray, sim.alg.client_state)
    assert all((np.asarray(l) == 0).all() for l in jax.tree.leaves(lam0))
    plan = sim._draw_plan(0, 4)
    sim.backend.run_round(sim, plan)
    lam1 = sim.alg.client_state
    active = set(int(i) for i in plan.idx)
    moved = np.asarray([
        any(
            np.abs(np.asarray(l)[i]).max() > 0
            for l in jax.tree.leaves(lam1)
        )
        for i in range(len(parts))
    ])
    assert moved[sorted(active)].all()
    assert not moved[[i for i in range(len(parts)) if i not in active]].any()


def test_fedadmm_converges_on_noniid_task(problem):
    """Loss decreases on the synthetic non-IID task (the smoke bar for a
    comparison algorithm — orderings vs. FedECADO are the benches' job)."""
    data, parts, params0, loss_fn = problem
    cfg = FedSimConfig(
        algorithm="fedadmm", n_clients=len(parts), participation=0.4,
        rounds=20, batch_size=32, steps_per_epoch=3, seed=0, mu=0.1,
        lr_fixed=5e-3, epochs_fixed=2,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 4),
        backend="vectorized", eval_every=1 << 30,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    hist = sim.run()
    losses = np.asarray(hist.loss)
    assert np.isfinite(losses).all()
    early, late = losses[:3].mean(), losses[-3:].mean()
    assert late < 0.8 * early, (early, late)


def test_fedadmm_event_backend_rejected(problem):
    data, parts, params0, loss_fn = problem
    cfg = FedSimConfig(
        algorithm="fedadmm", n_clients=len(parts), participation=0.4,
        rounds=1, batch_size=16, steps_per_epoch=1, seed=0, backend="event",
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    with pytest.raises(ValueError, match="event backend"):
        sim.run()


def test_fedadmm_sharded_segment_threads_duals(problem):
    """The sharded jit-resident segment must carry the duals through its
    fori_loop and write them back identically (rtol) to the dense path."""
    data, parts, params0, loss_fn = problem
    states = {}
    for backend in ("sequential", "sharded"):
        cfg = FedSimConfig(
            algorithm="fedadmm", n_clients=len(parts), participation=0.5,
            rounds=3, batch_size=4, steps_per_epoch=2, seed=9, mu=0.1,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), backend=backend,
            sharded_pad_multiple=3 if backend == "sharded" else None,
            consensus=ConsensusConfig(max_substeps=6),
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        states[backend] = (hist.loss, sim.alg.client_state, sim.params)

    for a, b in zip(
        jax.tree.leaves(states["sequential"][1]),
        jax.tree.leaves(states["sharded"][1]),
        strict=True,
    ):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-6, atol=2e-7
        )
    np.testing.assert_allclose(
        states["sharded"][0], states["sequential"][0], rtol=1e-6, atol=1e-7
    )

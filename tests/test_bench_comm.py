"""Comm-bench harness smoke test: ``benchmarks/run.py --only comm`` must run
end-to-end and persist a ``BENCH_comm.json`` whose schema downstream tooling
can rely on (algorithm × scenario × compressor × level → accuracy +
measured bytes totals). The schema is pinned here — bump
``COMM_BENCH_SCHEMA_VERSION`` in benchmarks/run.py when it changes, and
update this test in the same PR.

Schema v1: frontier rows with acc/bytes ratios against the per-(algorithm,
scenario) lossless baseline row, a per-family bytes-monotonicity section
(higher compression tier → strictly fewer measured uplink bytes), and the
``criterion`` block — the acceptance frontier on dirichlet01 (>= 95% of the
uncompressed accuracy at <= 25% of its uplink bytes, witnessed by at least
one lossy setting). Forbidden compressor × algorithm combos (topk × flow
dynamics) have no rows, mirroring the engine bench's flow-only event rows.
"""
import importlib.util
import json
import os

import pytest


def _bench_module():
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "benchmarks", "run.py"
    )
    spec = importlib.util.spec_from_file_location("bench_run_comm", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _expected_rows(report):
    """One row per (algorithm × scenario × setting), minus forbidden
    compressor × algorithm combos (the comm registry's capability guard)."""
    from repro.comm import get_compressor
    from repro.fed.algorithms import get_algorithm

    out = set()
    for a in report["algorithms"]:
        for s in report["scenarios"]:
            for st in report["settings"]:
                cls = get_compressor(st["compress"])
                if (get_algorithm(a).has_flow_dynamics
                        and not cls.supports_flow):
                    continue
                out.add((a, s, st["compress"], st["level"]))
    return out


def test_comm_bench_runs_and_json_schema_is_stable(tmp_path):
    bench = _bench_module()
    json_path = tmp_path / "BENCH_comm.json"
    report = bench.comm_bench(
        rounds=2, clients=6, participation=0.5,
        scenarios=("dirichlet01",),
        algorithms=("fedecado", "fednova"),
        json_path=str(json_path),
    )

    assert json_path.exists()
    with open(json_path) as f:
        persisted = json.load(f)
    assert persisted == report

    # -- schema: top level ------------------------------------------------
    assert persisted["schema_version"] == bench.COMM_BENCH_SCHEMA_VERSION == 1
    assert persisted["benchmark"] == "comm"
    assert persisted["rounds"] == 2
    assert persisted["scenarios"] == ["dirichlet01"]
    assert persisted["algorithms"] == ["fedecado", "fednova"]
    assert persisted["settings"][0] == {"compress": "identity", "level": None}
    assert isinstance(persisted["config"], dict)
    assert persisted["config"]["backend"] == "vectorized"

    # -- schema: frontier rows -------------------------------------------
    seen = set()
    for row in persisted["results"]:
        assert set(row) == {
            "algorithm", "scenario", "compress", "level", "acc",
            "final_loss", "bytes_up", "bytes_down", "wall_s",
            "bytes_ratio", "acc_ratio",
        }
        assert 0.0 <= row["acc"] <= 1.0
        assert row["bytes_up"] > 0 and row["bytes_down"] > 0
        assert isinstance(row["bytes_up"], int)
        if row["compress"] == "identity":
            assert row["bytes_ratio"] == 1.0 and row["acc_ratio"] == 1.0
        else:
            # a lossy wire can never cost MORE than fp32
            assert row["bytes_ratio"] < 1.0
        seen.add((row["algorithm"], row["scenario"],
                  row["compress"], row["level"]))
    assert seen == _expected_rows(persisted)
    # the capability guard held: no topk rows on the flow algorithm
    assert not any(
        r["algorithm"] == "fedecado" and r["compress"] == "topk"
        for r in persisted["results"]
    )

    # -- schema: monotonicity + criterion blocks --------------------------
    assert persisted["monotonicity"], "no monotonicity witnesses"
    for m in persisted["monotonicity"]:
        assert set(m) == {
            "algorithm", "scenario", "family", "settings", "bytes_up", "ok",
        }
        ups = m["bytes_up"]
        assert m["ok"] == all(a > b for a, b in zip(ups, ups[1:]))
        assert m["ok"], (
            f"bytes_up not monotone for {m['family']}/{m['algorithm']}: {ups}"
        )
    crit = persisted["criterion"]
    assert crit["scenario"] == "dirichlet01"
    assert crit["acc_floor"] == 0.95 and crit["bytes_ceiling"] == 0.25
    assert isinstance(crit["witnesses"], list)
    assert crit["ok"] == bool(crit["witnesses"])


def test_repo_comm_artifact_matches_schema_and_witnesses_frontier():
    """The committed BENCH_comm.json must parse under schema v1 and witness
    the acceptance criteria: at least one lossy setting holds >= 95% of the
    uncompressed dirichlet01 accuracy at <= 25% of its uplink bytes, every
    in-family bytes ladder is strictly monotone, and the grid covers
    fedecado vs the fedprox/fednova baselines."""
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "BENCH_comm.json"
    )
    if not os.path.exists(path):
        pytest.skip("no committed BENCH_comm.json")
    with open(path) as f:
        report = json.load(f)

    assert report["schema_version"] == 1
    assert "dirichlet01" in report["scenarios"]
    assert set(("fedecado", "fedprox", "fednova")) <= set(report["algorithms"])
    names = {s["compress"] for s in report["settings"]}
    assert set(("identity", "int8", "int4", "topk")) <= names

    crit = report["criterion"]
    assert crit["ok"], "no accuracy-vs-bytes frontier witness on dirichlet01"
    for w in crit["witnesses"]:
        assert w["acc_ratio"] >= crit["acc_floor"]
        assert w["bytes_ratio"] <= crit["bytes_ceiling"]
        assert w["compress"] != "identity"

    assert report["monotonicity"]
    assert all(m["ok"] for m in report["monotonicity"]), (
        "committed artifact has a non-monotone bytes ladder"
    )

    # fedecado appears on the frontier with a quantized wire (the flow
    # family's only lossy option) and its rows never use topk
    fe = [r for r in report["results"] if r["algorithm"] == "fedecado"]
    assert any(r["compress"] in ("int8", "int4") for r in fe)
    assert not any(r["compress"] == "topk" for r in fe)

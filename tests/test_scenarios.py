"""Scenario subsystem tests (repro/scenarios, DESIGN.md §7).

Three layers:
  * partition invariants — every sample assigned exactly once, fractions
    sum to 1, per-seed reproducibility, label-shard class cap, and the
    dirichlet retry cap raising instead of spinning forever;
  * spec/runtime semantics — registry behaviour, feature-shift/label-noise
    materialization, availability traces, device profiles, mid-round
    dropout, and drift re-draws;
  * integration — every registered scenario runs end-to-end through FedSim,
    and the dropout scenario exercises the event backend's staleness path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig
from repro.fed.partition import (
    data_fractions,
    dirichlet_partition,
    iid_partition,
    label_shard_partition,
    quantity_skew_partition,
)
from repro.scenarios import (
    AvailabilitySpec,
    DropoutSpec,
    FeatureShiftSpec,
    PartitionSpec,
    Scenario,
    available_scenarios,
    get_scenario,
    make_scenario,
    register_scenario,
)


# ---------------------------------------------------------------------------
# partition invariants
# ---------------------------------------------------------------------------


def _labels(n=600, classes=8, seed=0):
    return np.random.RandomState(seed).randint(0, classes, size=n)


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(["dirichlet", "label_shard", "quantity_skew", "iid"]),
    n_clients=st.integers(2, 12),
    seed=st.integers(0, 100),
)
def test_partitioners_cover_every_sample_exactly_once(kind, n_clients, seed):
    labels = _labels(seed=seed)
    parts = PartitionSpec(kind, alpha=0.5).build(labels, n_clients, seed)
    assert len(parts) == n_clients
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)   # disjoint and complete
    np.testing.assert_allclose(data_fractions(parts).sum(), 1.0, rtol=1e-5)


@pytest.mark.parametrize(
    "fn",
    [
        lambda labels, s: dirichlet_partition(labels, 6, 0.3, seed=s),
        lambda labels, s: label_shard_partition(labels, 6, 2, seed=s),
        lambda labels, s: quantity_skew_partition(len(labels), 6, seed=s),
        lambda labels, s: iid_partition(len(labels), 6, seed=s),
    ],
    ids=["dirichlet", "label_shard", "quantity_skew", "iid"],
)
def test_partitioners_reproducible_per_seed(fn):
    labels = _labels()
    a = fn(labels, 3)
    b = fn(labels, 3)
    c = fn(labels, 4)
    for pa, pb in zip(a, b, strict=True):
        np.testing.assert_array_equal(pa, pb)
    assert any(
        len(pa) != len(pc) or (pa != pc).any() for pa, pc in zip(a, c)
    ), "different seeds should give different draws"


@pytest.mark.parametrize("k", [1, 2, 3])
def test_label_shard_class_cap(k):
    labels = _labels(n=800, classes=8)
    parts = label_shard_partition(labels, 8, shards_per_client=k, seed=1)
    for part in parts:
        assert len(part) > 0
        assert len(np.unique(labels[part])) <= k


def test_quantity_skew_is_skewed_and_floored():
    parts = quantity_skew_partition(1000, 10, zipf_a=1.6, seed=0, min_size=3)
    sizes = np.sort([len(p) for p in parts])
    assert sizes.min() >= 3
    assert sizes[-1] > 4 * sizes[0]       # heavy head vs long tail


def test_dirichlet_min_size_unreachable_raises_immediately():
    labels = _labels(n=10)
    with pytest.raises(ValueError, match="unreachable"):
        dirichlet_partition(labels, 5, alpha=0.1, seed=0, min_size=4)


def test_dirichlet_retry_cap_raises_instead_of_spinning():
    # one class, 20 samples, 10 clients each needing >= 2 under alpha=1e-3:
    # essentially every draw concentrates on one client, so the capped
    # retry loop must terminate with the explanatory error
    labels = np.zeros(20, np.int64)
    with pytest.raises(ValueError, match="max_retries"):
        dirichlet_partition(labels, 10, alpha=1e-3, seed=0, min_size=2,
                            max_retries=3)


def test_dirichlet_first_try_success_matches_historic_stream():
    """Attempt 0 keeps the pre-fix rng stream: the retry machinery must not
    perturb partitions that succeeded first try (every committed artifact
    depends on them)."""
    labels = _labels(n=500, classes=7, seed=3)
    parts = dirichlet_partition(labels, 5, alpha=0.5, seed=3)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == 500
    parts2 = dirichlet_partition(labels, 5, alpha=0.5, seed=3)
    for a, b in zip(parts, parts2, strict=True):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_enumerates_and_resolves():
    names = available_scenarios()
    assert len(names) >= 6
    for required in ("dirichlet01", "feature-shift", "diurnal",
                     "flaky-dropout", "hetero-devices", "quantity-zipf"):
        assert required in names
        assert get_scenario(required).name == required


def test_registry_rejects_duplicates_and_unknown_names():
    with pytest.raises(ValueError, match="already registered"):
        register_scenario(Scenario("dirichlet01"))
    with pytest.raises(ValueError, match="registered scenarios"):
        get_scenario("no-such-scenario")
    with pytest.raises(TypeError):
        make_scenario(42)


def test_make_scenario_accepts_adhoc_spec():
    spec = Scenario("adhoc-test", partition=PartitionSpec("dirichlet", alpha=2.0))
    rt = make_scenario(spec)
    assert rt.spec is spec
    assert "adhoc-test" not in available_scenarios()


# ---------------------------------------------------------------------------
# materialization: statistical axis
# ---------------------------------------------------------------------------


def _data(n=400, dim=8, classes=4, seed=0):
    return make_classification(n, dim=dim, n_classes=classes, seed=seed)


def test_feature_shift_rotates_and_scales_per_client():
    data = _data()
    rt = make_scenario(Scenario("t", feature_shift=FeatureShiftSpec()))
    out, parts = rt.materialize(data, 5, seed=0)
    assert out is not data and out["x"] is not data["x"]
    np.testing.assert_array_equal(out["y"], data["y"])      # labels untouched
    for part in parts:
        # orthogonal rotation × scalar s_i: per-sample norm ratio is the
        # SAME constant within a client (and ~never exactly 1)
        r = np.linalg.norm(out["x"][part], axis=1) / np.linalg.norm(
            data["x"][part], axis=1
        )
        np.testing.assert_allclose(r, r[0], rtol=1e-5)
    ratios = [
        np.linalg.norm(out["x"][p[0]]) / np.linalg.norm(data["x"][p[0]])
        for p in parts
    ]
    assert np.std(ratios) > 1e-3, "clients should get distinct scales"


def test_label_noise_flips_about_the_requested_fraction():
    data = _data(n=4000)
    rt = make_scenario(Scenario("t", label_noise=0.25))
    out, _ = rt.materialize(data, 5, seed=0)
    np.testing.assert_array_equal(out["x"], data["x"])      # inputs untouched
    flipped = np.mean(out["y"] != data["y"])
    # uniform resample keeps the old label 1/classes of the time
    expect = 0.25 * (1 - 1 / 4)
    assert abs(flipped - expect) < 0.05
    assert data["y"] is not out["y"]


def test_materialize_without_transforms_returns_same_data_object():
    data = _data()
    rt = make_scenario("dirichlet01")
    out, parts = rt.materialize(data, 5, seed=0)
    assert out is data                  # identity preserved -> device caches hold
    assert len(parts) == 5


def test_drift_redraws_partitions_deterministically():
    data = _data()
    rt = make_scenario("drift")
    _, p0 = rt.materialize(data, 5, seed=9)
    _, p1 = rt.materialize(data, 5, seed=9)     # drift_count advanced
    assert any(len(a) != len(b) or (a != b).any() for a, b in zip(p0, p1))
    rt2 = make_scenario("drift")
    _, q0 = rt2.materialize(data, 5, seed=9)
    for a, b in zip(p0, q0, strict=True):
        np.testing.assert_array_equal(a, b)     # same seed, same first draw


# ---------------------------------------------------------------------------
# systems axis hooks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["sine", "blocks", "markov"])
def test_availability_cohorts_are_valid_subsets(kind):
    rt = make_scenario(
        Scenario("t", availability=AvailabilitySpec(kind, n_blocks=3))
    )
    rng = np.random.RandomState(0)
    n, A = 12, 5
    for rnd in range(8):
        idx = rt.draw_cohort(rng, rnd, n, A)
        assert 1 <= len(idx) <= A
        assert (np.diff(idx) > 0).all()             # sorted, unique
        assert idx.min() >= 0 and idx.max() < n
        if kind == "blocks":
            blocks = np.arange(n) * 3 // n
            assert (blocks[idx] == rnd % 3).all()   # deterministic membership


def test_arrival_poisson_trace_is_deterministic_and_clipped():
    """Arrival traces replace the fixed cohort size with k ~ Poisson(rate)
    clipped to [1, |pool|]; the draw consumes the given rng stream only, so
    identical streams yield identical traces (the backend-equivalence
    determinism contract)."""
    from repro.scenarios import ArrivalSpec

    rt = make_scenario(Scenario("t", arrivals=ArrivalSpec("poisson", rate=5.0)))
    rng = np.random.RandomState(0)
    cohorts = [rt.draw_cohort(rng, r, 20, 4) for r in range(12)]
    sizes = [len(c) for c in cohorts]
    assert all(1 <= k <= 20 for k in sizes)
    assert len(set(sizes)) > 1                 # round-varying, ignores A=4
    for idx in cohorts:
        assert (np.diff(idx) > 0).all()        # sorted, unique
        assert idx.min() >= 0 and idx.max() < 20
    rng2 = np.random.RandomState(0)
    replay = [rt.draw_cohort(rng2, r, 20, 4) for r in range(12)]
    for a, b in zip(cohorts, replay, strict=True):
        np.testing.assert_array_equal(a, b)


def test_arrival_diurnal_trace_modulates_rate():
    """λ(rnd) = rate_min + (rate − rate_min)·(1 + sin(2π·rnd/period))/2:
    peak rounds (sin = +1) must land far more endpoints than troughs."""
    from repro.scenarios import ArrivalSpec

    spec = ArrivalSpec("diurnal", rate=30.0, period=8, rate_min=1.0)
    rt = make_scenario(Scenario("t", arrivals=spec))
    peaks = [
        len(rt.draw_cohort(np.random.RandomState(t), 2, 64, 4))
        for t in range(30)
    ]
    troughs = [
        len(rt.draw_cohort(np.random.RandomState(100 + t), 6, 64, 4))
        for t in range(30)
    ]
    assert np.mean(peaks) > 3 * np.mean(troughs)


def test_arrivals_compose_with_availability_pool():
    """Availability restricts WHO can land, arrivals decide HOW MANY: with
    a blocks trace the Poisson count is clipped to the active block and
    every drawn id stays inside it."""
    from repro.scenarios import ArrivalSpec

    rt = make_scenario(Scenario(
        "t",
        availability=AvailabilitySpec("blocks", n_blocks=3),
        arrivals=ArrivalSpec("poisson", rate=6.0),
    ))
    n = 12
    blocks = np.arange(n) * 3 // n
    for rnd in range(6):
        idx = rt.draw_cohort(np.random.RandomState(rnd), rnd, n, 5)
        assert (blocks[idx] == rnd % 3).all()
        assert 1 <= len(idx) <= 4              # block size caps the clip


def test_arrival_unknown_kind_raises_actionably():
    from repro.scenarios import ARRIVAL_KINDS, ArrivalSpec

    rt = make_scenario(Scenario("t", arrivals=ArrivalSpec("weibull")))
    with pytest.raises(ValueError, match="weibull"):
        rt.draw_cohort(np.random.RandomState(0), 0, 8, 4)
    assert ARRIVAL_KINDS == ("poisson", "diurnal")


def test_arrival_axes_tag():
    from repro.scenarios import ArrivalSpec

    s = Scenario("t", arrivals=ArrivalSpec("diurnal"))
    assert "arr-diurnal" in s.axes()
    assert "arr" not in Scenario("t2").axes()


def test_device_profiles_draw_within_tier_ranges_and_persist_over_drift():
    rt = make_scenario("diurnal")
    data = _data()
    rt.materialize(data, 10, seed=0)
    pin0 = rt.tier_of(np.arange(10))
    rt.materialize(data, 10, seed=0)                # drift re-draw
    np.testing.assert_array_equal(pin0, rt.tier_of(np.arange(10)))
    # lazy pinning: any subset hashes to the same tiers as the full sweep
    np.testing.assert_array_equal(pin0[[3, 7]], rt.tier_of([3, 7]))

    rng = np.random.RandomState(1)
    idx = np.arange(10)
    lrs, eps = rt.draw_rates(rng, idx)
    for j, i in enumerate(idx):
        p = rt.spec.profiles[int(pin0[i])]
        assert p.lr_min <= lrs[j] <= p.lr_max
        assert p.epochs_min <= eps[j] <= p.epochs_max


def test_dropout_truncates_to_nonempty_prefix():
    rt = make_scenario(Scenario("t", dropout=DropoutSpec(prob=1.0, min_frac=0.2)))
    rng = np.random.RandomState(0)
    n_steps = np.asarray([1, 4, 10, 25], np.int64)
    out = rt.apply_dropout(rng, n_steps)
    assert (out >= 1).all()
    assert (out <= n_steps).all()
    assert (out < n_steps).any()        # prob=1 must actually truncate


# ---------------------------------------------------------------------------
# FedSim integration
# ---------------------------------------------------------------------------


_PROBLEM = None


def _problem():
    global _PROBLEM
    if _PROBLEM is None:
        data = make_classification(384, dim=6, n_classes=3, seed=11)
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        params0 = {
            "w0": jax.random.normal(k1, (6, 8)) / 3.0,
            "b0": jnp.zeros((8,)),
            "w1": jax.random.normal(k2, (8, 3)) / np.sqrt(8),
            "b1": jnp.zeros((3,)),
        }

        def loss_fn(p, batch):
            h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
            lp = jax.nn.log_softmax(h)
            return -jnp.mean(
                jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
            )

        _PROBLEM = (data, params0, loss_fn)
    return _PROBLEM


@pytest.mark.parametrize("name", available_scenarios())
def test_every_registered_scenario_runs_end_to_end(name):
    data, params0, loss_fn = _problem()
    cfg = FedSimConfig(
        algorithm="fednova", n_clients=6, participation=0.6, rounds=2,
        batch_size=4, steps_per_epoch=1, seed=3, backend="vectorized",
        scenario=name,
    )
    sim = FedSim(loss_fn, params0, data, None, cfg)
    hist = sim.run()
    assert len(hist.loss) == 2
    assert np.isfinite(hist.loss).all()


def test_scenario_rejects_explicit_partitions():
    data, params0, loss_fn = _problem()
    cfg = FedSimConfig(algorithm="fednova", n_clients=6, scenario="iid")
    with pytest.raises(ValueError, match="partitions=None"):
        FedSim(loss_fn, params0, data, [np.arange(10)] * 6, cfg)


def test_drift_scenario_rebuilds_partitions_midrun():
    data, params0, loss_fn = _problem()
    spec = dataclasses.replace(get_scenario("drift"), drift_every=2)
    cfg = FedSimConfig(
        algorithm="fednova", n_clients=6, participation=0.6, rounds=4,
        batch_size=4, steps_per_epoch=1, seed=3, backend="vectorized",
        scenario=spec,
    )
    sim = FedSim(loss_fn, params0, data, None, cfg)
    before = [p.copy() for p in sim.partitions]
    hist = sim.run()
    assert np.isfinite(hist.loss).all()
    assert sim.scn.drift_count == 2     # initial materialize + one drift
    changed = any(
        len(a) != len(b) or (a != b).any()
        for a, b in zip(before, sim.partitions)
    )
    assert changed, "drift boundary should have re-drawn the partition"


def test_dropout_scenario_exercises_event_staleness():
    """Mid-round dropout + device tiers + a sub-1.0 horizon: stragglers
    must be left pending (the staleness/re-anchoring path) and the flow
    invariant machinery must keep losses finite throughout."""
    data, params0, loss_fn = _problem()
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=8, participation=0.75, rounds=1,
        batch_size=4, steps_per_epoch=2, seed=5, backend="event",
        event_horizon=0.5, scenario="flaky-dropout",
        consensus=ConsensusConfig(max_substeps=6),
    )
    sim = FedSim(loss_fn, params0, data, None, cfg)
    total_stale = 0
    for _ in range(6):
        hist = sim.run(1)
        assert np.isfinite(hist.loss).all()
        total_stale += sim.backend.last_round_stats["stale"]
    assert total_stale > 0, "sub-1.0 horizon under dropout must leave stragglers"


def test_full_participation_algorithm_ignores_availability():
    """ecado is synchronous-by-definition: availability traces and device
    profiles must degrade to the full synchronous cohort draw."""
    data, params0, loss_fn = _problem()
    cfg = FedSimConfig(
        algorithm="ecado", n_clients=6, rounds=1, batch_size=4,
        steps_per_epoch=1, seed=3, backend="sequential", scenario="diurnal",
        consensus=ConsensusConfig(max_substeps=4),
    )
    sim = FedSim(loss_fn, params0, data, None, cfg)
    plan = sim._draw_plan(0, sim.n)
    np.testing.assert_array_equal(plan.idx, np.arange(6))
    assert (plan.n_steps == sim.cfg.epochs_fixed * sim.cfg.steps_per_epoch).all()

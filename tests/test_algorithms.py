"""Algorithm plugin registry tests (fed/algorithms, DESIGN.md §6).

* registration: builtins present, duplicate names rejected loudly,
  unknown names produce an error that LISTS the registered names (both at
  registry level and from the CLI ``choices=`` wiring);
* capability flags: declared correctly for the builtins and actually
  consulted by FedSim / the execution backends (event-backend gating,
  full-participation, heterogeneity eligibility);
* the client-kind registry that algorithm plugins extend;
* CohortPlan.windows() vectorization: the batched float32 rounding must
  match the historical per-element path exactly.
"""
import numpy as np
import pytest

from repro.fed.algorithms import (
    FederatedAlgorithm,
    available_algorithms,
    get_algorithm,
    make_algorithm,
    register,
)
from repro.fed.client import CLIENT_KINDS, client_kind_spec, register_client_kind


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtin_algorithms_registered():
    names = available_algorithms()
    assert set(names) >= {"fedecado", "ecado", "fedavg", "fedprox", "fednova"}
    # registration order is stable (CLIs enumerate it into --algorithm)
    assert names.index("fedecado") < names.index("fedavg")


def test_duplicate_registration_rejected():
    class Impostor(FederatedAlgorithm):
        name = "fedavg"

    with pytest.raises(ValueError, match="already registered"):
        register(Impostor)
    # the original class is untouched
    assert get_algorithm("fedavg").__name__ == "FedAvg"


def test_register_requires_a_name():
    class Nameless(FederatedAlgorithm):
        pass

    with pytest.raises(ValueError, match="name"):
        register(Nameless)


def test_unknown_algorithm_error_lists_registry():
    with pytest.raises(ValueError) as ei:
        get_algorithm("fedsgdmomentum")
    msg = str(ei.value)
    assert "fedsgdmomentum" in msg
    for name in available_algorithms():
        assert name in msg


def test_fedsim_rejects_unknown_algorithm_with_listing():
    from repro.fed import FedSim, FedSimConfig

    cfg = FedSimConfig(algorithm="nope", n_clients=2)
    data = {"x": np.zeros((4, 2), np.float32), "y": np.zeros((4,), np.int64)}
    parts = [np.asarray([0, 1]), np.asarray([2, 3])]
    with pytest.raises(ValueError, match="fedecado"):
        FedSim(lambda p, b: 0.0, {"w": np.zeros((2,))}, data, parts, cfg)


# ---------------------------------------------------------------------------
# capability flags
# ---------------------------------------------------------------------------


def test_capability_flags_of_builtins():
    assert get_algorithm("fedecado").has_flow_dynamics
    assert get_algorithm("fedecado").refreshable_gains
    assert get_algorithm("ecado").full_participation_only
    assert not get_algorithm("ecado").supports_hetero
    assert not get_algorithm("ecado").refreshable_gains
    for name in ("fedavg", "fedprox", "fednova"):
        cls = get_algorithm(name)
        assert not cls.has_flow_dynamics
        assert not cls.full_participation_only
        assert cls.supports_hetero
    # client kinds resolve in the client-kind registry
    for name in available_algorithms():
        client_kind_spec(get_algorithm(name).client_kind)


def test_capability_gates_event_backend():
    """The event scheduler must be gated on has_flow_dynamics for EVERY
    registered algorithm — not on a name list."""
    import jax.numpy as jnp

    from repro.data import make_classification
    from repro.fed import FedSim, FedSimConfig, dirichlet_partition

    data = make_classification(64, dim=4, n_classes=2, seed=0)
    parts = dirichlet_partition(data["y"], 4, alpha=1.0, seed=0)
    params0 = {"w": jnp.zeros((4, 2))}
    loss_fn = lambda p, b: jnp.mean(jnp.square(b["x"] @ p["w"]))
    for name in available_algorithms():
        if get_algorithm(name).has_flow_dynamics:
            continue
        cfg = FedSimConfig(
            algorithm=name, n_clients=4, participation=0.5, rounds=1,
            batch_size=8, steps_per_epoch=1, seed=0, backend="event",
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        with pytest.raises(ValueError, match="event backend"):
            sim.run()


def test_sharded_backend_runs_plugin_without_weighted_delta_spec():
    """A protocol-conformant plugin that implements ``aggregate`` directly
    (no flow dynamics, no WeightedDeltaAlgorithm spec) must still run on
    the sharded backend — via the per-round dense-aggregate fallback, not
    an AttributeError inside the segment path."""
    import jax
    import jax.numpy as jnp

    from repro.data import make_classification
    from repro.fed import FedSim, FedSimConfig, dirichlet_partition

    class MeanOfEndpoints(FederatedAlgorithm):
        name = "mean-of-endpoints-test"   # instance-injected, NOT registered

        def aggregate(self, sim, plan, result):
            sim.params = jax.tree.map(
                lambda xa: jnp.mean(xa, axis=0), result.x_new_a
            )

    data = make_classification(256, dim=6, n_classes=3, seed=4)
    parts = dirichlet_partition(data["y"], 6, alpha=1.0, seed=4)
    params0 = {"w": jax.random.normal(jax.random.PRNGKey(4), (6, 3)) / 3.0}

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(batch["x"] @ p["w"])
        return -jnp.mean(
            jnp.take_along_axis(lp, batch["y"][:, None].astype(np.int32), -1)
        )

    cfg = FedSimConfig(
        algorithm="fedavg", n_clients=6, participation=0.5, rounds=2,
        batch_size=8, steps_per_epoch=2, seed=1, backend="sharded",
        sharded_pad_multiple=3,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    sim.alg = MeanOfEndpoints(cfg)        # swap in the bare-protocol plugin
    hist = sim.run()
    assert len(hist.loss) == 2 and np.isfinite(hist.loss).all()


def test_make_algorithm_instances_are_per_config():
    from repro.fed import FedSimConfig

    a = make_algorithm(FedSimConfig(algorithm="fednova"))
    b = make_algorithm(FedSimConfig(algorithm="fednova"))
    assert a is not b and type(a) is type(b)


# ---------------------------------------------------------------------------
# client-kind registry
# ---------------------------------------------------------------------------


def test_client_kind_registry_builtins_and_errors():
    assert {"fedecado", "fedprox", "sgd"} <= set(CLIENT_KINDS)
    assert client_kind_spec("fedecado").takes_flow
    assert not client_kind_spec("sgd").takes_flow
    with pytest.raises(ValueError, match="already registered"):
        register_client_kind("sgd", lambda mu: None)
    with pytest.raises(ValueError) as ei:
        client_kind_spec("warp")
    assert "sgd" in str(ei.value)   # error lists registered kinds


# ---------------------------------------------------------------------------
# CohortPlan.windows() vectorization regression
# ---------------------------------------------------------------------------


def test_windows_vectorized_rounding():
    """The batched (lrs · n_steps).astype(float32) must reproduce the old
    per-element np.float32(float(lr) · int(ns)) rounding bit-for-bit:
    both compute the exact product in double and round once to float32."""
    from repro.sim import CohortPlan

    rng = np.random.RandomState(0)
    lrs = rng.uniform(1e-5, 2e-1, 4096).astype(np.float32)
    n_steps = rng.randint(1, 1 << 14, 4096).astype(np.int64)
    plan = CohortPlan(
        rnd=0, idx=np.arange(4096), lrs=lrs, epochs=n_steps,
        n_steps=n_steps, batch_idx=[],
    )
    old = np.asarray(
        [np.float32(float(lr) * int(ns)) for lr, ns in zip(lrs, n_steps)],
        np.float32,
    )
    new = plan.windows()
    assert new.dtype == np.float32
    np.testing.assert_array_equal(new.view(np.uint32), old.view(np.uint32))

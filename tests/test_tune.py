"""Tests for the repro.tune cost-model subsystem (DESIGN.md §12).

Covers the satellite parser coverage (hlocost trip-count multiplication,
collective "-done" dedup, fusion-boundary byte counting, roofline term
math — all on canned HLO text, no compilation), the shared dtype table,
the dryrun XLA_FLAGS merge, the bench emitter, the BENCH_* regression
gate comparators + CLI exit codes, and the "auto" backend: selection for
every flow-capable algorithm at n ∈ {10, 100, 1000} plus the FedSim
end-to-end run-log decision record.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax  # noqa: F401 — lock the device topology before any env games
import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# hlocost parsers on canned HLO text (no compilation)
# ---------------------------------------------------------------------------

_WHILE_HLO = """\
HloModule trip_test

body.1 (p: (f32[8,16], f32[16,8])) -> (f32[8,16], f32[16,8]) {
  p0 = (f32[8,16], f32[16,8]) parameter(0)
  x = f32[8,16] get-tuple-element(%p0), index=0
  y = f32[16,8] get-tuple-element(%p0), index=1
  d = f32[8,8] dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT t = (f32[8,16], f32[16,8]) tuple(%x, %y)
}

cond.1 (p: (f32[8,16], f32[16,8])) -> pred[] {
  p0 = (f32[8,16], f32[16,8]) parameter(0)
  ROOT lt = pred[] constant(true)
}

ENTRY main (a: (f32[8,16], f32[16,8])) -> (f32[8,16], f32[16,8]) {
  a0 = (f32[8,16], f32[16,8]) parameter(0)
  ROOT w = (f32[8,16], f32[16,8]) while(%a0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"TRIP"}}
}
"""


def test_hlocost_trip_count_multiplies_loop_body():
    from repro.tune import hlocost

    one = hlocost.analyze(_WHILE_HLO.replace("TRIP", "1"))
    five = hlocost.analyze(_WHILE_HLO.replace("TRIP", "5"))
    # dot: 2 · prod(out 8x8) · contracting 16 = 2048 flops per iteration
    assert one["flops"] == pytest.approx(2048.0)
    assert five["flops"] == pytest.approx(5 * 2048.0)
    assert five["bytes"] == pytest.approx(5 * one["bytes"])
    assert one["unknown_trip_counts"] == 0


def test_hlocost_unknown_trip_count_is_flagged():
    from repro.tune import hlocost

    text = _WHILE_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"TRIP"}}', ""
    )
    out = hlocost.analyze(text)
    assert out["unknown_trip_counts"] == 1
    assert out["flops"] == pytest.approx(2048.0)  # trip defaults to 1


_COLLECTIVE_HLO = """\
HloModule coll_test

ENTRY main (a: f32[1024]) -> f32[1024] {
  a0 = f32[1024] parameter(0)
  ars = f32[1024] all-reduce-start(%a0), replica_groups={}
  ard = f32[1024] all-reduce-done(%ars)
  rs = f32[256] reduce-scatter(%ard), dimensions={0}
  ROOT c = f32[1024] copy(%ard)
}
"""


def test_hlocost_collective_done_halves_not_double_counted():
    from repro.tune import hlocost

    out = hlocost.analyze(_COLLECTIVE_HLO)
    # the async pair counts ONCE (the -start), 1024 f32 = 4096 bytes;
    # reduce-scatter output is 256 f32 = 1024 bytes
    assert out["coll_all-reduce"] == pytest.approx(4096.0)
    assert out["coll_reduce-scatter"] == pytest.approx(1024.0)
    assert out["collective_bytes"] == pytest.approx(5120.0)


_FUSION_HLO = """\
HloModule fusion_test

fused_computation (fp0: f32[128,64], fp1: f32[1,64], fp2: s32[]) -> f32[128,64] {
  fp0 = f32[128,64] parameter(0)
  fp1 = f32[1,64] parameter(1)
  fp2 = s32[] parameter(2)
  ROOT dus = f32[128,64] dynamic-update-slice(%fp0, %fp1, %fp2, %fp2)
}

ENTRY main (buf: f32[128,64], upd: f32[1,64]) -> f32[128,64] {
  buf = f32[128,64] parameter(0)
  upd = f32[1,64] parameter(1)
  ROOT f = f32[128,64] fusion(%buf, %upd), kind=kLoop, calls=%fused_computation
}
"""


def test_hlocost_fusion_boundary_in_place_update():
    from repro.tune import hlocost

    out = hlocost.analyze(_FUSION_HLO)
    # a dus-rooted fusion is an in-place slice write: traffic = 2x the
    # 1x64 f32 update slice (512 bytes), NOT the 32 KiB carried buffer —
    # and the fusion body is never costed standalone
    assert out["bytes"] == pytest.approx(2 * 64 * 4)
    assert out["flops"] == 0.0


def test_hlocost_fusion_body_not_walked():
    from repro.tune import hlocost

    comps, entry, root_ops = hlocost.parse_module(_FUSION_HLO)
    assert entry == "main"
    assert "fused_computation" in comps
    assert root_ops["fused_computation"] == "dynamic-update-slice"


# ---------------------------------------------------------------------------
# roofline terms + shared dtype table
# ---------------------------------------------------------------------------


def test_roofline_terms_math():
    from repro.tune.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms

    t = roofline_terms(PEAK_FLOPS, HBM_BW / 2, ICI_BW / 4)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute_s"
    assert t["bound_s"] == pytest.approx(1.0)


def test_parse_collective_bytes_counts_subbyte_dtypes():
    from repro.tune.roofline import parse_collective_bytes

    # s4 was missing from roofline's old private dtype table — the shared
    # table (repro.tune.dtypes) parses it now; sub-byte rounds up to 1B
    text = "  %ag = s4[100] all-gather(%x), dimensions={0}\n"
    out = parse_collective_bytes(text)
    assert out["all-gather"] == 100
    assert out["total"] == 100


def test_dtype_table_single_copy_across_shims():
    from repro.launch import hlocost as launch_hlocost
    from repro.launch import roofline as launch_roofline
    from repro.tune import dtypes

    assert launch_hlocost._DTYPE_BYTES is dtypes.DTYPE_BYTES
    assert launch_roofline._DTYPE_BYTES is dtypes.DTYPE_BYTES
    assert launch_hlocost._SHAPE_RE is dtypes.SHAPE_RE
    assert launch_roofline._SHAPE_RE is dtypes.SHAPE_RE


def test_shape_re_longest_match_wins():
    from repro.tune.dtypes import SHAPE_RE, text_bytes

    # "s64" must never half-match as "s4"
    assert SHAPE_RE.findall("s64[2]") == [("s64", "2")]
    assert text_bytes("s64[2]") == 16
    assert text_bytes("s4[2]") == 2
    assert text_bytes("(f32[4], bf16[8])") == 16 + 16


# ---------------------------------------------------------------------------
# dryrun XLA_FLAGS merge (satellite: the clobber fix)
# ---------------------------------------------------------------------------


def test_dryrun_import_does_not_mutate_xla_flags():
    # the 512-device forcing must only fire when dryrun IS the program:
    # importing the module for its helpers used to poison the whole
    # process (and every subprocess) with 512 forced host devices
    before = os.environ.get("XLA_FLAGS")
    import repro.launch.dryrun  # noqa: F401

    assert os.environ.get("XLA_FLAGS") == before


def test_with_forced_device_count_preserves_existing_flags():
    from repro.launch.dryrun import _with_forced_device_count

    out = _with_forced_device_count(
        "--xla_cpu_foo=1 --xla_force_host_platform_device_count=4 --bar=z",
        512,
    )
    assert "--xla_cpu_foo=1" in out
    assert "--bar=z" in out
    assert out.count("--xla_force_host_platform_device_count") == 1
    assert out.endswith("--xla_force_host_platform_device_count=512")
    # empty env: just the forced flag
    assert _with_forced_device_count("", 8) == (
        "--xla_force_host_platform_device_count=8"
    )


# ---------------------------------------------------------------------------
# bench emitter
# ---------------------------------------------------------------------------


def test_write_bench_report_envelope_and_machine_block(tmp_path):
    from repro.tune.bench_io import write_bench_report

    report = {"schema_version": 1, "benchmark": "test", "results": []}
    path = str(tmp_path / "BENCH_test.json")
    out = write_bench_report(report, path, calibrate=False)
    assert out is report and "machine" in report
    assert report["machine"]["platform"]
    raw = open(path).read()
    assert raw.endswith("\n")
    assert json.loads(raw) == report

    with pytest.raises(ValueError, match="envelope"):
        write_bench_report({"results": []}, str(tmp_path / "bad.json"))


# ---------------------------------------------------------------------------
# gate comparators + CLI
# ---------------------------------------------------------------------------


def _engine_report(rps, machine=None):
    rep = {
        "schema_version": 5,
        "benchmark": "engine",
        "results": [
            {
                "algorithm": "fedecado", "backend": b, "n_clients": 10,
                "rounds_per_sec": r,
            }
            for b, r in rps.items()
        ],
    }
    if machine is not None:
        rep["machine"] = machine
    return rep


def test_gate_engine_self_compare_passes():
    from repro.tune.gate import compare_engine

    base = _engine_report({"event": 100.0, "vectorized": 5.0})
    rep = compare_engine(base, base, threshold=0.5)
    assert rep["ok"] and rep["n_checked"] == 2 and not rep["violations"]


def test_gate_engine_fails_on_regression_and_respects_threshold():
    from repro.tune.gate import compare_engine

    base = _engine_report({"event": 100.0})
    cand = _engine_report({"event": 30.0})   # 70% slower
    assert not compare_engine(base, cand, threshold=0.5)["ok"]
    assert compare_engine(base, cand, threshold=0.8)["ok"]


def test_gate_engine_machine_normalization():
    from repro.tune.gate import compare_engine

    fast = {"calibration": {"flops_per_s": 16e9, "bytes_per_s": 16e9}}
    slow = {"calibration": {"flops_per_s": 1e9, "bytes_per_s": 1e9}}
    base = _engine_report({"event": 100.0}, machine=fast)
    cand = _engine_report({"event": 30.0}, machine=slow)
    # candidate machine is 16x slower -> scale 16: no regression
    rep = compare_engine(base, cand, threshold=0.5)
    assert rep["normalization"]["calibrated"]
    assert rep["normalization"]["scale"] == pytest.approx(16.0)
    assert rep["ok"]
    # without calibration blocks the same rows fail (scale 1, uncalibrated)
    rep2 = compare_engine(
        _engine_report({"event": 100.0}), _engine_report({"event": 30.0}),
        threshold=0.5,
    )
    assert not rep2["normalization"]["calibrated"] and not rep2["ok"]


def test_gate_engine_unmatched_rows_are_skipped_not_failed():
    from repro.tune.gate import compare_engine

    base = _engine_report({"event": 100.0, "sharded": 50.0})
    cand = _engine_report({"event": 100.0})
    rep = compare_engine(base, cand, threshold=0.5)
    assert rep["ok"]
    # row keys gained the participation column in schema v6 (defaulted to
    # 1.0 for pre-v6 rows, so dense cells keep matching across versions)
    assert ["fedecado", "sharded", 10, 1.0] in rep["skipped_rows"]


def _comm_report(rounds, bytes_up, acc_ratio=1.0, criterion_ok=True):
    return {
        "schema_version": 1,
        "benchmark": "comm",
        "rounds": rounds,
        "results": [{
            "algorithm": "fedprox", "scenario": "dirichlet01",
            "compress": "int8", "level": None,
            "bytes_up": bytes_up, "bytes_down": bytes_up * 4,
            "acc": 0.3, "acc_ratio": acc_ratio,
        }],
        "criterion": {"ok": criterion_ok},
    }


def test_gate_comm_per_round_bytes_erosion():
    from repro.tune.gate import compare_comm

    base = _comm_report(rounds=30, bytes_up=3000.0)
    # shorter run, identical per-round bytes: fine
    assert compare_comm(base, _comm_report(rounds=10, bytes_up=1000.0))["ok"]
    # ANY per-round growth is erosion, regardless of threshold
    rep = compare_comm(
        base, _comm_report(rounds=10, bytes_up=1100.0), threshold=0.9
    )
    assert not rep["ok"]
    assert "bytes_up" in rep["violations"][0]["problems"][0]


def test_gate_comm_criterion_and_acc_ratio_regressions():
    from repro.tune.gate import compare_comm

    base = _comm_report(rounds=30, bytes_up=3000.0)
    rep = compare_comm(
        base,
        _comm_report(rounds=30, bytes_up=3000.0, criterion_ok=False),
    )
    assert not rep["ok"] and rep["criterion_regressed"]
    rep2 = compare_comm(
        base,
        _comm_report(rounds=30, bytes_up=3000.0, acc_ratio=0.2),
        threshold=0.5,
    )
    assert not rep2["ok"]


def test_gate_cli_exit_codes(tmp_path):
    from repro.tune.gate import run_gate

    base_p = str(tmp_path / "base.json")
    good_p = str(tmp_path / "good.json")
    bad_p = str(tmp_path / "bad.json")
    json.dump(_engine_report({"event": 100.0}), open(base_p, "w"))
    json.dump(_engine_report({"event": 95.0}), open(good_p, "w"))
    json.dump(_engine_report({"event": 10.0}), open(bad_p, "w"))

    report_p = str(tmp_path / "rep.json")
    assert run_gate("engine", base_p, good_p, report_path=report_p) == 0
    assert json.load(open(report_p))["ok"]
    assert run_gate("engine", base_p, bad_p) == 1
    assert run_gate("engine", base_p, bad_p, warn_only=True) == 0
    assert run_gate("engine", base_p, str(tmp_path / "missing.json")) == 2
    assert run_gate("nope", base_p, good_p) == 2


def test_benchmarks_cli_rejects_unknown_only():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--only", "bogus"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert proc.returncode != 0
    assert "bogus" in proc.stderr
    assert "engine" in proc.stderr  # actionable: lists the choices


# ---------------------------------------------------------------------------
# the "auto" backend
# ---------------------------------------------------------------------------


def _toy_problem(seed=0):
    rng = np.random.RandomState(seed)
    data = {
        "x": rng.randn(512, 4).astype(np.float32),
        "y": rng.randint(0, 3, 512).astype(np.int32),
    }
    params = {
        "w": jnp.zeros((4, 3), jnp.float32),
        "b": jnp.zeros((3,), jnp.float32),
    }

    def loss_fn(p, batch):
        logits = batch["x"] @ p["w"] + p["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(
            jnp.take_along_axis(
                lp, batch["y"][:, None].astype(jnp.int32), -1
            )
        )

    return data, params, loss_fn


def _flow_algorithms():
    from repro.fed.algorithms import available_algorithms, get_algorithm

    return [
        a for a in available_algorithms()
        if get_algorithm(a).has_flow_dynamics
    ]


@pytest.mark.parametrize("n", [10, 100, 1000])
def test_resolve_auto_every_flow_algorithm(n):
    from repro.fed import FedSimConfig
    from repro.fed.algorithms import make_algorithm
    from repro.sim.engine import BACKENDS
    from repro.tune.autotune import candidate_backends, resolve_auto

    data, params, loss_fn = _toy_problem()
    algs = _flow_algorithms()
    assert algs, "no flow-capable algorithms registered?"
    for name in algs:
        cfg = FedSimConfig(
            algorithm=name, n_clients=n, participation=0.1,
            backend="auto", batch_size=4, steps_per_epoch=1,
            epochs_fixed=1,
        )
        alg = make_algorithm(cfg)
        new_cfg, dec = resolve_auto(cfg, alg, loss_fn, params, data)
        assert new_cfg.backend in BACKENDS
        assert dec.chosen == new_cfg.backend
        assert set(dec.scores) == set(candidate_backends(alg))
        assert all(s > 0 for s in dec.scores.values())
        assert dec.chosen == min(dec.scores, key=dec.scores.get)
        assert dec.method in ("hlo", "measured")
        assert "client_cohort" in dec.terms and "consensus" in dec.terms
        assert "flight_integrate" in dec.terms


def test_resolve_auto_averaging_family_skips_event():
    from repro.fed import FedSimConfig
    from repro.fed.algorithms import make_algorithm
    from repro.tune.autotune import resolve_auto

    data, params, loss_fn = _toy_problem()
    cfg = FedSimConfig(
        algorithm="fedavg", n_clients=10, participation=0.5,
        backend="auto", batch_size=4, steps_per_epoch=1, epochs_fixed=1,
    )
    alg = make_algorithm(cfg)
    new_cfg, dec = resolve_auto(cfg, alg, loss_fn, params, data)
    assert "event" not in dec.scores
    assert new_cfg.backend != "event"
    assert "batch_agg" in dec.terms


def test_fedsim_auto_end_to_end_with_runlog(tmp_path):
    from repro.fed import FedSim, FedSimConfig, iid_partition
    from repro.obs import validate_jsonl

    data, params, loss_fn = _toy_problem()
    parts = iid_partition(len(data["y"]), 10, seed=0)
    log = str(tmp_path / "auto.jsonl")
    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=10, participation=0.3,
        rounds=2, backend="auto", batch_size=4, steps_per_epoch=1,
        epochs_fixed=1, eval_every=1 << 30, log_jsonl=log,
    )
    sim = FedSim(loss_fn, params, data, parts, cfg)
    assert sim.cfg.backend != "auto"
    assert sim.tune_decision is not None
    hist = sim.run(2)
    assert len(hist.loss) == 2
    recs = validate_jsonl(log)
    header = recs[0]
    assert header["kind"] == "run"
    assert header["backend"] == sim.cfg.backend
    tune = header["autotune"]
    assert tune["chosen"] == sim.cfg.backend
    assert set(tune["scores"]) >= {"sequential", "vectorized", "sharded"}
    assert tune["calibration"]["dispatch_s"] > 0
    # predicted-vs-measured audit trail: either the committed bench has no
    # matching row (recorded as null) or agreement + gap are recorded
    if tune["bench_reference"] is not None:
        assert "agrees" in tune["bench_reference"]
        assert "fastest_measured" in tune["bench_reference"]


def test_get_backend_rejects_unresolved_auto():
    from repro.fed import FedSimConfig
    from repro.sim.engine import get_backend

    with pytest.raises(ValueError, match="resolve_auto"):
        get_backend(FedSimConfig(backend="auto"))


def test_bench_reference_agreement_on_committed_baseline():
    """At the committed bench sizes the decision record must either agree
    with the empirically fastest backend or carry the gap audit trail."""
    from repro.tune.autotune import _bench_reference

    bench_path = os.path.join(REPO, "BENCH_engine.json")
    if not os.path.exists(bench_path):
        pytest.skip("no committed BENCH_engine.json")
    scores = {
        "sequential": 1.0, "vectorized": 0.5, "event": 0.1, "sharded": 0.2,
    }
    ref = _bench_reference("fedecado", 10, "event", scores)
    assert ref is not None
    assert ref["fastest_measured"] == "event"
    assert ref["agrees"] is True
    assert ref["measured_rounds_per_sec"]["event"] > 0
    assert ref["chosen_gap_ratio"] is not None

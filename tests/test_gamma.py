"""Property tests (hypothesis) for the Γ operator — the two linearity
properties the Theorem-1 proof relies on, plus interpolation/extrapolation
correctness and the Lemma-1 monotonicity."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.gamma import gamma_leaf, gamma_stacked

import numpy as _np

floats = st.floats(-1e3, 1e3, allow_nan=False, width=32)
pos_floats = st.floats(
    float(_np.float32(0.001)), 1e3, allow_nan=False, width=32
)


@settings(max_examples=200, deadline=None)
@given(
    x1=floats, x2=floats, y1=floats, y2=floats,
    T=pos_floats, tau=st.floats(0.0, 2e3, allow_nan=False, width=32),
)
def test_gamma_additivity(x1, x2, y1, y2, T, tau):
    """Γ(y+z, τ) = Γ(y, τ) + Γ(z, τ) (up to fp32 cancellation, which scales
    with the extrapolation factor τ/T)."""
    a = gamma_leaf(jnp.float32(x1 + y1), jnp.float32(x2 + y2), T, tau)
    b = gamma_leaf(jnp.float32(x1), jnp.float32(x2), T, tau) + gamma_leaf(
        jnp.float32(y1), jnp.float32(y2), T, tau
    )
    scale = (abs(x1) + abs(x2) + abs(y1) + abs(y2) + 1.0) * (1.0 + tau / T)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5 * scale)


@settings(max_examples=200, deadline=None)
@given(x1=floats, x2=floats, alpha=floats, T=pos_floats,
       tau=st.floats(0.0, 2e3, allow_nan=False, width=32))
def test_gamma_homogeneity(x1, x2, alpha, T, tau):
    """Γ(αy, τ) = αΓ(y, τ)."""
    a = gamma_leaf(jnp.float32(alpha * x1), jnp.float32(alpha * x2), T, tau)
    b = alpha * gamma_leaf(jnp.float32(x1), jnp.float32(x2), T, tau)
    scale = (abs(alpha) + 1.0) * (abs(x1) + abs(x2) + 1.0) * (1.0 + tau / T)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5 * scale)


@settings(max_examples=100, deadline=None)
@given(x1=floats, x2=floats, T=pos_floats)
def test_gamma_endpoints(x1, x2, T):
    np.testing.assert_allclose(gamma_leaf(jnp.float32(x1), jnp.float32(x2), T, 0.0), x1, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(gamma_leaf(jnp.float32(x1), jnp.float32(x2), T, T), x2, rtol=1e-4, atol=1e-2)


@settings(max_examples=100, deadline=None)
@given(x1=floats, x2=floats, T=pos_floats, frac=st.floats(0.0, 1.0, width=32))
def test_gamma_interpolation_bounds(x1, x2, T, frac):
    """For τ in [0, T], Γ lies between the endpoints."""
    tau = frac * T
    g = float(gamma_leaf(jnp.float32(x1), jnp.float32(x2), T, tau))
    lo, hi = min(x1, x2), max(x1, x2)
    assert lo - 1e-2 - 1e-4 * abs(lo) <= g <= hi + 1e-2 + 1e-4 * abs(hi)


@settings(max_examples=60, deadline=None)
@given(
    data=st.lists(
        st.tuples(floats, floats, pos_floats), min_size=2, max_size=5
    ),
    tau=st.floats(0.0, 100.0, width=32),
)
def test_gamma_monotonicity_lemma1(data, tau):
    """Lemma 1: X(T_i) > Y(T_i) for all i (and same at t0) => Γ(X) > Γ(Y)."""
    xp = jnp.asarray([d[0] for d in data], jnp.float32)
    T = jnp.asarray([d[2] for d in data], jnp.float32)
    gap = 1.0 + jnp.abs(xp)  # strictly positive separation
    xn = jnp.asarray([d[1] for d in data], jnp.float32)
    g_hi = gamma_stacked(
        {"w": (xp + gap)[:, None]}, {"w": (xn + gap)[:, None]}, T, tau
    )["w"]
    g_lo = gamma_stacked({"w": xp[:, None]}, {"w": xn[:, None]}, T, tau)["w"]
    assert bool(jnp.all(g_hi >= g_lo))


def test_gamma_stacked_matches_leaf():
    xp = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
    xn = xp * 2 + 1
    T = jnp.asarray([0.5, 1.0, 2.0])
    tau = 0.75
    out = gamma_stacked({"w": xp}, {"w": xn}, T, tau)["w"]
    for i in range(3):
        np.testing.assert_allclose(
            out[i], gamma_leaf(xp[i], xn[i], T[i], tau), rtol=1e-6
        )

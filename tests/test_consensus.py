"""Unit + property tests of the BE consensus core: the closed-form Schur
solve vs a dense arrowhead solve, LTE behaviour, Algorithm-1 backtracking,
contraction toward the fixed point, and frozen-client handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional test dependency; conftest.py installs a deterministic fallback
# when the real package is absent, so this only skips if both are missing
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ConsensusConfig,
    adaptive_be_step,
    be_step,
    init_server_state,
    lte,
    server_round,
    set_gains,
)
from repro.core.flow import broadcast_clients
from repro.core.gamma import gamma_stacked


def _dense_arrowhead_solve(x_c, I, J, gamma, g_inv, S_frozen, dt, L):
    """Reference: assemble and solve the (A+1)x(A+1) arrowhead system of
    eq. 28 (stable orientation) per scalar parameter element."""
    A = I.shape[0]
    r = dt / L
    M = np.zeros((A + 1, A + 1))
    rhs = np.zeros(A + 1)
    for i in range(A):
        M[i, i] = 1.0 + r * g_inv[i]
        M[i, A] = r
        rhs[i] = I[i] + r * (gamma[i] + J[i] * g_inv[i])
    M[A, :A] = -dt
    M[A, A] = 1.0
    rhs[A] = x_c + dt * S_frozen
    sol = np.linalg.solve(M, rhs)
    return sol[A], sol[:A]


@settings(max_examples=100, deadline=None)
@given(
    A=st.integers(1, 6),
    dt=st.floats(float(np.float32(1e-4)), 1.0, width=32),
    L=st.floats(float(np.float32(0.1)), 10.0, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_schur_solve_matches_dense(A, dt, L, seed):
    rng = np.random.RandomState(seed)
    x_c = {"w": jnp.float32(rng.randn())}
    I = rng.randn(A).astype(np.float32)
    J = rng.randn(A).astype(np.float32)
    gam = rng.randn(A).astype(np.float32)
    g_inv = rng.uniform(0.01, 1.0, A).astype(np.float32)
    Sf = np.float32(rng.randn() * 0.1)

    xc_new, I_new = be_step(
        x_c,
        {"w": jnp.asarray(I)[:, None].squeeze(-1)},
        {"w": jnp.asarray(J)},
        {"w": jnp.asarray(gam)},
        jnp.asarray(g_inv),
        {"w": jnp.asarray(Sf)},
        jnp.float32(dt),
        float(L),
    )
    xc_ref, I_ref = _dense_arrowhead_solve(
        float(x_c["w"]), I, J, gam, g_inv, float(Sf), dt, L
    )
    np.testing.assert_allclose(float(xc_new["w"]), xc_ref, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(I_new["w"]), I_ref, rtol=2e-4, atol=1e-5)


def test_fixed_point_is_stationary():
    """At x_i = x_c, I = J, Σ I = 0 and Γ constant, the BE step is a no-op."""
    A, D = 3, 4
    x_c = {"w": jnp.ones((D,))}
    I = jnp.stack([jnp.full((D,), 1.0), jnp.full((D,), -0.5), jnp.full((D,), -0.5)])
    gam = jnp.broadcast_to(x_c["w"], (A, D))  # clients sit at the central state
    g_inv = jnp.full((A,), 0.1)
    Sf = {"w": jnp.zeros((D,))}
    xc_new, I_new = be_step(
        x_c, {"w": I}, {"w": I}, {"w": gam}, g_inv, Sf, jnp.float32(0.05), 1.0
    )
    np.testing.assert_allclose(xc_new["w"], x_c["w"], rtol=1e-6)
    np.testing.assert_allclose(I_new["w"], I, rtol=1e-5, atol=1e-6)


def test_lte_zero_at_fixed_point():
    A, D = 2, 3
    x_c = {"w": jnp.ones((D,))}
    I = {"w": jnp.stack([jnp.full((D,), 0.3), jnp.full((D,), -0.3)])}
    gam = {"w": jnp.broadcast_to(x_c["w"], (A, D))}
    g_inv = jnp.full((A,), 0.1)
    eps = lte(x_c, I, x_c, I, I, gam, gam, g_inv, jnp.float32(0.1), 1.0)
    assert float(eps) < 1e-7


def test_adaptive_step_backtracks_to_tolerance():
    """A huge initial dt must be backtracked until max|ε| <= δ."""
    rng = np.random.RandomState(0)
    A, D = 4, 8
    x_c = {"w": jnp.zeros((D,))}
    x_new = {"w": jnp.asarray(rng.randn(A, D), jnp.float32)}
    x_prev = broadcast_clients(x_c, A)
    I = {"w": jnp.asarray(rng.randn(A, D) * 0.1, jnp.float32)}
    T = jnp.asarray(rng.uniform(0.01, 0.1, A), jnp.float32)
    g_inv = jnp.asarray(rng.uniform(0.01, 0.3, A), jnp.float32)
    Sf = {"w": jnp.zeros((D,))}
    ccfg = ConsensusConfig(delta=1e-4, max_backtracks=16)
    res = adaptive_be_step(
        x_c, I, I, x_prev, x_new, T, g_inv, Sf,
        jnp.float32(0.0), jnp.float32(100.0), ccfg,
    )
    assert float(res.eps) <= ccfg.delta * 1.0001
    assert int(res.n_backtracks) >= 1
    assert float(res.dt_used) < 100.0


def test_quadratic_convergence_partial_participation():
    """End-to-end: heterogeneous quadratic clients converge to the weighted
    optimum under 40% participation (the paper's core claim, miniature)."""
    n, dim, A = 10, 4, 4
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (n,), minval=0.5, maxval=2.0)
    c = jax.random.normal(jax.random.PRNGKey(1), (n, dim))
    p = jnp.ones((n,)) / n
    xstar = jnp.sum(p[:, None] * a[:, None] * c, 0) / jnp.sum(p * a)

    ccfg = ConsensusConfig(L=1.0, delta=1e-3, dt_init=0.1, max_substeps=32)
    state = init_server_state({"w": jnp.zeros((dim,))}, n)
    state = set_gains(state, 1.0 / (1.0 / 0.05 + p * a))
    rng = np.random.RandomState(0)
    round_fn = jax.jit(lambda s, x, T, i: server_round(s, x, T, i, ccfg))
    for _ in range(150):
        idx = np.sort(rng.choice(n, A, replace=False))
        lr = rng.uniform(1e-2, 5e-2, A)
        ep = rng.randint(2, 8, A)
        xs, Ts = [], []
        for j in range(A):
            i = int(idx[j])
            x = state.x_c["w"]
            I = state.I["w"][i]
            for _e in range(int(ep[j])):
                x = x - lr[j] * (p[i] * a[i] * (x - c[i]) + I)
            xs.append(x)
            Ts.append(lr[j] * ep[j])
        state, _ = round_fn(
            state, {"w": jnp.stack(xs)}, jnp.asarray(Ts, jnp.float32),
            jnp.asarray(idx, jnp.int32),
        )
    err = float(jnp.linalg.norm(state.x_c["w"] - xstar))
    err0 = float(jnp.linalg.norm(xstar))
    assert err < 0.1 * err0, (err, err0)


def test_frozen_clients_contribute_constant_flow():
    """Inactive clients' flow variables enter ẋ_c but stay frozen."""
    n, D = 5, 3
    state = init_server_state({"w": jnp.zeros((D,))}, n)
    # seed nonzero flows for clients 3, 4 (they stay inactive)
    I0 = state.I["w"].at[3].set(1.0).at[4].set(-0.25)
    state = state._replace(I=({"w": I0}))
    idx = jnp.asarray([0, 1], jnp.int32)
    x_new = {"w": jnp.zeros((2, D))}
    T = jnp.asarray([0.05, 0.05])
    ccfg = ConsensusConfig(max_substeps=4)
    new_state, _ = server_round(state, x_new, T, idx, ccfg)
    # frozen rows unchanged
    np.testing.assert_allclose(new_state.I["w"][3], I0[3], rtol=1e-6)
    np.testing.assert_allclose(new_state.I["w"][4], I0[4], rtol=1e-6)
    # their net positive flow pushed x_c up (ẋ_c = ΣI > 0)
    assert float(jnp.mean(new_state.x_c["w"])) > 0


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_contraction_property(seed):
    """Theorem 1 (empirical): two different central states contract toward
    each other under the SAME fixed-Δt BE consensus step (Δt small enough
    that Γ interpolates, not extrapolates)."""
    rng = np.random.RandomState(seed)
    A, D = 3, 5
    x_new = {"w": jnp.asarray(rng.randn(A, D), jnp.float32)}
    T = jnp.asarray(rng.uniform(0.1, 0.2, A), jnp.float32)
    g_inv = jnp.asarray(rng.uniform(0.05, 0.2, A), jnp.float32)
    Sf = {"w": jnp.zeros((D,))}
    dt = jnp.float32(0.04)  # < min(T): interpolation regime
    tau = jnp.float32(0.0)

    def one_step(xc_val):
        x_c = {"w": jnp.asarray(xc_val, jnp.float32)}
        I = {"w": jnp.zeros((A, D), jnp.float32)}
        gam = gamma_stacked(broadcast_clients(x_c, A), x_new, T, tau + dt)
        xc_n, _ = be_step(x_c, I, I, gam, g_inv, Sf, dt, 1.0)
        return np.asarray(xc_n["w"])

    x0a = rng.randn(D)
    x0b = rng.randn(D) + 1.0
    xa = one_step(x0a)
    xb = one_step(x0b)
    assert np.linalg.norm(xa - xb) <= np.linalg.norm(x0a - x0b) + 1e-6

"""Property-based backend-equivalence harness (DESIGN.md §5).

FedECADO's multi-rate integration is only reproduced faithfully if every
scheduler/backend slicing preserves the coupled flow's trajectory — and the
bugs hide in exactly the corners single-seed smoke tests miss: ragged
partitions (|part| < batch_size), partial participation, heterogeneous
e_i/lr_i, and uneven client→device padding. This suite fuzzes those corners
with hypothesis (or the deterministic fallback in tests/_hypothesis_fallback
when hypothesis isn't installed — only the API subset the fallback covers is
used here): on the same seed, the vectorized and sharded backends must
reproduce the sequential oracle's histories and final parameters at
rtol ≈ 1e-6 for every client kind. Bitwise equality is NOT expected: vmap
may re-associate the minibatch loss mean and psum re-associates the
sharded Σ_a reductions.

The algorithm axis is enumerated from the fed/algorithms plugin registry —
both in the fuzz sampling and in a deterministic per-algorithm sweep — so
any newly registered plugin (FedADMM, a user's algorithm) is equivalence-
checked automatically, with zero edits here.

A second group of properties pins the ``StackedPlan`` densification
(engine.py::stack_plans): padding semantics, plan-order preservation, and
the ragged-cohort refusal (including the uneven-cohort refusal that
availability-trace scenarios rely on).

A third group extends the equivalence guarantee to the scenario subsystem
(repro/scenarios, DESIGN.md §7): availability traces, feature shift,
device profiles, mid-round dropout and partition drift all act through the
shared host-side plan draw, so sequential == vectorized == sharded must
keep holding at rtol 1e-6 under every scenario axis.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ConsensusConfig
from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition
from repro.fed.algorithms import available_algorithms, get_algorithm
from repro.sim import CohortPlan, stack_plans

ALGS = available_algorithms()
FLOW_ALGS = [a for a in ALGS if get_algorithm(a).has_flow_dynamics]

_PROBLEM = None


def _problem():
    """One shared tiny non-IID problem (module-level, not a pytest fixture:
    real hypothesis forbids function-scoped fixtures under @given). Dirichlet
    alpha small enough that some partitions are < the larger fuzzed batch
    size, exercising the ragged grouping / sharded fallback path."""
    global _PROBLEM
    if _PROBLEM is None:
        data = make_classification(384, dim=6, n_classes=3, seed=11)
        parts = dirichlet_partition(data["y"], 6, alpha=0.4, seed=11)
        k1, k2 = jax.random.split(jax.random.PRNGKey(11))
        params0 = {
            "w0": jax.random.normal(k1, (6, 8)) / 3.0,
            "b0": jnp.zeros((8,)),
            "w1": jax.random.normal(k2, (8, 3)) / np.sqrt(8),
            "b1": jnp.zeros((3,)),
        }

        def loss_fn(p, batch):
            h = jnp.tanh(batch["x"] @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]
            lp = jax.nn.log_softmax(h)
            return -jnp.mean(
                jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1)
            )

        _PROBLEM = (data, parts, params0, loss_fn)
    return _PROBLEM


# ---------------------------------------------------------------------------
# sequential == vectorized == sharded on fuzzed cohort structure
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    alg=st.sampled_from(ALGS),
    participation=st.floats(min_value=0.25, max_value=1.0),
    batch_size=st.sampled_from([4, 16]),      # 16 > smallest partition -> ragged
    steps_per_epoch=st.integers(min_value=1, max_value=2),
    epochs_max=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=3),
    pad_multiple=st.sampled_from([0, 3, 4]),  # 0 -> natural device padding
)
def test_backends_match_sequential_oracle(
    alg, participation, batch_size, steps_per_epoch, epochs_max, seed, pad_multiple
):
    data, parts, params0, loss_fn = _problem()
    runs = {}
    for backend in ("sequential", "vectorized", "sharded"):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=len(parts), participation=participation,
            rounds=2, batch_size=batch_size, steps_per_epoch=steps_per_epoch,
            hetero=HeteroConfig(1e-3, 1e-2, 1, epochs_max), seed=100 + seed,
            backend=backend, consensus=ConsensusConfig(max_substeps=6),
            sharded_pad_multiple=(pad_multiple or None),
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        runs[backend] = (hist.loss, sim.current_params())

    ref_loss, ref_params = runs["sequential"]
    for backend in ("vectorized", "sharded"):
        loss, params = runs[backend]
        np.testing.assert_allclose(
            loss, ref_loss, rtol=1e-6, atol=1e-7,
            err_msg=f"{backend} history diverged from sequential ({alg})",
        )
        for a, b in zip(
            jax.tree.leaves(ref_params), jax.tree.leaves(params), strict=True
        ):
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), rtol=1e-6, atol=2e-7,
                err_msg=f"{backend} params diverged from sequential ({alg})",
            )


@pytest.mark.parametrize("alg", ALGS)
def test_every_registered_algorithm_matches_oracle(alg):
    """Deterministic sweep over the WHOLE registry (the fuzz above samples
    the algorithm axis; this guarantees each registered plugin — including
    ones added after this test was written — gets at least one
    ragged+uneven-padding equivalence check per run)."""
    data, parts, params0, loss_fn = _problem()
    runs = {}
    for backend in ("sequential", "vectorized", "sharded"):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=len(parts), participation=0.5,
            rounds=2, batch_size=16, steps_per_epoch=2,   # bs 16 -> ragged
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), seed=77,
            backend=backend, consensus=ConsensusConfig(max_substeps=6),
            sharded_pad_multiple=3,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        runs[backend] = (hist.loss, sim.current_params())

    ref_loss, ref_params = runs["sequential"]
    for backend in ("vectorized", "sharded"):
        loss, params = runs[backend]
        np.testing.assert_allclose(
            loss, ref_loss, rtol=1e-6, atol=1e-7,
            err_msg=f"{backend} history diverged from sequential ({alg})",
        )
        for a, b in zip(
            jax.tree.leaves(ref_params), jax.tree.leaves(params), strict=True
        ):
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), rtol=1e-6, atol=2e-7,
                err_msg=f"{backend} params diverged from sequential ({alg})",
            )


# ---------------------------------------------------------------------------
# event backend: deterministic equivalence pin at the synchronous setting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", FLOW_ALGS)
@pytest.mark.parametrize("mode", ["dense", "sharded"])
@pytest.mark.parametrize("batch_size", [4, 16])
def test_event_backend_matches_oracle_at_full_horizon(alg, mode, batch_size):
    """At ``horizon_quantile=1.0, max_waves=1`` every flight arrives
    in-round and the flight-table integrator is exactly the synchronous
    Algorithm-2 round, so the event backend must reproduce the sequential
    oracle at rtol 1e-5 — for every flow-capable registered algorithm
    (future flow plugins are auto-checked via the registry), in both the
    dense and the sharded (mesh-sharded flight table, psum wave solves)
    event modes. ``batch_size=4`` keeps the plans stackable and pins the
    jit-resident StackedPlan segment path; ``batch_size=16`` makes some
    partitions ragged and pins the grouped-fallback path on the same
    numbers."""
    data, parts, params0, loss_fn = _problem()
    runs = {}
    for backend, kw in (
        ("sequential", {}),
        ("event", {"event_horizon": 1.0, "event_max_waves": 1,
                   "event_sharded": mode == "sharded",
                   "sharded_pad_multiple": 3 if mode == "sharded" else None}),
    ):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=len(parts), participation=0.5,
            rounds=3, batch_size=batch_size, steps_per_epoch=2,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), seed=77,
            backend=backend, consensus=ConsensusConfig(max_substeps=6), **kw,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        runs[backend] = (hist.loss, sim.current_params())

    ref_loss, ref_params = runs["sequential"]
    loss, params = runs["event"]
    np.testing.assert_allclose(
        loss, ref_loss, rtol=1e-5, atol=1e-6,
        err_msg=f"event[{mode}] history diverged from sequential ({alg})",
    )
    for a, b in zip(
        jax.tree.leaves(ref_params), jax.tree.leaves(params), strict=True
    ):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6,
            err_msg=f"event[{mode}] params diverged from sequential ({alg})",
        )


@pytest.mark.parametrize("alg", FLOW_ALGS)
@pytest.mark.parametrize("mode", ["dense", "sharded"])
def test_buffered_event_backend_matches_oracle_at_cohort_buffer(alg, mode):
    """Equivalence pin for the fully-asynchronous buffered server
    (DESIGN.md §10): with buffer size K = cohort size, zero staleness
    damping and a full horizon, every round's buffer fills and drains
    in-round — the K-th order statistic of the queued windows equals the
    q = 1.0 quantile horizon — so buffered mode must reproduce the
    sequential oracle at rtol 1e-5 for every flow-capable registered
    algorithm, dense and sharded."""
    data, parts, params0, loss_fn = _problem()
    cohort = max(1, round(0.5 * len(parts)))
    runs = {}
    for backend, kw in (
        ("sequential", {}),
        ("event", {"event_horizon": 1.0, "event_max_waves": 1,
                   "event_buffered": True, "event_buffer_size": cohort,
                   "event_stale_gamma": 0.0,
                   "event_sharded": mode == "sharded",
                   "sharded_pad_multiple": 3 if mode == "sharded" else None}),
    ):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=len(parts), participation=0.5,
            rounds=3, batch_size=4, steps_per_epoch=2,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), seed=77,
            backend=backend, consensus=ConsensusConfig(max_substeps=6), **kw,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        runs[backend] = (hist.loss, sim.current_params())

    ref_loss, ref_params = runs["sequential"]
    loss, params = runs["event"]
    np.testing.assert_allclose(
        loss, ref_loss, rtol=1e-5, atol=1e-6,
        err_msg=f"buffered[{mode}] history diverged from sequential ({alg})",
    )
    for a, b in zip(
        jax.tree.leaves(ref_params), jax.tree.leaves(params), strict=True
    ):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-5, atol=1e-6,
            err_msg=f"buffered[{mode}] params diverged from sequential ({alg})",
        )


# ---------------------------------------------------------------------------
# telemetry equivalence (repro/obs shared schema, DESIGN.md §9)
# ---------------------------------------------------------------------------

# the integer counters of the shared record schema: these are exact device
# counts (never padded approximations), so equivalence is == not allclose
_COUNTER_FIELDS = (
    "cohort", "dropped", "substeps", "backtracks", "waves", "arrived", "stale"
)


def test_telemetry_counters_identical_across_backends():
    """Every backend emits the same shared-schema telemetry, and the jit-safe
    counters are exact: at the pinned equivalence settings the sequential,
    vectorized and sharded backends must report identical integer counters
    round for round (solver substeps, LTE backtracks, cohort sizes) and
    matching dt extrema at the usual reassociation tolerance — plus
    identical per-client participation counts."""
    data, parts, params0, loss_fn = _problem()
    tels, pcounts = {}, {}
    for backend in ("sequential", "vectorized", "sharded"):
        cfg = FedSimConfig(
            algorithm="fedecado", n_clients=len(parts), participation=0.5,
            rounds=3, batch_size=4, steps_per_epoch=2,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), seed=77,
            backend=backend, consensus=ConsensusConfig(max_substeps=6),
            sharded_pad_multiple=3,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        tels[backend] = hist.telemetry
        pcounts[backend] = np.asarray(hist.participation)

    ref = tels["sequential"]
    assert len(ref) == 3
    assert all(r["substeps"] > 0 for r in ref)    # non-trivial solver work
    for backend in ("vectorized", "sharded"):
        got = tels[backend]
        assert len(got) == len(ref)
        for r_ref, r_got in zip(ref, got):
            assert r_got["round"] == r_ref["round"]
            for f in _COUNTER_FIELDS:
                assert r_got[f] == r_ref[f], (
                    f"{backend} round {r_ref['round']}: counter {f} "
                    f"{r_got[f]} != sequential {r_ref[f]}"
                )
            for f in ("loss", "dt_min", "dt_max", "dt_mean", "tau_end"):
                np.testing.assert_allclose(
                    r_got[f], r_ref[f], rtol=1e-5, atol=1e-7,
                    err_msg=f"{backend} round {r_ref['round']}: {f}",
                )
        np.testing.assert_array_equal(
            pcounts[backend], pcounts["sequential"],
            err_msg=f"{backend} participation counts diverged",
        )


def test_event_telemetry_matches_sequential_at_full_horizon():
    """At ``horizon_quantile=1.0, max_waves=1`` every dispatched flight is
    absorbed in-round, so the event backend's async counters must collapse
    to the synchronous reading: arrived == cohort, one wave, no stragglers
    (stale == 0, empty staleness histogram), no busy drops — with the
    telemetry loss matching the sequential oracle round for round and
    device-exact participation equal to the plan-derived counts."""
    data, parts, params0, loss_fn = _problem()
    tels, pcounts = {}, {}
    for backend, kw in (
        ("sequential", {}),
        ("event", {"event_horizon": 1.0, "event_max_waves": 1}),
    ):
        cfg = FedSimConfig(
            algorithm="fedecado", n_clients=len(parts), participation=0.5,
            rounds=3, batch_size=4, steps_per_epoch=2,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 3), seed=77,
            backend=backend, consensus=ConsensusConfig(max_substeps=6), **kw,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg)
        hist = sim.run()
        tels[backend] = hist.telemetry
        pcounts[backend] = np.asarray(hist.participation)

    for r_seq, r_ev in zip(tels["sequential"], tels["event"]):
        assert r_ev["round"] == r_seq["round"]
        assert r_ev["cohort"] == r_seq["cohort"]
        assert r_ev["arrived"] == r_ev["cohort"]
        assert r_ev["waves"] == 1
        assert r_ev["stale"] == 0 and r_ev["dropped"] == 0
        assert sum(r_ev["stale_hist"]) == 0
        np.testing.assert_allclose(
            r_ev["loss"], r_seq["loss"], rtol=1e-5, atol=1e-6,
            err_msg=f"event telemetry loss, round {r_seq['round']}",
        )
    np.testing.assert_array_equal(pcounts["event"], pcounts["sequential"])


# ---------------------------------------------------------------------------
# StackedPlan densification properties
# ---------------------------------------------------------------------------


def _draw_plans(rng, R, A, n_clients, bs, max_steps, ragged_client=None):
    plans = []
    for r in range(R):
        idx = np.sort(rng.choice(n_clients, A, replace=False))
        n_steps = rng.randint(1, max_steps + 1, A).astype(np.int64)
        lrs = rng.uniform(1e-3, 1e-2, A).astype(np.float32)
        batch_idx = [
            rng.randint(
                0, 64, (int(ns), bs - 1 if j == ragged_client else bs)
            ).astype(np.int64)
            for j, ns in enumerate(n_steps)
        ]
        plans.append(CohortPlan(
            rnd=r, idx=idx, lrs=lrs, epochs=n_steps // 1, n_steps=n_steps,
            batch_idx=batch_idx,
        ))
    return plans


@settings(max_examples=25, deadline=None)
@given(
    A=st.integers(min_value=1, max_value=7),
    R=st.integers(min_value=1, max_value=3),
    bs=st.integers(min_value=2, max_value=5),
    max_steps=st.integers(min_value=1, max_value=6),
    unit=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_stack_plans_padding_semantics(A, R, bs, max_steps, unit, seed):
    rng = np.random.RandomState(seed)
    n_clients = 9
    plans = _draw_plans(rng, R, A, n_clients, bs, max_steps)
    A_pad = -(-A // unit) * unit
    S_pad = int(max(int(p.n_steps.max()) for p in plans)) + rng.randint(0, 3)
    sp = stack_plans(plans, n_clients, A_pad, S_pad)

    assert sp is not None
    assert sp.idx.shape == (R, A_pad)
    assert sp.sel.shape == (R, A_pad, S_pad, bs)
    for r in range(R):
        # mask marks exactly the real cohort, in plan order
        assert sp.mask[r].sum() == A
        np.testing.assert_array_equal(sp.idx[r, :A], plans[r].idx)
        np.testing.assert_array_equal(sp.scatter_idx[r, :A], plans[r].idx)
        # cohort padding: gather ids stay in-bounds, scatter ids are dropped
        # out of bounds, windows are zero (excluded from the T_max horizon)
        assert (sp.idx[r, A:] == 0).all()
        assert (sp.scatter_idx[r, A:] == n_clients).all()
        assert (sp.n_steps[r, A:] == 0).all()
        assert (sp.Ts[r, A:] == 0).all()
        for j in range(A):
            ns = int(plans[r].n_steps[j])
            np.testing.assert_array_equal(
                sp.sel[r, j, :ns], plans[r].batch_idx[j]
            )
            # step padding repeats the client's last real minibatch row
            np.testing.assert_array_equal(
                sp.sel[r, j, ns:],
                np.broadcast_to(
                    plans[r].batch_idx[j][-1], (S_pad - ns, bs)
                ),
            )
        np.testing.assert_allclose(
            sp.Ts[r, :A], plans[r].lrs * plans[r].n_steps, rtol=1e-6
        )


# ---------------------------------------------------------------------------
# scenario-axis equivalence (repro/scenarios)
# ---------------------------------------------------------------------------


def _scenario_cases():
    """One case per scenario axis the plan draw can exercise: availability
    trace, covariate shift, device tiers + dropout, and drift (forced to
    fire inside the 4-round window)."""
    from repro.scenarios import get_scenario

    return [
        ("diurnal", get_scenario("diurnal")),                  # availability
        ("feature-shift", get_scenario("feature-shift")),      # covariate
        ("flaky-dropout", get_scenario("flaky-dropout")),      # tiers+dropout
        ("drift", dataclasses.replace(get_scenario("drift"), drift_every=2)),
    ]


@pytest.mark.parametrize("alg", ["fedecado", "fednova"])
@pytest.mark.parametrize(
    "case", _scenario_cases(), ids=[c[0] for c in _scenario_cases()]
)
def test_scenario_backends_match_sequential_oracle(case, alg):
    _, spec = case
    data, _, params0, loss_fn = _problem()
    runs = {}
    for backend in ("sequential", "vectorized", "sharded"):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=6, participation=0.6,
            rounds=4, batch_size=4, steps_per_epoch=1, seed=91,
            backend=backend, consensus=ConsensusConfig(max_substeps=6),
            sharded_pad_multiple=3, scenario=spec,
        )
        sim = FedSim(loss_fn, params0, data, None, cfg)
        hist = sim.run()
        runs[backend] = (hist.loss, sim.current_params())

    ref_loss, ref_params = runs["sequential"]
    for backend in ("vectorized", "sharded"):
        loss, params = runs[backend]
        np.testing.assert_allclose(
            loss, ref_loss, rtol=1e-6, atol=1e-7,
            err_msg=f"{backend} history diverged from sequential "
            f"({alg}, scenario {spec.name})",
        )
        for a, b in zip(
            jax.tree.leaves(ref_params), jax.tree.leaves(params), strict=True
        ):
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), rtol=1e-6, atol=2e-7,
                err_msg=f"{backend} params diverged from sequential "
                f"({alg}, scenario {spec.name})",
            )


@settings(max_examples=10, deadline=None)
@given(
    A=st.integers(min_value=2, max_value=6),
    bs=st.integers(min_value=2, max_value=5),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_stack_plans_refuses_ragged_cohorts(A, bs, seed):
    """Mixed per-client batch sizes cannot share one dense sel tensor
    without changing the minibatch-mean arithmetic — stack_plans must
    refuse so the backend takes the grouped fallback."""
    rng = np.random.RandomState(seed)
    plans = _draw_plans(
        rng, 1, A, 9, bs, 3, ragged_client=int(rng.randint(0, A))
    )
    assert stack_plans(plans, 9, A, 4) is None


def test_stack_plans_refuses_uneven_cohort_sizes():
    """Availability-trace scenarios admit fewer clients on sparse rounds;
    such segments cannot share one dense cohort axis and must fall back to
    per-round execution instead of asserting."""
    rng = np.random.RandomState(0)
    plans = _draw_plans(rng, 2, 4, 9, 3, 3)
    small = _draw_plans(rng, 1, 2, 9, 3, 3)
    assert stack_plans(plans + small, 9, 4, 4) is None


def test_stack_plans_allow_uneven_pads_with_sentinels():
    """The buffered event backend opts in to uneven cohorts (arrival-trace
    rounds have varying sizes): short rounds must pad with the §5.5
    sentinels (mask 0, zero steps/window, out-of-bounds scatter) so padded
    rows are arithmetic no-ops — while mixed per-client batch sizes still
    refuse even under allow_uneven."""
    rng = np.random.RandomState(0)
    plans = _draw_plans(rng, 2, 4, 9, 3, 3)
    small = _draw_plans(rng, 1, 2, 9, 3, 3)
    sp = stack_plans(plans + small, 9, 4, 4, allow_uneven=True)
    assert sp is not None
    assert sp.mask[2].sum() == 2
    np.testing.assert_array_equal(sp.idx[2, :2], small[0].idx)
    assert (sp.n_steps[2, 2:] == 0).all()
    assert (sp.Ts[2, 2:] == 0).all()
    assert (sp.scatter_idx[2, 2:] == 9).all()
    # full-size rounds are stacked exactly as in the even path
    for r in range(2):
        assert sp.mask[r].sum() == 4
        np.testing.assert_array_equal(sp.idx[r, :4], plans[r].idx)

    ragged = _draw_plans(rng, 1, 4, 9, 3, 3, ragged_client=1)
    assert stack_plans(plans + ragged, 9, 4, 4, allow_uneven=True) is None


# ---------------------------------------------------------------------------
# identity wire == no wire, bitwise, on every backend (DESIGN.md §11)
# ---------------------------------------------------------------------------


def _run_with_wire(alg, backend, compress, level=None, **kw):
    data, parts, params0, loss_fn = _problem()
    cfg = FedSimConfig(
        algorithm=alg, n_clients=len(parts), participation=0.5,
        rounds=2, batch_size=4, steps_per_epoch=2,
        hetero=HeteroConfig(1e-3, 1e-2, 1, 2), seed=55,
        backend=backend, consensus=ConsensusConfig(max_substeps=6),
        compress=compress, compress_level=level, **kw,
    )
    sim = FedSim(loss_fn, params0, data, parts, cfg)
    hist = sim.run()
    return hist, sim.current_params()


def _assert_bitwise(ref, got, msg):
    h1, p1 = ref
    h2, p2 = got
    assert h1.loss == h2.loss, msg
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)


@pytest.mark.parametrize("alg", ALGS)
@pytest.mark.parametrize("backend", ["sequential", "vectorized", "sharded"])
def test_identity_wire_is_bitwise_off(alg, backend):
    """``--compress identity`` must equal no ``--compress`` flag BITWISE on
    every registered algorithm × backend: the lossless short-circuit in
    ``CommSpec.compress_endpoints`` returns its inputs untouched before any
    arithmetic, so threading the comm hook through a backend cannot perturb
    the trajectory. Bytes accounting must be on in BOTH runs (the identity
    wire still counts fp32 payloads)."""
    ref = _run_with_wire(alg, backend, None)
    got = _run_with_wire(alg, backend, "identity")
    _assert_bitwise(ref, got, f"identity wire perturbed {backend}/{alg}")
    for hist, _ in (ref, got):
        s = hist.summary()
        assert s["bytes_up"] > 0 and s["bytes_down"] > 0
    assert ref[0].summary()["bytes_up"] == got[0].summary()["bytes_up"]


@pytest.mark.parametrize("alg", FLOW_ALGS)
@pytest.mark.parametrize("mode,kw", [
    ("dense", {"event_horizon": 1.0, "event_max_waves": 1}),
    ("sharded", {"event_horizon": 1.0, "event_max_waves": 1,
                 "event_sharded": True, "sharded_pad_multiple": 3}),
    ("buffered", {"event_horizon": 1.0, "event_buffered": True,
                  "event_buffer_size": 2, "event_stale_gamma": 0.0}),
])
def test_identity_wire_is_bitwise_off_event(alg, mode, kw):
    """Same identity==off bitwise pin on the event backend's three modes
    (dense flight table, mesh-sharded waves, buffered K-trigger)."""
    ref = _run_with_wire(alg, "event", None, **kw)
    got = _run_with_wire(alg, "event", "identity", **kw)
    _assert_bitwise(ref, got, f"identity wire perturbed event[{mode}]/{alg}")


@pytest.mark.parametrize("backend", ["sequential", "vectorized", "sharded"])
def test_lossy_wire_is_live_on_every_backend(backend):
    """Anti-dead-code witness for the comm hook: an int8 wire must (a)
    actually change the trajectory vs lossless and (b) report the smaller
    quantized uplink payload — on every backend. A refactor that silently
    drops the compress call would keep every identity pin green; this
    catches it."""
    ref = _run_with_wire("fednova", backend, None)
    got = _run_with_wire("fednova", backend, "int8")
    assert ref[0].loss != got[0].loss, f"int8 wire dead on {backend}"
    assert got[0].summary()["bytes_up"] < ref[0].summary()["bytes_up"] // 3

"""Per-kernel tests: shape/dtype sweeps asserting allclose against the
ref.py pure-jnp oracles (interpret=True executes the Pallas bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.consensus import consensus_call
from repro.kernels.gamma import gamma_call
from repro.kernels.hutchinson import hutchinson_call
from repro.kernels.ops import (
    fused_consensus_step,
    ravel_stacked,
    ravel_tree,
    unravel_stacked,
    unravel_tree,
)


def _mk(rng, A, D):
    return dict(
        x_c=jnp.asarray(rng.randn(D), jnp.float32),
        S_frozen=jnp.asarray(rng.randn(D) * 0.1, jnp.float32),
        I=jnp.asarray(rng.randn(A, D) * 0.1, jnp.float32),
        J=jnp.asarray(rng.randn(A, D) * 0.1, jnp.float32),
        # explicit (re-based) Γ anchors — the event scheduler's stale-flight
        # case; the synchronous round is the x_prev == broadcast x_c special
        # case, checked separately in test_fused_step_matches_core_reference
        x_prev=jnp.asarray(rng.randn(A, D), jnp.float32),
        x_new=jnp.asarray(rng.randn(A, D), jnp.float32),
        T=jnp.asarray(rng.uniform(0.01, 0.2, A), jnp.float32),
        g_inv=jnp.asarray(rng.uniform(0.01, 0.5, A), jnp.float32),
        mask=jnp.ones((A,), jnp.float32),
    )


@pytest.mark.parametrize("A", [1, 3, 8, 17])
@pytest.mark.parametrize("D,tile", [(1024, 1024), (4096, 1024), (2048, 512)])
def test_consensus_kernel_shape_sweep(A, D, tile):
    rng = np.random.RandomState(A * 1000 + D)
    m = _mk(rng, A, D)
    dt, tau, L = jnp.float32(0.05), jnp.float32(0.02), 1.0
    k = consensus_call(
        m["x_c"], m["S_frozen"], m["I"], m["J"], m["x_prev"], m["x_new"],
        m["T"], m["g_inv"], m["mask"], dt, tau, L,
        interpret=True, tile_d=tile,
    )
    r = ref.consensus_ref(
        m["x_c"], m["S_frozen"], m["I"], m["J"], m["x_prev"], m["x_new"],
        m["T"], m["g_inv"], m["mask"], dt, tau, L,
    )
    np.testing.assert_allclose(k[0], r[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(k[1], r[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(k[2], r[2], rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(k[3], r[3], rtol=1e-4, atol=1e-7)


def test_consensus_kernel_masked_rows_are_inert():
    """Padded (mask=0) client rows must not affect x_c or eps."""
    rng = np.random.RandomState(0)
    A, D = 4, 1024
    m = _mk(rng, A, D)
    dt, tau, L = jnp.float32(0.05), jnp.float32(0.02), 1.0
    full = consensus_call(
        m["x_c"], m["S_frozen"], m["I"], m["J"], m["x_prev"], m["x_new"],
        m["T"], m["g_inv"], m["mask"], dt, tau, L, interpret=True,
    )
    # add 2 garbage rows with mask 0
    pad = lambda t: jnp.concatenate([t, 99.0 * jnp.ones((2,) + t.shape[1:], t.dtype)])
    mask2 = jnp.concatenate([m["mask"], jnp.zeros((2,))])
    padded = consensus_call(
        m["x_c"], m["S_frozen"], pad(m["I"]), pad(m["J"]), pad(m["x_prev"]),
        pad(m["x_new"]), pad(m["T"]), pad(m["g_inv"]), mask2, dt, tau, L,
        interpret=True,
    )
    np.testing.assert_allclose(full[0], padded[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(full[1], padded[1][:A], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(full[2], padded[2], rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("A,D,tile", [(3, 1024, 1024), (7, 2048, 512)])
def test_anchor_rebase_kernel_vs_ref(A, D, tile):
    """The event scheduler's staleness hot loop: masked Γ anchor rebase
    (kernels/gamma.py::anchor_rebase_call) vs the jnp oracle; mask=0 rows
    must pass through bitwise untouched."""
    from repro.kernels.gamma import anchor_rebase_call

    rng = np.random.RandomState(A * 10 + 1)
    xp = jnp.asarray(rng.randn(A, D), jnp.float32)
    xn = jnp.asarray(rng.randn(A, D), jnp.float32)
    frac = jnp.asarray(rng.uniform(0.0, 1.5, A), jnp.float32)
    mask = jnp.asarray((rng.rand(A) > 0.4).astype(np.float32))
    k = anchor_rebase_call(xp, xn, frac, mask, interpret=True, tile_d=tile)
    r = ref.anchor_rebase_ref(xp, xn, frac, mask)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(k)[np.asarray(mask) == 0], np.asarray(xp)[np.asarray(mask) == 0]
    )


def test_anchor_rebase_op_kernel_matches_jnp_path():
    """The pytree entry (kernels/ops.py::anchor_rebase_op) agrees between
    the Pallas and plain-jnp paths on a ragged-leaf flight table."""
    from repro.kernels import anchor_rebase_op

    rng = np.random.RandomState(9)
    mk = lambda: {
        "w": jnp.asarray(rng.randn(5, 13, 7), jnp.float32),
        "b": jnp.asarray(rng.randn(5, 3), jnp.float32),
    }
    xp, xn = mk(), mk()
    frac = jnp.asarray(rng.uniform(0.0, 1.2, 5), jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0, 1.0], jnp.float32)
    a = anchor_rebase_op(xp, xn, frac, mask, use_kernel=True)
    b = anchor_rebase_op(xp, xn, frac, mask, use_kernel=False)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("A,D", [(2, 1024), (5, 3072)])
def test_gamma_kernel_vs_ref(A, D):
    rng = np.random.RandomState(1)
    xc = jnp.asarray(rng.randn(D), jnp.float32)
    xn = jnp.asarray(rng.randn(A, D), jnp.float32)
    T = jnp.asarray(rng.uniform(0.01, 0.2, A), jnp.float32)
    mask = jnp.ones((A,), jnp.float32)
    for tau in (0.0, 0.05, 0.5):
        k = gamma_call(xc, xn, T, jnp.float32(tau), mask, interpret=True)
        r = ref.gamma_ref(xc, xn, T, jnp.float32(tau), mask)
        np.testing.assert_allclose(k, r, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("A", [1, 4, 13])
@pytest.mark.parametrize("D,tile", [(1024, 1024), (2048, 512)])
def test_batch_agg_kernel_vs_ref(A, D, tile):
    from repro.kernels.batch_agg import batch_agg_call

    rng = np.random.RandomState(A + D)
    xc = jnp.asarray(rng.randn(D), jnp.float32)
    xn = jnp.asarray(rng.randn(A, D), jnp.float32)
    w = jnp.asarray(rng.uniform(0.0, 1.0, A), jnp.float32)
    mask = jnp.asarray((rng.rand(A) > 0.2).astype(np.float32))
    for scale in (1.0, 3.7):
        k = batch_agg_call(xc, xn, w, mask, jnp.float32(scale), interpret=True, tile_d=tile)
        r = ref.batch_agg_ref(xc, xn, w, mask, jnp.float32(scale))
        np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


def test_batched_aggregate_matches_fedavg():
    """The pytree wrapper (kernel and ref paths) reproduces the jnp
    fedavg aggregation baseline on a ragged-leaf model."""
    from repro.fed import fedavg_aggregate
    from repro.kernels import batched_aggregate

    rng = np.random.RandomState(3)
    x_c = {
        "w0": jnp.asarray(rng.randn(13, 7), jnp.float32),
        "b": jnp.asarray(rng.randn(5), jnp.float32),
    }
    x_new = jax.tree.map(lambda l: jnp.asarray(rng.randn(6, *l.shape), jnp.float32), x_c)
    p = jnp.asarray(rng.uniform(0.5, 2.0, 6), jnp.float32)
    expect = fedavg_aggregate(x_c, x_new, p)
    w = p / jnp.sum(p)
    for uk in (True, False):
        got = batched_aggregate(x_c, x_new, w, 1.0, use_kernel=uk)
        for a, b in zip(jax.tree.leaves(expect), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("D", [1024, 8192])
def test_hutchinson_kernel_vs_ref(D):
    rng = np.random.RandomState(2)
    v = jnp.asarray(rng.choice([-1.0, 1.0], D), jnp.float32)
    hv = jnp.asarray(rng.randn(D), jnp.float32)
    acc = jnp.asarray(rng.randn(D) * 0.1, jnp.float32)
    ka, kt = hutchinson_call(v, hv, acc, interpret=True)
    ra, rt = ref.hutchinson_ref(v, hv, acc)
    np.testing.assert_allclose(ka, ra, rtol=1e-6)
    np.testing.assert_allclose(jnp.sum(kt), rt, rtol=1e-5)


def test_ravel_roundtrip():
    rng = np.random.RandomState(3)
    tree = {
        "a": jnp.asarray(rng.randn(7, 5), jnp.float32),
        "b": {"c": jnp.asarray(rng.randn(13), jnp.float32)},
    }
    flat, meta = ravel_tree(tree)
    assert flat.shape[0] % 1024 == 0
    back = unravel_tree(flat, meta)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(x, y)

    stacked = jax.tree.map(lambda l: jnp.stack([l, l * 2, l * 3]), tree)
    flat2, meta2 = ravel_stacked(stacked)
    back2 = unravel_stacked(flat2, meta2)
    for x, y in zip(jax.tree.leaves(stacked), jax.tree.leaves(back2)):
        np.testing.assert_allclose(x, y)


def test_fused_step_matches_core_reference():
    """ops.fused_consensus_step == core.be_step + core.lte on pytrees."""
    from repro.core.consensus import be_step, lte
    from repro.core.gamma import gamma_stacked

    rng = np.random.RandomState(4)
    tree = {"w": jnp.asarray(rng.randn(13, 7), jnp.float32),
            "b": jnp.asarray(rng.randn(5), jnp.float32)}
    A = 3
    stk = lambda t, s: jax.tree.map(
        lambda l: jnp.stack([l * (i + 1) * s for i in range(A)]), t
    )
    I_a, J_a, xn_a = stk(tree, 0.1), stk(tree, 0.07), stk(tree, 0.9)
    Sf = jax.tree.map(lambda l: l * 0.01, tree)
    T = jnp.asarray([0.05, 0.08, 0.02])
    gi = jnp.asarray([0.1, 0.05, 0.2])
    dt, tau = jnp.float32(0.03), jnp.float32(0.01)

    x_prev = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (A,) + l.shape), tree)
    xc_k, I_k, eps_k = fused_consensus_step(
        tree, Sf, I_a, J_a, x_prev, xn_a, T, gi, dt, tau, 1.0, use_kernel=True
    )
    g_new = gamma_stacked(x_prev, xn_a, T, tau + dt)
    g_old = gamma_stacked(x_prev, xn_a, T, tau)
    xc_r, I_r = be_step(tree, I_a, J_a, g_new, gi, Sf, dt, 1.0)
    eps_r = lte(tree, I_a, xc_r, I_r, J_a, g_old, g_new, gi, dt, 1.0)
    for a, b in zip(jax.tree.leaves(xc_k), jax.tree.leaves(xc_r)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(I_k), jax.tree.leaves(I_r)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(eps_k, eps_r, rtol=1e-4, atol=1e-7)


def test_fused_step_anchored_masked_matches_core():
    """The anchored-masked fused path (explicit stale-flight Γ anchors +
    activity mask — what lets the event backend keep use_kernels on) equals
    be_step + lte with the same mask."""
    from repro.core.consensus import be_step, lte
    from repro.core.gamma import gamma_stacked

    rng = np.random.RandomState(6)
    tree = {"w": jnp.asarray(rng.randn(13, 7), jnp.float32),
            "b": jnp.asarray(rng.randn(5), jnp.float32)}
    A = 4
    stk = lambda s: jax.tree.map(
        lambda l: jnp.stack([
            l * (i + 1) * s + jnp.asarray(rng.randn(*l.shape) * 0.05, jnp.float32)
            for i in range(A)
        ]), tree
    )
    I_a, J_a, xp_a, xn_a = stk(0.1), stk(0.07), stk(0.8), stk(0.9)
    Sf = jax.tree.map(lambda l: l * 0.01, tree)
    T = jnp.asarray([0.05, 0.08, 0.02, 0.04])
    gi = jnp.asarray([0.1, 0.05, 0.2, 0.15])
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    dt, tau = jnp.float32(0.03), jnp.float32(0.01)

    xc_k, I_k, eps_k = fused_consensus_step(
        tree, Sf, I_a, J_a, xp_a, xn_a, T, gi, dt, tau, 1.0,
        mask=mask, use_kernel=True,
    )
    g_new = gamma_stacked(xp_a, xn_a, T, tau + dt)
    g_old = gamma_stacked(xp_a, xn_a, T, tau)
    xc_r, I_r = be_step(tree, I_a, J_a, g_new, gi, Sf, dt, 1.0, mask=mask)
    eps_r = lte(tree, I_a, xc_r, I_r, J_a, g_old, g_new, gi, dt, 1.0, mask=mask)
    for a, b in zip(jax.tree.leaves(xc_k), jax.tree.leaves(xc_r)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(I_k), jax.tree.leaves(I_r)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(eps_k, eps_r, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("B,S,inner,N,tile", [
    (1, 32, 128, 16, 128), (2, 64, 256, 16, 128), (2, 96, 512, 8, 256),
])
def test_ssm_scan_kernel_vs_ref(B, S, inner, N, tile):
    """Pallas selective-scan (VMEM-resident state) vs the lax.scan oracle."""
    from repro.kernels.ssm_scan import ssm_scan_call
    from repro.kernels.ref import ssm_scan_ref

    rng = np.random.RandomState(B * 100 + S)
    dt = jnp.asarray(np.abs(rng.randn(B, S, inner)) * 0.05, jnp.float32)
    Bt = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Ct = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    u = jnp.asarray(rng.randn(B, S, inner), jnp.float32)
    a_log = jnp.asarray(
        np.log(np.tile(np.arange(1, N + 1, dtype=np.float32), (inner, 1)))
    )
    d = jnp.ones((inner,), jnp.float32)
    h0 = jnp.asarray(rng.randn(B, inner, N) * 0.1, jnp.float32)
    yk, hk = ssm_scan_call(dt, Bt, Ct, u, a_log, d, h0, interpret=True, tile_i=tile)
    yr, hr = ssm_scan_ref(dt, Bt, Ct, u, a_log, d, h0)
    np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# comm compression kernels (src/repro/comm/kernels, DESIGN.md §11)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("A", [1, 3, 8])
@pytest.mark.parametrize("D,tile", [(1024, 1024), (4096, 1024), (2048, 512)])
@pytest.mark.parametrize("q_max", [127.0, 7.0])
def test_stoch_quant_kernel_vs_ref(A, D, tile, q_max):
    from repro.comm.kernels import quant_scale, stoch_quant_call, stoch_quant_ref

    rng = np.random.RandomState(A * 31 + D)
    x = jnp.asarray(rng.randn(A, D), jnp.float32)
    u = jnp.asarray(rng.uniform(0.0, 1.0, (A, D)), jnp.float32)
    s = quant_scale(x, q_max)
    k = stoch_quant_call(x, u, s, q_max, interpret=True, tile_d=tile)
    r = stoch_quant_ref(x, u, s, q_max)
    np.testing.assert_allclose(np.asarray(k), r, rtol=1e-6, atol=1e-7)
    # the round-trip is inside one grid step of the per-row lattice
    step = np.asarray(s)[:, None] + 1e-7
    assert np.all(np.abs(np.asarray(k) - np.asarray(x)) <= step)


def test_stoch_quant_kernel_zero_rows_stay_zero():
    """All-zero rows have scale 0; the clamped-eps scale must send them
    through the round-trip bitwise unchanged (padded cohort rows rely on
    this: a zero delta compresses to a zero delta)."""
    from repro.comm.kernels import quant_scale, stoch_quant_call

    x = jnp.zeros((3, 1024), jnp.float32)
    u = jnp.full((3, 1024), 0.999, jnp.float32)
    out = stoch_quant_call(x, u, quant_scale(x, 127.0), 127.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("A", [1, 4])
@pytest.mark.parametrize("D,tile", [(1024, 1024), (2048, 512)])
@pytest.mark.parametrize("k", [1, 16, 200])
def test_topk_mask_kernel_vs_ref(A, D, tile, k):
    from repro.comm.kernels import topk_mask_call, topk_mask_ref, topk_threshold

    rng = np.random.RandomState(A * 7 + D + k)
    x = jnp.asarray(rng.randn(A, D), jnp.float32)
    thr = topk_threshold(x, k)
    got = topk_mask_call(x, thr, interpret=True, tile_d=tile)
    want = topk_mask_ref(np.asarray(x), np.asarray(thr))
    np.testing.assert_array_equal(np.asarray(got), want)
    assert np.all(np.sum(np.asarray(got) != 0.0, axis=-1) == k)


def test_topk_threshold_clamps_k():
    from repro.comm.kernels import topk_threshold

    x = jnp.asarray(np.random.RandomState(0).randn(2, 64), jnp.float32)
    # k beyond the width keeps everything; k < 1 keeps at least one
    lo = topk_threshold(x, 1000)
    np.testing.assert_allclose(
        np.asarray(lo), np.min(np.abs(np.asarray(x)), -1), rtol=1e-7
    )
    hi = topk_threshold(x, 0)
    np.testing.assert_allclose(
        np.asarray(hi), np.max(np.abs(np.asarray(x)), -1), rtol=1e-7
    )

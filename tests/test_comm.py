"""repro/comm subsystem tests (DESIGN.md §11): compressor registry +
capability guards, payload-byte formulas, Pallas-kernel-vs-numpy-ref
round-trip parity, the error-feedback sum-preservation invariant
(compressed delta + residual == raw delta, exactly the telescoping the EF
convergence argument needs), the identity wire's short-circuit contract
(the basis of the identity==off bitwise equivalence pins in
tests/test_backend_equiv.py), and the per-row locality property that makes
the device-local call under shard_map THE sharded variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommSpec,
    available_compressors,
    check_algorithm,
    get_compressor,
    make_comm_spec,
)
from repro.comm.base import FP32_BYTES, Identity, tree_dim

LOSSY = ("int8", "int4", "topk")


def _params(d0=6, d1=5, seed=0):
    rng = np.random.RandomState(seed)
    return {
        "w": jnp.asarray(rng.randn(d0, d1), jnp.float32),
        "b": jnp.asarray(rng.randn(d1), jnp.float32),
    }


def _endpoints(params, A=4, seed=1, scale=0.1):
    rng = np.random.RandomState(seed)
    return jax.tree.map(
        lambda p: p[None] + scale * jnp.asarray(
            rng.randn(A, *p.shape), jnp.float32
        ),
        params,
    )


# ---------------------------------------------------------------------------
# registry + capability guards
# ---------------------------------------------------------------------------


def test_registry_names_and_lookup():
    names = available_compressors()
    assert set(("identity", "int8", "int4", "topk")) <= set(names)
    for n in names:
        assert get_compressor(n).name == n


def test_unknown_compressor_lists_registry():
    with pytest.raises(ValueError, match="identity"):
        get_compressor("gzip")


def test_invalid_level_lists_valid_levels():
    with pytest.raises(ValueError, match="valid levels"):
        get_compressor("int8")(7)
    with pytest.raises(ValueError, match="valid levels"):
        get_compressor("topk")(99)
    # every advertised level constructs
    for name in available_compressors():
        cls = get_compressor(name)
        for level in cls.levels:
            assert cls(level).level == level


def test_topk_refused_for_flow_dynamics():
    from repro.fed.algorithms import get_algorithm

    with pytest.raises(ValueError, match="has_flow_dynamics"):
        check_algorithm("topk", get_algorithm("fedecado"))
    # quantizers and identity pass for flow algorithms; everything passes
    # for the averaging family
    for name in ("identity", "int8", "int4"):
        check_algorithm(name, get_algorithm("fedecado"))
    for name in available_compressors():
        check_algorithm(name, get_algorithm("fednova"))


def test_make_comm_spec_defaults_to_identity():
    params = _params()
    spec = make_comm_spec(None, None, params)
    assert spec.lossless and spec.comp.name == "identity"
    assert spec.d_model == tree_dim(params) == 6 * 5 + 5
    assert spec.payload_down == FP32_BYTES * spec.d_model
    # a level without a compressor hits the identity ladder and is refused
    with pytest.raises(ValueError, match="valid levels"):
        make_comm_spec(None, 2, params)


# ---------------------------------------------------------------------------
# payload formulas
# ---------------------------------------------------------------------------


def test_payload_byte_formulas():
    d = 1000
    assert Identity().payload_bytes(d) == 4 * d
    # quantized payload: ceil(d*bits/8) data bytes + one fp32 scale
    assert get_compressor("int8")().payload_bytes(d) == d + 4
    assert get_compressor("int4")().payload_bytes(d) == d // 2 + 4
    # top-k: (int32 coordinate, fp32 value) per kept coordinate
    for level, frac in ((1, 0.25), (2, 0.10), (3, 0.05), (4, 0.01)):
        k = get_compressor("topk")(level)._k(d)
        assert k == max(1, int(np.ceil(frac * d)))
        assert get_compressor("topk")(level).payload_bytes(d) == 8 * k


def test_payloads_monotone_in_aggressiveness():
    d = 4096
    up = lambda name, level=None: get_compressor(name)(level).payload_bytes(d)
    assert up("int4") < up("int8") < Identity().payload_bytes(d)
    assert up("topk", 4) < up("topk", 3) < up("topk", 2) < up("topk", 1)


# ---------------------------------------------------------------------------
# kernel vs numpy ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,level", [("int8", None), ("int4", None),
                                        ("topk", 1), ("topk", 2)])
@pytest.mark.parametrize("A,D", [(1, 1024), (4, 2048), (3, 4096)])
def test_roundtrip_matches_numpy_ref(name, level, A, D):
    """The Pallas round-trip (interpret mode on CPU) must match the
    plugin's numpy oracle on the same noise draw. Widths here are
    tile-aligned — the plugin-level round-trip contract (CommSpec.roundtrip
    zero-pads arbitrary models up to the tile, pinned separately below)."""
    rng = np.random.RandomState(A * 100 + D)
    rows = jnp.asarray(rng.randn(A, D), jnp.float32)
    comp = get_compressor(name)(level)
    key = jax.random.PRNGKey(5)
    got = np.asarray(comp.roundtrip(rows, key))
    want = comp.ref_roundtrip(rows, key)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    if name != "topk":
        # quantization error is bounded by one step of the per-row grid
        step = np.max(np.abs(np.asarray(rows)), -1) / comp.q_max
        assert np.all(
            np.abs(got - np.asarray(rows)) <= step[:, None] + 1e-6
        )


def test_topk_keeps_exactly_k_per_row():
    rng = np.random.RandomState(3)
    D = 1024
    rows = jnp.asarray(rng.randn(4, D), jnp.float32)
    for level in (1, 2, 3, 4):
        comp = get_compressor("topk")(level)
        out = np.asarray(comp.roundtrip(rows, jax.random.PRNGKey(0)))
        kept = np.sum(out != 0.0, axis=-1)
        # ties in |x| are measure-zero under randn; k exact per row
        np.testing.assert_array_equal(kept, comp._k(D))
        # surviving coordinates are unchanged
        mask = out != 0.0
        np.testing.assert_array_equal(out[mask], np.asarray(rows)[mask])


@pytest.mark.parametrize("name,level", [("topk", 2), ("int8", None)])
def test_rowwise_locality_makes_device_local_call_the_sharded_variant(
    name, level
):
    """Every compressor is elementwise per ROW on the stacked (A, d) delta
    matrix, so compressing a shard of the rows equals slicing the full
    compressed matrix — the property that lets the sharded backend call the
    same round-trip device-locally under shard_map with no collective. The
    stochastic quantizers hold it only on a shared noise draw, so their
    per-row noise is sliced alongside the rows here (the backends draw
    noise at full-cohort shape for exactly this reason — see the int8
    cross-backend tolerance note in DESIGN.md §11)."""
    rng = np.random.RandomState(9)
    A, D = 6, 1024
    rows = jnp.asarray(rng.randn(A, D), jnp.float32)
    comp = get_compressor(name)(level)
    key = jax.random.PRNGKey(11)
    if name == "topk":
        full = np.asarray(comp.roundtrip(rows, key))
        for lo, hi in ((0, 2), (2, 4), (4, 6)):
            shard = np.asarray(comp.roundtrip(rows[lo:hi], key))
            np.testing.assert_allclose(shard, full[lo:hi], rtol=1e-7)
    else:
        from repro.comm.kernels import stoch_quant_call
        from repro.comm.quantize import quant_scale

        u = jax.random.uniform(key, rows.shape, rows.dtype)
        full = np.asarray(stoch_quant_call(
            rows, u, quant_scale(rows, comp.q_max), comp.q_max,
            interpret=True,
        ))
        for lo, hi in ((0, 3), (3, 6)):
            r = rows[lo:hi]
            shard = np.asarray(stoch_quant_call(
                r, u[lo:hi], quant_scale(r, comp.q_max), comp.q_max,
                interpret=True,
            ))
            np.testing.assert_allclose(shard, full[lo:hi], rtol=1e-7)


# ---------------------------------------------------------------------------
# CommSpec: identity short-circuit, EF sum preservation
# ---------------------------------------------------------------------------


def test_identity_compress_endpoints_is_a_short_circuit():
    """The lossless wire must return its inputs UNTOUCHED — no ravel, no
    arithmetic — which is what makes identity==off bitwise-equal on every
    backend (pinned end-to-end in tests/test_backend_equiv.py)."""
    params = _params()
    x_a = _endpoints(params)
    spec = make_comm_spec(None, None, params)
    out, ef = spec.compress_endpoints(params, x_a, None, 3)
    assert ef is None
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(x_a), strict=True):
        assert a is b


@pytest.mark.parametrize("name,level", [("int8", None), ("int4", None),
                                        ("topk", 2)])
def test_error_feedback_sum_preservation(name, level):
    """EF invariant: for raw = (x_a − x_c) + ef, the compressed delta and
    the new residual must satisfy c + ef' == raw exactly — the residual
    carries precisely what the wire dropped, so nothing is ever lost, only
    delayed. The model is sized well past one kernel tile so top-k's
    padded-width k stays below d and the wire is genuinely lossy."""
    params = _params(d0=40, d1=30)
    x_a = _endpoints(params)
    spec = CommSpec(comp=get_compressor(name)(level),
                    d_model=tree_dim(params), seed=7)
    A = 4
    ef = jax.tree.map(
        lambda p: 0.05 * jnp.ones((A,) + p.shape, jnp.float32), params
    )
    out, ef_new = spec.compress_endpoints(params, x_a, ef, rnd=2)
    assert ef_new is not None
    for xc, xa, e, o, en in zip(
        jax.tree.leaves(params), jax.tree.leaves(x_a), jax.tree.leaves(ef),
        jax.tree.leaves(out), jax.tree.leaves(ef_new), strict=True,
    ):
        raw = (np.asarray(xa) - np.asarray(xc)[None]) + np.asarray(e)
        c = np.asarray(o) - np.asarray(xc)[None]
        np.testing.assert_allclose(c + np.asarray(en), raw,
                                   rtol=1e-5, atol=1e-6)
        # and the wire was genuinely lossy (ef' != 0 somewhere)
        assert np.max(np.abs(np.asarray(en))) > 0


def test_flow_path_compresses_without_error_feedback():
    params = _params()
    x_a = _endpoints(params)
    spec = CommSpec(comp=get_compressor("int8")(), d_model=tree_dim(params))
    out, ef_new = spec.compress_endpoints(params, x_a, None, rnd=0)
    assert ef_new is None
    # lossy: the endpoints moved
    diffs = [
        np.max(np.abs(np.asarray(a) - np.asarray(b)))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(x_a))
    ]
    assert max(diffs) > 0


def test_compress_endpoints_is_deterministic_in_rnd():
    params = _params()
    x_a = _endpoints(params)
    spec = CommSpec(comp=get_compressor("int8")(), d_model=tree_dim(params),
                    seed=3)
    a1, _ = spec.compress_endpoints(params, x_a, None, rnd=5)
    a2, _ = spec.compress_endpoints(params, x_a, None, rnd=5)
    b, _ = spec.compress_endpoints(params, x_a, None, rnd=6)
    for l1, l2 in zip(jax.tree.leaves(a1), jax.tree.leaves(a2), strict=True):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert any(
        np.any(np.asarray(l1) != np.asarray(l3))
        for l1, l3 in zip(jax.tree.leaves(a1), jax.tree.leaves(b))
    )


def test_init_ef_state_zero_rows():
    params = _params()
    spec = CommSpec(comp=get_compressor("int4")(), d_model=tree_dim(params))
    assert spec.error_feedback
    st = spec.init_ef_state(params, n=9)
    for p, s in zip(jax.tree.leaves(params), jax.tree.leaves(st), strict=True):
        assert s.shape == (9,) + p.shape and s.dtype == jnp.float32
        assert not np.any(np.asarray(s))


def test_cache_key_distinguishes_wire_models():
    params = _params()
    keys = {
        make_comm_spec(c, l, params, seed=s).cache_key()
        for c, l, s in (
            (None, None, 0), ("int8", None, 0), ("int4", None, 0),
            ("topk", 1, 0), ("topk", 2, 0), ("int8", None, 1),
        )
    }
    assert len(keys) == 6

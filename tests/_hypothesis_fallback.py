"""Minimal deterministic fallback for the ``hypothesis`` API surface these
tests use, installed by conftest.py ONLY when the real package is missing
(it is an optional test dependency — see pyproject.toml [test] extras).

Real hypothesis does guided search and shrinking; this fallback just runs
``max_examples`` seeded pseudo-random samples per test, which keeps the
property suites executing (rather than erroring at collection) in minimal
environments. Install hypothesis for real property testing.

Covered API: @given(**kwargs), @settings(max_examples=, deadline=),
strategies.{integers, floats, booleans, sampled_from, lists, tuples, just}.
"""
from __future__ import annotations


import zlib

import numpy as np

__version__ = "0.0-fallback"


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.RandomState):
        return self._sample(rng)


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.randint(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        # width/allow_nan/allow_infinity accepted and ignored: bounded
        # uniform draws are always finite and fp32-representable enough
        def draw(rng):
            v = float(rng.uniform(min_value, max_value))
            # hit the boundaries occasionally, like hypothesis does
            r = rng.rand()
            if r < 0.05:
                return float(min_value)
            if r < 0.1:
                return float(max_value)
            return v

        return _Strategy(draw)

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.randint(0, 2)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.randint(0, len(options))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.randint(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(n)]

        return _Strategy(draw)

    @staticmethod
    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)


def settings(max_examples=100, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NOTE: the wrapper must expose a ZERO-argument signature (and no
        # __wrapped__ chain) or pytest would try to resolve the strategy
        # parameters as fixtures — hence no functools.wraps here.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", 100)
            # deterministic per-test seed so failures reproduce
            seed = zlib.adler32(fn.__qualname__.encode()) & 0x7FFFFFFF
            rng = np.random.RandomState(seed)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategy_kwargs.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def assume(condition) -> bool:
    """Real hypothesis aborts the example; here we just require truthiness."""
    return bool(condition)

"""Substrate tests: optimizers, checkpointing, data pipeline, sensitivity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_pytree, restore_server_state, save_pytree, save_server_state
from repro.core import hutchinson_diag, hutchinson_scalar, init_server_state, make_gain
from repro.data import ClientDataLoader, lm_batches, make_classification, make_lm_stream
from repro.optim import adam, apply_updates, cosine_schedule, momentum, sgd


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _rosenbrock_ish(p):
    return jnp.sum(jnp.square(p["w"] - 3.0)) + 0.5 * jnp.sum(jnp.square(p["b"]))


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: momentum(0.05), lambda: adam(0.1)
])
def test_optimizers_converge_on_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((4,)), "b": jnp.ones((3,))}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(_rosenbrock_ish)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(_rosenbrock_ish(params)) < 1e-2


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.int32(10))), 1.0, rtol=1e-5)
    assert float(lr(jnp.int32(100))) < 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_pytree_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree)
        back = load_pytree(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )
        assert x.dtype == y.dtype


def test_server_state_checkpoint_roundtrip():
    state = init_server_state({"w": jnp.ones((3,))}, n_clients=4)
    state = state._replace(t=jnp.float32(1.5), round=jnp.int32(7))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "state.npz")
        save_server_state(path, state)
        back = restore_server_state(path, init_server_state({"w": jnp.ones((3,))}, 4))
    assert float(back.t) == 1.5
    assert int(back.round) == 7
    np.testing.assert_allclose(back.x_c["w"], state.x_c["w"])


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.ones((3,))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.npz")
        save_pytree(path, tree)
        with pytest.raises(ValueError):
            load_pytree(path, {"a": jnp.ones((4,))})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_synthetic_classification_learnable():
    data = make_classification(512, dim=8, n_classes=3, seed=0)
    assert data["x"].shape == (512, 8)
    assert set(np.unique(data["y"])) <= set(range(3))
    # a linear probe should beat chance on teacher-generated labels
    from numpy.linalg import lstsq
    Y = np.eye(3)[data["y"]]
    W, *_ = lstsq(data["x"], Y, rcond=None)
    acc = (np.argmax(data["x"] @ W, -1) == data["y"]).mean()
    assert acc > 0.4  # chance = 1/3


def test_lm_stream_planted_structure():
    toks = make_lm_stream(20_000, vocab=64, seed=0)
    # successor structure: P(next == succ(cur)) ~ 0.7
    # estimate by the most common successor per token
    succ_hits = 0
    from collections import Counter, defaultdict
    nxt = defaultdict(Counter)
    for a, b in zip(toks[:-1], toks[1:]):
        nxt[a][b] += 1
    top_mass = np.mean(
        [c.most_common(1)[0][1] / sum(c.values()) for c in nxt.values() if sum(c.values()) > 20]
    )
    assert top_mass > 0.5


def test_client_dataloader_stacking():
    data = {"x": np.arange(100, dtype=np.float32)[:, None], "y": np.arange(100)}
    dl = ClientDataLoader(data, np.arange(50), batch_size=8, seed=0)
    stacked = dl.stacked(4)
    assert stacked["x"].shape == (4, 8, 1)
    assert stacked["y"].shape == (4, 8)


# ---------------------------------------------------------------------------
# sensitivity (Hutchinson)
# ---------------------------------------------------------------------------


def test_hutchinson_trace_on_known_quadratic():
    """f = 0.5 x^T D x -> H = diag(D); tr(H)/n estimated by probes."""
    D = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    loss = lambda p, b: 0.5 * jnp.sum(D * jnp.square(p["x"]))
    params = {"x": jnp.ones((4,))}
    est = hutchinson_scalar(loss, params, {}, jax.random.PRNGKey(0), n_probes=16)
    np.testing.assert_allclose(float(est), 2.5, rtol=1e-4)  # exact: probes cancel


def test_hutchinson_diag_on_known_quadratic():
    D = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    loss = lambda p, b: 0.5 * jnp.sum(D * jnp.square(p["x"]))
    params = {"x": jnp.ones((4,))}
    diag = hutchinson_diag(loss, params, {}, jax.random.PRNGKey(0), n_probes=8)
    np.testing.assert_allclose(diag["x"], D, rtol=1e-4)  # diag H exact for v in {-1,1}


@settings(max_examples=20, deadline=None)
@given(p_i=st.floats(0.01, 2.0), h=st.floats(-5.0, 50.0), dt_ref=st.floats(0.01, 1.0))
def test_make_gain_positive_and_monotone(p_i, h, dt_ref):
    g = float(make_gain(jnp.float32(h), p_i, dt_ref))
    assert g >= 1.0 / dt_ref - 1e-5          # clipped curvature cannot reduce G
    g2 = float(make_gain(jnp.float32(max(h, 0) + 1.0), p_i, dt_ref))
    assert g2 >= g                            # more curvature -> bigger gain

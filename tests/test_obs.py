"""Schema pins + round-trips for the observability layer (repro/obs).

The telemetry record schema is the contract between the device half (rows
packed inside jit segments by every backend) and every host consumer (the
JSONL run log, ``FedSim`` history, the sweep/bench summary columns, the
shared round-line formatter, CI's ``--log-jsonl`` smoke cell). These tests
pin that contract:

  * the field tuples and bucket edges are frozen (changing them is a
    schema bump, not a silent edit);
  * ``pack_row``/``rows_to_records`` round-trip device rows into records;
  * ``RunLog`` files round-trip through ``validate_jsonl`` and tampered
    files are rejected;
  * ``TraceRecorder``/``span`` emit valid Chrome-trace JSON and ``span``
    is a no-op without a recorder;
  * a real ``FedSim.run`` emits schema-valid log + trace files, and the
    committed example artifacts under examples/artifacts keep validating.
"""
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import (
    N_STALE_BUCKETS,
    RECORD_FIELDS,
    RUNLOG_SCHEMA_VERSION,
    STALE_BUCKET_EDGES,
    TELEMETRY_FIELDS,
    RunHistory,
    RunLog,
    TraceRecorder,
    field_index,
    format_counters,
    format_round_line,
    make_record,
    pack_row,
    rows_to_records,
    span,
    stale_histogram,
    summarize_records,
    validate_jsonl,
    validate_record,
    validate_trace,
)

_REPO = os.path.join(os.path.dirname(__file__), os.pardir)


# ---------------------------------------------------------------------------
# schema pins
# ---------------------------------------------------------------------------


def test_telemetry_schema_is_pinned():
    assert TELEMETRY_FIELDS == (
        "loss", "cohort", "dropped", "substeps", "backtracks",
        "dt_min", "dt_max", "dt_sum", "waves", "arrived", "stale",
        "horizon", "tau_end", "bytes_up", "bytes_down",
    )
    assert STALE_BUCKET_EDGES == (1, 2, 4, 8)
    assert N_STALE_BUCKETS == 4
    assert RUNLOG_SCHEMA_VERSION == 1
    # host records: every device field except the internal dt_sum, plus the
    # round stamp, derived dt_mean and the staleness histogram
    assert RECORD_FIELDS == (
        "round", "loss", "cohort", "dropped", "substeps", "backtracks",
        "dt_min", "dt_max", "waves", "arrived", "stale", "horizon",
        "tau_end", "bytes_up", "bytes_down", "dt_mean", "stale_hist",
    )
    for i, name in enumerate(TELEMETRY_FIELDS):
        assert field_index(name) == i


# ---------------------------------------------------------------------------
# device rows
# ---------------------------------------------------------------------------


def test_pack_row_defaults_and_layout():
    row = np.asarray(pack_row(cohort=3, substeps=5, dt_max=0.25))
    assert row.shape == (len(TELEMETRY_FIELDS),)
    assert row.dtype == np.float32
    assert math.isnan(row[field_index("loss")])   # loss must be set on host
    assert row[field_index("cohort")] == 3
    assert row[field_index("substeps")] == 5
    assert row[field_index("dt_max")] == np.float32(0.25)
    assert row[field_index("waves")] == 0


def test_pack_row_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown telemetry fields"):
        pack_row(cohort=1, solver_iters=2)


def test_stale_histogram_buckets():
    # ages 1, 2, 3, 4, 7, 8, 40 with one dead slot -> [1], [2,3], [4,7], [8+)
    ages = jnp.asarray([1, 2, 3, 4, 7, 8, 40, 99], jnp.int32)
    alive = jnp.asarray([1, 1, 1, 1, 1, 1, 1, 0], jnp.float32)
    hist = np.asarray(stale_histogram(ages, alive))
    np.testing.assert_array_equal(hist, [1, 2, 2, 2])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_stale_histogram_sums_to_stale_counter(seed):
    """Property: the 4 staleness buckets partition the stale flights, so
    the histogram always sums to the ``stale`` counter — i.e. the number
    of alive flights, every one of which a round ages to >= 1 (the bucket
    edges start at 1, so no alive flight can fall outside all buckets)."""
    rng = np.random.RandomState(seed)
    C = rng.randint(1, 33)
    alive = (rng.rand(C) < 0.6).astype(np.float32)
    # after a round, every surviving flight has stale_rounds >= 1; dead
    # slots carry 0 (exactly what multirate_integrate writes)
    ages = np.where(alive > 0, rng.randint(1, 50, C), 0)
    hist = np.asarray(stale_histogram(
        jnp.asarray(ages, jnp.int32), jnp.asarray(alive)
    ))
    assert hist.shape == (N_STALE_BUCKETS,)
    assert int(hist.sum()) == int((alive > 0).sum())


# ---------------------------------------------------------------------------
# host records
# ---------------------------------------------------------------------------


def test_make_record_semantics():
    rec = make_record(
        7, loss=0.5, cohort=4.0, substeps=6.0, backtracks=2.0,
        dt_min=0.01, dt_max=0.04, dt_sum=0.12,
    )
    assert set(rec) == set(RECORD_FIELDS)
    # integral counters become python ints (JSON round-trip stays exact)
    for key in ("round", "cohort", "dropped", "substeps", "backtracks",
                "waves", "arrived", "stale", "bytes_up", "bytes_down"):
        assert isinstance(rec[key], int), key
    assert rec["round"] == 7 and rec["cohort"] == 4
    assert rec["arrived"] == 4          # defaults to cohort (synchronous)
    assert rec["dt_mean"] == pytest.approx(0.02)
    assert rec["stale_hist"] == [0] * N_STALE_BUCKETS


def test_make_record_zero_substeps_clears_dt():
    rec = make_record(0, loss=1.0, cohort=2, substeps=0, dt_min=math.inf)
    assert rec["dt_min"] == 0.0 and rec["dt_mean"] == 0.0


def test_rows_to_records_roundtrip():
    rows = np.stack([
        np.asarray(pack_row(
            loss=1.5, cohort=3, substeps=4, backtracks=1,
            dt_min=0.01, dt_max=0.02, dt_sum=0.06, waves=2, arrived=2,
            stale=1, horizon=0.5, tau_end=0.04,
        )),
        np.asarray(pack_row(loss=1.25, cohort=3, substeps=2, dt_sum=0.02)),
    ])
    hists = np.asarray([[1, 0, 0, 0], [0, 0, 0, 0]], np.float32)
    recs = rows_to_records(10, rows, hists)
    assert [r["round"] for r in recs] == [10, 11]
    assert recs[0]["waves"] == 2 and recs[0]["stale"] == 1
    assert recs[0]["stale_hist"] == [1, 0, 0, 0]
    assert recs[0]["dt_mean"] == pytest.approx(0.015)
    # device rows carry arrived explicitly, so the cohort default never
    # applies on this path (pack_row's unset fields are 0)
    assert recs[1]["arrived"] == 0
    for rec in recs:
        validate_record({"kind": "round", **rec})


def test_summarize_records():
    recs = [
        make_record(0, loss=1.0, cohort=4, substeps=4, dt_sum=0.08,
                    dt_min=0.01, dt_max=0.03, waves=1,
                    stale_hist=[2, 1, 0, 0]),
        make_record(1, loss=float("nan"), cohort=0, dropped=2, substeps=0),
    ]
    s = summarize_records(recs)
    assert s["rounds"] == 2
    assert s["mean_loss"] == pytest.approx(1.0)   # nan round excluded
    assert s["substeps_per_round"] == pytest.approx(2.0)
    assert s["dropped"] == 2
    assert s["dt_min"] == pytest.approx(0.01)     # substeps==0 round excluded
    assert s["dt_mean"] == pytest.approx(0.02)
    assert s["stale_hist"] == [2, 1, 0, 0]
    assert summarize_records([]) == {"rounds": 0}


# ---------------------------------------------------------------------------
# JSONL run logs
# ---------------------------------------------------------------------------


def _write_log(path, rounds=3):
    with RunLog(str(path)) as log:
        log.start(config={"rounds": rounds}, backend="vectorized")
        recs = [
            make_record(r, loss=1.0 / (r + 1), cohort=2, substeps=3,
                        dt_sum=0.03, dt_min=0.01, dt_max=0.02)
            for r in range(rounds)
        ]
        for rec in recs:
            log.round(rec, metrics={"acc": 0.5} if rec["round"] == 2 else None)
        log.summary(summarize_records(recs))


def test_runlog_roundtrip(tmp_path):
    path = tmp_path / "run.jsonl"
    _write_log(path)
    records = validate_jsonl(str(path))
    kinds = [r["kind"] for r in records]
    assert kinds == ["run", "round", "round", "round", "summary"]
    header = records[0]
    assert header["schema_version"] == RUNLOG_SCHEMA_VERSION
    for key in ("git_sha", "jax_version", "n_devices", "platform"):
        assert key in header
    assert header["config"] == {"rounds": 3}
    assert records[3]["metrics"] == {"acc": 0.5}


def test_runlog_rejects_tampered_records(tmp_path):
    path = tmp_path / "run.jsonl"
    _write_log(path)
    lines = path.read_text().splitlines()

    # drop a pinned field from a round record
    bad = json.loads(lines[1])
    del bad["substeps"]
    (tmp_path / "t1.jsonl").write_text(
        "\n".join([lines[0], json.dumps(bad)] + lines[2:])
    )
    with pytest.raises(ValueError, match="substeps"):
        validate_jsonl(str(tmp_path / "t1.jsonl"))

    # header must come first and be unique
    (tmp_path / "t2.jsonl").write_text("\n".join(lines[1:]))
    with pytest.raises(ValueError, match="run header"):
        validate_jsonl(str(tmp_path / "t2.jsonl"))

    # wrong schema version
    hdr = json.loads(lines[0])
    hdr["schema_version"] = RUNLOG_SCHEMA_VERSION + 1
    (tmp_path / "t3.jsonl").write_text("\n".join([json.dumps(hdr)] + lines[1:]))
    with pytest.raises(ValueError, match="schema_version"):
        validate_jsonl(str(tmp_path / "t3.jsonl"))

    # float counters are rejected (padding leaks would show up this way)
    bad = json.loads(lines[1])
    bad["cohort"] = 2.0
    (tmp_path / "t4.jsonl").write_text(
        "\n".join([lines[0], json.dumps(bad)] + lines[2:])
    )
    with pytest.raises(ValueError, match="cohort"):
        validate_jsonl(str(tmp_path / "t4.jsonl"))


def test_validate_record_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        validate_record({"kind": "telemetry"})


def test_runlog_rejects_tampered_bytes_fields(tmp_path):
    """The PR-8 bytes columns are part of the pinned schema: a round record
    with a missing or non-integral bytes counter must be rejected exactly
    like the older counters (no silent fp-bytes drift in committed logs)."""
    path = tmp_path / "run.jsonl"
    _write_log(path)
    lines = path.read_text().splitlines()

    bad = json.loads(lines[1])
    del bad["bytes_up"]
    (tmp_path / "b1.jsonl").write_text(
        "\n".join([lines[0], json.dumps(bad)] + lines[2:])
    )
    with pytest.raises(ValueError, match="bytes_up"):
        validate_jsonl(str(tmp_path / "b1.jsonl"))

    bad = json.loads(lines[1])
    bad["bytes_down"] = 104.5
    (tmp_path / "b2.jsonl").write_text(
        "\n".join([lines[0], json.dumps(bad)] + lines[2:])
    )
    with pytest.raises(ValueError, match="bytes_down"):
        validate_jsonl(str(tmp_path / "b2.jsonl"))


def test_bytes_accounting_summary_and_format():
    """bytes_up/bytes_down total across rounds in the run summary, render
    in round lines only when nonzero, and surface in format_counters."""
    from repro.obs.format import format_bytes

    recs = [
        make_record(0, loss=1.0, cohort=4, bytes_up=400, bytes_down=1600),
        make_record(1, loss=0.9, cohort=2, bytes_up=200, bytes_down=800),
    ]
    s = summarize_records(recs)
    assert s["bytes_up"] == 600 and s["bytes_down"] == 2400

    line = format_round_line(recs[0])
    assert "up 400B" in line and "down " in line
    assert "up=" in format_counters(s)

    # uncounted (legacy zero) rounds don't clutter the line
    quiet = make_record(2, loss=0.5, cohort=1)
    assert "up " not in format_round_line(quiet)

    assert format_bytes(812) == "812B"
    assert format_bytes(14540) == "14.2KB"
    assert format_bytes(3 * 1024 * 1024) == "3.0MB"


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------


def test_span_is_noop_without_recorder():
    with span("unrecorded", x=1):
        pass        # must not raise, must not require a recorder


def test_trace_recorder_roundtrip(tmp_path):
    path = tmp_path / "trace.json"
    with TraceRecorder(str(path)) as rec:
        with span("segment", backend="vectorized", rounds=2):
            with span("inner"):
                pass
    events = validate_trace(str(path))
    names = [e["name"] for e in events]
    assert names == ["inner", "segment"]     # completion order
    seg = events[1]
    assert seg["args"] == {"backend": "vectorized", "rounds": 2}
    assert seg["dur"] >= events[0]["dur"]
    # recorder uninstalled on exit: span() is a no-op again
    with span("after"):
        pass
    assert len(rec.events) == 2


# ---------------------------------------------------------------------------
# shared formatter
# ---------------------------------------------------------------------------


def test_format_round_line():
    sync = make_record(3, loss=0.25, cohort=4, substeps=5, backtracks=1,
                       dt_sum=0.05)
    line = format_round_line(sync, wall_s=1.5)
    assert "round   3" in line and "loss 0.2500" in line
    assert "substeps 5" in line and "backtracks 1" in line
    assert "cohort 4" in line and "(1.50s)" in line
    assert "arrived" not in line        # async group only when async

    ev = make_record(4, loss=0.5, cohort=3, substeps=2, waves=2, arrived=2,
                     stale=1, dropped=1)
    line = format_round_line(ev, extra={"devices": 8})
    assert "arrived 2 stale 1 waves 2 dropped 1" in line
    assert "devices 8" in line


def test_format_counters():
    s = summarize_records([
        make_record(0, loss=1.0, cohort=2, substeps=4, waves=2, stale=1,
                    dropped=1),
    ])
    out = format_counters(s)
    assert "substeps/r=4.0" in out and "waves/r=2.0" in out
    assert "stale=1" in out and "dropped=1" in out
    assert format_counters({"rounds": 0}) == ""


# ---------------------------------------------------------------------------
# FedSim end-to-end: history, log + trace files
# ---------------------------------------------------------------------------


def _tiny_sim(tmp_path, backend="vectorized", **cfg_kw):
    import jax

    from repro.data import make_classification
    from repro.fed import FedSim, FedSimConfig, iid_partition

    data = make_classification(96, dim=4, n_classes=3, seed=3)
    parts = iid_partition(len(data["y"]), 4, seed=3)
    k = jax.random.PRNGKey(3)
    params0 = {"w": jax.random.normal(k, (4, 3)) / 2.0, "b": jnp.zeros((3,))}

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(batch["x"] @ p["w"] + p["b"])
        return -jnp.mean(jnp.take_along_axis(
            lp, batch["y"][:, None].astype(jnp.int32), -1
        ))

    def eval_fn(p):
        return {"acc": 0.5}

    cfg = FedSimConfig(
        algorithm="fedecado", n_clients=4, participation=0.5, rounds=3,
        batch_size=8, steps_per_epoch=1, seed=5, eval_every=3,
        backend=backend,
        log_jsonl=str(tmp_path / f"{backend}.jsonl"),
        trace_json=str(tmp_path / f"{backend}.json"),
        **cfg_kw,
    )
    return FedSim(loss_fn, params0, data, parts, cfg, eval_fn)


@pytest.mark.parametrize("backend", ["vectorized", "event"])
def test_fedsim_emits_valid_log_and_trace(tmp_path, backend):
    sim = _tiny_sim(tmp_path, backend=backend)
    hist = sim.run()

    assert isinstance(hist, RunHistory)
    assert len(hist) == 3 and hist.rounds == [0, 1, 2]
    assert len(hist.telemetry) == 3
    for rec in hist.telemetry:
        validate_record({"kind": "round", **rec})
    assert len(hist.eval_rounds) == len(hist.metrics)   # aligned lists
    assert hist.eval_rounds[-1] == 2 and hist.metrics[-1] == {"acc": 0.5}
    assert hist.participation is not None and hist.participation.sum() > 0
    assert hist.summary()["rounds"] == 3

    records = validate_jsonl(str(tmp_path / f"{backend}.jsonl"))
    rounds = [r for r in records if r["kind"] == "round"]
    assert [r["round"] for r in rounds] == [0, 1, 2]
    assert rounds[2]["metrics"] == {"acc": 0.5}
    summary = [r for r in records if r["kind"] == "summary"]
    assert len(summary) == 1 and summary[0]["rounds"] == 3

    events = validate_trace(str(tmp_path / f"{backend}.json"))
    names = {e["name"] for e in events}
    assert "segment" in names and "eval" in names and "plan_draw" in names


def test_buffered_records_validate_and_histogram_matches_stale(tmp_path):
    """Buffered-server rounds (K-trigger drains, no-trigger ageing rounds)
    must emit the SAME pinned record schema: every record passes
    validate_record, the staleness histogram sums to the ``stale`` counter
    round for round, and the run log + trace round-trip through the
    validators unchanged."""
    sim = _tiny_sim(
        tmp_path, backend="event",
        event_buffered=True, event_buffer_size=3,
    )
    hist = sim.run()

    assert len(hist.telemetry) == 3
    aged = False
    for rec in hist.telemetry:
        validate_record({"kind": "round", **rec})
        assert sum(rec["stale_hist"]) == rec["stale"]
        aged = aged or rec["stale"] > 0
    # buffer K=3 > cohort 2: round 0 cannot trigger, so flights aged
    assert aged

    records = validate_jsonl(str(tmp_path / "event.jsonl"))
    rounds = [r for r in records if r["kind"] == "round"]
    assert [r["round"] for r in rounds] == [0, 1, 2]
    for r in rounds:
        assert sum(r["stale_hist"]) == r["stale"]
    validate_trace(str(tmp_path / "event.json"))
    # the backend's max-staleness witness saw the ageing too
    assert sim.backend.max_stale >= 1


def test_history_loss_endpoints_still_work(tmp_path):
    from repro.fed import last_finite_loss, mean_finite_loss

    hist = _tiny_sim(tmp_path).run()
    assert np.isfinite(last_finite_loss(hist.loss))
    assert np.isfinite(mean_finite_loss(hist.loss))


# ---------------------------------------------------------------------------
# committed example artifacts
# ---------------------------------------------------------------------------


def test_committed_artifacts_validate():
    """The committed example run log + trace (examples/artifacts, produced
    by launch/fedrun.py --log-jsonl/--trace-json) must keep round-tripping
    through the schema validators."""
    log = os.path.join(_REPO, "examples", "artifacts", "fedrun_event.jsonl")
    trace = os.path.join(_REPO, "examples", "artifacts", "fedrun_event_trace.json")
    if not (os.path.exists(log) and os.path.exists(trace)):
        pytest.skip("no committed example artifacts")
    records = validate_jsonl(log)
    rounds = [r for r in records if r["kind"] == "round"]
    assert rounds, "committed run log has no round records"
    # the event backend's async counters are present and consistent
    assert any(r["waves"] > 0 for r in rounds)
    events = validate_trace(trace)
    assert any(e["name"] == "round" for e in events)

"""Engine-bench harness smoke test: ``benchmarks/run.py --only engine`` must
run end-to-end and persist a ``BENCH_engine.json`` whose schema downstream
tooling can rely on (algorithm × backend × n_clients → rounds/sec). The
schema is pinned here — bump ``ENGINE_BENCH_SCHEMA_VERSION`` in
benchmarks/run.py when it changes, and update this test in the same PR.

Schema history: v1 = backend × n_clients (single hardwired algorithm);
v2 = adds the per-algorithm axis ("algorithms" list + "algorithm" per
results row, enumerable from the fed/algorithms registry); v3 = adds the
event backend (device-resident flight-table scheduler) — event rows exist
only for flow-capable algorithms, and the config block records the event
horizon/wave settings; v4 = rows gain compile_seconds (warm-up minus
steady-state wall, so rounds/sec stays a pure steady-state number) and the
shared-telemetry columns substeps_per_round / waves_per_round / stale /
dropped (repro/obs, DESIGN.md §9); v5 = adds the event_buffered backend
axis (fully-asynchronous K-trigger buffered server, DESIGN.md §10), a
max_stale column on every row, and the optional heavy_traffic section
(n=10^4 Poisson-arrival cell with the bounded max-staleness witness);
v6 = rows gain participation / peak_state_bytes / state_rows (resident
per-client state accounting, repro.sim.cache.state_nbytes — gated at 2x
growth by repro.tune.gate), plus the sparse client-cache cells
(client_cache=True rows whose state_rows track the cohort, not the
population, each with a materialized_state_bytes projection witness;
DESIGN.md §13)."""
import importlib.util
import json
import os

import pytest


def _bench_module():
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "benchmarks", "run.py"
    )
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _expected_rows(report):
    """One row per (algorithm × backend × n_clients), minus the event rows
    of algorithms without flow dynamics (the event scheduler is flow-only)."""
    from repro.fed.algorithms import get_algorithm

    return {
        (a, b, n)
        for a in report["algorithms"]
        for b in report["backends"]
        for n in report["sizes"]
        if not (b in ("event", "event_buffered")
                and not get_algorithm(a).has_flow_dynamics)
    }


def test_engine_bench_runs_and_json_schema_is_stable(tmp_path):
    bench = _bench_module()
    json_path = tmp_path / "BENCH_engine.json"
    report = bench.engine_bench(
        rounds=2, sizes=(4,),
        backends=("sequential", "vectorized", "event", "sharded",
                  "event_buffered"),
        algorithms=("fedecado", "fednova"),
        json_path=str(json_path),
        # tiny heavy-traffic cell so the n=10^4 code path stays covered
        heavy_traffic={"n": 32, "rounds": 3, "buffer_size": 4},
        # tiny sparse client-cache cell so the million-client code path
        # stays covered (n small enough to run cache growth in seconds)
        sparse=((256, 0.05),),
    )

    assert json_path.exists()
    with open(json_path) as f:
        persisted = json.load(f)
    assert persisted == report

    # -- schema: top level ------------------------------------------------
    assert persisted["schema_version"] == bench.ENGINE_BENCH_SCHEMA_VERSION == 6
    assert persisted["benchmark"] == "engine"
    assert isinstance(persisted["n_devices"], int) and persisted["n_devices"] >= 1
    assert persisted["rounds"] == 2
    assert persisted["sizes"] == [4]
    assert persisted["backends"] == [
        "sequential", "vectorized", "event", "sharded", "event_buffered"
    ]
    assert persisted["algorithms"] == ["fedecado", "fednova"]
    assert isinstance(persisted["config"], dict)
    assert persisted["config"]["event_horizon"] == 1.0
    assert isinstance(persisted["config"]["event_max_waves"], int)
    assert persisted["config"]["event_stale_gamma"] >= 0

    # -- schema: heavy-traffic buffered cell ------------------------------
    ht = persisted["heavy_traffic"]
    assert ht["scenario"] == "heavy-traffic"
    assert ht["n_clients"] == 32 and ht["buffer_size"] == 4
    assert ht["rounds_per_sec"] > 0
    # bounded staleness: the K-trigger must keep endpoint age well under
    # the horizon of the run (unbounded growth would reach rounds-1)
    assert 0 <= ht["max_stale"] < ht["rounds"]
    assert ht["stale"] >= 0 and ht["dropped"] >= 0

    # -- schema: results rows — full product minus flow-only event gaps ---
    rows = persisted["results"]
    assert isinstance(rows, list)
    dense = [r for r in rows if not r.get("client_cache")]
    sparse = [r for r in rows if r.get("client_cache")]
    seen = set()
    for row in dense:
        assert set(row) == {
            "algorithm", "backend", "n_clients", "participation",
            "rounds_per_sec", "compile_seconds", "substeps_per_round",
            "waves_per_round", "stale", "dropped", "max_stale",
            "peak_state_bytes", "state_rows",
        }
        assert row["algorithm"] in persisted["algorithms"]
        assert row["backend"] in persisted["backends"]
        assert row["n_clients"] in persisted["sizes"]
        assert row["participation"] == 1.0
        assert isinstance(row["rounds_per_sec"], float)
        assert row["rounds_per_sec"] > 0
        assert isinstance(row["compile_seconds"], float)
        assert row["compile_seconds"] >= 0
        assert isinstance(row["stale"], int) and isinstance(row["dropped"], int)
        assert isinstance(row["max_stale"], int) and row["max_stale"] >= 0
        # dense cells run cache-off: the per-client arrays are materialized
        # (stateless averaging algorithms legitimately report 0 bytes)
        assert isinstance(row["peak_state_bytes"], int)
        assert row["peak_state_bytes"] >= 0
        assert row["state_rows"] == row["n_clients"]
        if row["algorithm"] == "fedecado":
            # flow algorithms do adaptive-BE solver work every round and
            # carry per-client flow rows
            assert row["substeps_per_round"] > 0
            assert row["peak_state_bytes"] > 0
        if row["backend"] in ("event", "event_buffered"):
            assert row["waves_per_round"] > 0
        if row["backend"] not in ("event", "event_buffered"):
            # barrier backends cannot age endpoints by construction
            assert row["max_stale"] == 0
        seen.add((row["algorithm"], row["backend"], row["n_clients"]))
    assert seen == _expected_rows(persisted)

    # -- schema: sparse client-cache cells --------------------------------
    assert persisted["sparse_cells"] == [
        {"n_clients": 256, "participation": 0.05}
    ]
    assert len(sparse) == 1
    srow = sparse[0]
    assert srow["algorithm"] == "fedecado" and srow["backend"] == "sharded"
    assert srow["n_clients"] == 256 and srow["participation"] == 0.05
    assert srow["rounds_per_sec"] > 0
    # participants-only state: packed rows stay below the population and
    # the materialized projection scales them back up to n
    assert 0 < srow["state_rows"] < srow["n_clients"]
    assert srow["peak_state_bytes"] < srow["materialized_state_bytes"]


def test_repo_bench_artifact_matches_schema():
    """The committed BENCH_engine.json (produced on 8 forced host devices)
    must parse under the same schema and witness the acceptance criteria:
    sharded rounds/sec ≥ vectorized at n=100, and the jit-resident event
    backend present at every size on the fedecado axis (the
    ≥2x-over-host-loop bar is measured at regeneration time and recorded
    in CHANGES.md — rounds/sec is hardware-dependent, so the artifact pins
    presence + internal ordering, not absolute numbers). The sharded
    ordering is pinned at n=100, not n_max: with 8 *forced* host devices
    the n=1000 ordering depends on the physical core count of the machine
    that regenerated the artifact (on a single core the shard dispatch is
    pure overhead at large n), so the large-n cells pin positivity only."""
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "BENCH_engine.json"
    )
    if not os.path.exists(path):
        pytest.skip("no committed BENCH_engine.json")
    with open(path) as f:
        report = json.load(f)
    assert report["schema_version"] == 6
    assert "fedecado" in report["algorithms"]
    assert "event" in report["backends"]
    assert "event_buffered" in report["backends"]
    rps = {
        (r["backend"], r["n_clients"]): r["rounds_per_sec"]
        for r in report["results"]
        if r["algorithm"] == "fedecado" and not r.get("client_cache")
    }
    n_max = max(report["sizes"])
    n_pin = 100 if 100 in report["sizes"] else n_max
    assert rps[("sharded", n_pin)] >= rps[("vectorized", n_pin)]
    for n in report["sizes"]:
        assert rps[("event", n)] > 0
        # buffered rows exist at every size on the fedecado axis
        assert rps[("event_buffered", n)] > 0
    # jit-residency witness: the event scheduler must beat the per-client
    # sequential dispatch at scale (the old host-loop event backend ran at
    # roughly sequential speed — 2.9 vs 4.1 rounds/sec at n=100)
    assert rps[("event", n_max)] > rps[("sequential", n_max)]
    # heavy-traffic buffered cell: n=10^4 sustained throughput with the
    # bounded max-staleness witness (staleness must not grow with the run)
    ht = report["heavy_traffic"]
    assert ht["n_clients"] == 10_000
    assert ht["rounds_per_sec"] > 0
    assert 0 <= ht["max_stale"] < ht["rounds"]
    # sparse client-cache cells (schema v6): the million-client-engine
    # acceptance witnesses. Both cells keep state_rows strictly under the
    # population; the n=10^5 q=0.001 cell must sit >= 50x below its
    # materialized projection AND clear the dense n=1000 sharded
    # rounds/sec — at fixed cohort work the population size may no longer
    # tax the round.
    sparse = {
        (r["n_clients"], r["participation"]): r
        for r in report["results"] if r.get("client_cache")
    }
    assert (10_000, 0.01) in sparse and (100_000, 0.001) in sparse
    for r in sparse.values():
        assert r["algorithm"] == "fedecado" and r["backend"] == "sharded"
        assert r["rounds_per_sec"] > 0
        assert 0 < r["state_rows"] < r["n_clients"]
        assert r["peak_state_bytes"] < r["materialized_state_bytes"]
    big = sparse[(100_000, 0.001)]
    assert big["peak_state_bytes"] * 50 <= big["materialized_state_bytes"]
    assert big["state_rows"] * 50 <= big["n_clients"]
    if ("sharded", 1000) in rps:
        assert big["rounds_per_sec"] >= rps[("sharded", 1000)]

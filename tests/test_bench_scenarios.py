"""Sweep-runner harness smoke test: ``launch/sweep.py`` must run its
algorithms × scenarios × seeds matrix end-to-end and persist a
``BENCH_scenarios.json`` whose schema downstream tooling can rely on. The
schema is pinned here — bump ``SCENARIO_BENCH_SCHEMA_VERSION`` in
src/repro/launch/sweep.py when it changes, and update this test in the same
PR.

Schema v1: accuracy matrix rows (algorithm × scenario × seed × backend ->
acc/final_loss/wall_s) + a sequential/vectorized/sharded equivalence grid
(max_abs_err of loss histories vs the sequential oracle at rtol 1e-6).
Schema v2: accuracy rows gain a "telemetry" block — the run-level summary
of the shared per-round telemetry schema (repro/obs: substeps/waves/
staleness/dropped counters + accepted-Δt envelope, DESIGN.md §9).

The committed repo artifact additionally witnesses the acceptance bar:
>= 6 scenarios, every registered algorithm, all three backends in the
equivalence grid (including an availability-trace and a feature-shift
scenario), and FedECADO's accuracy ordering vs FedProx/FedNova on the
paper's Dirichlet(0.1) scenario.
"""
import json
import os

import numpy as np
import pytest

from repro.launch import sweep


def test_sweep_runs_and_json_schema_is_stable(tmp_path):
    json_path = tmp_path / "BENCH_scenarios.json"
    report = sweep.run_sweep(
        algorithms=("fedecado", "fednova"),
        scenarios=("dirichlet01", "feature-shift", "diurnal"),
        seeds=1, rounds=2, clients=6, participation=0.5, batch_size=8,
        steps_per_epoch=1,
        equiv_scenarios=("feature-shift", "diurnal"), equiv_rounds=2,
        json_path=str(json_path), table=False,
    )

    assert json_path.exists()
    with open(json_path) as f:
        persisted = json.load(f)
    assert persisted == report

    # -- schema: top level ------------------------------------------------
    assert (
        persisted["schema_version"]
        == sweep.SCENARIO_BENCH_SCHEMA_VERSION
        == 2
    )
    assert persisted["benchmark"] == "scenarios"
    assert persisted["rounds"] == 2
    assert persisted["seeds"] == [0]
    assert persisted["algorithms"] == ["fedecado", "fednova"]
    assert persisted["scenarios"] == ["dirichlet01", "feature-shift", "diurnal"]
    assert persisted["backend"] == "vectorized"
    assert isinstance(persisted["config"], dict)
    eq_cfg = persisted["equivalence_config"]
    assert eq_cfg["backends"] == ["sequential", "vectorized", "sharded"]
    assert eq_cfg["scenarios"] == ["feature-shift", "diurnal"]
    assert eq_cfg["rtol"] == 1e-6

    # -- schema: accuracy rows — one per (algorithm × scenario × seed) ----
    rows = persisted["results"]
    seen = set()
    for row in rows:
        assert set(row) == {
            "algorithm", "scenario", "seed", "backend",
            "acc", "final_loss", "wall_s", "telemetry",
        }
        assert row["algorithm"] in persisted["algorithms"]
        assert row["scenario"] in persisted["scenarios"]
        assert row["backend"] == persisted["backend"]
        assert 0.0 <= row["acc"] <= 1.0
        assert np.isfinite(row["final_loss"])
        tel = row["telemetry"]
        assert tel["rounds"] == persisted["rounds"]
        if row["algorithm"] == "fedecado":
            # flow algorithms report adaptive-BE solver effort per cell
            assert tel["substeps_per_round"] > 0
        seen.add((row["algorithm"], row["scenario"], row["seed"]))
    assert seen == {
        (a, s, sd)
        for a in persisted["algorithms"]
        for s in persisted["scenarios"]
        for sd in persisted["seeds"]
    }

    # -- schema: equivalence rows — non-sequential backends vs oracle -----
    eq = persisted["equivalence"]
    seen_eq = set()
    for row in eq:
        assert set(row) == {
            "algorithm", "scenario", "backend", "max_abs_err", "ok",
        }
        assert row["ok"] is True, (
            f"{row['scenario']}/{row['algorithm']}/{row['backend']} "
            f"diverged from the sequential oracle by {row['max_abs_err']}"
        )
        seen_eq.add((row["algorithm"], row["scenario"], row["backend"]))
    assert seen_eq == {
        (a, s, b)
        for a in persisted["algorithms"]
        for s in eq_cfg["scenarios"]
        for b in ("vectorized", "sharded")
    }


def test_repo_bench_artifact_matches_schema_and_witnesses_claims():
    """The committed BENCH_scenarios.json must parse under schema v2 and
    witness the acceptance criteria: every registered algorithm × >= 6
    scenarios, three-backend equivalence including an availability-trace
    and a feature-shift scenario, and FedECADO >= FedProx/FedNova on the
    paper's Dirichlet(0.1) regime."""
    from repro.fed.algorithms import available_algorithms
    from repro.scenarios import get_scenario

    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "BENCH_scenarios.json"
    )
    if not os.path.exists(path):
        pytest.skip("no committed BENCH_scenarios.json")
    with open(path) as f:
        report = json.load(f)

    assert report["schema_version"] == 2
    assert set(available_algorithms()) <= set(report["algorithms"])
    assert len(report["scenarios"]) >= 6
    assert "dirichlet01" in report["scenarios"]

    # equivalence grid ran all registered algorithms on all three backends,
    # on >= 6 scenarios including one availability trace + one feature shift
    eq_cfg = report["equivalence_config"]
    assert eq_cfg["backends"] == ["sequential", "vectorized", "sharded"]
    assert len(eq_cfg["scenarios"]) >= 6
    assert any(
        get_scenario(s).availability is not None for s in eq_cfg["scenarios"]
    )
    assert any(
        get_scenario(s).feature_shift is not None for s in eq_cfg["scenarios"]
    )
    assert eq_cfg["rtol"] <= 1e-6
    eq_algs = {r["algorithm"] for r in report["equivalence"]}
    assert set(report["algorithms"]) <= eq_algs
    assert all(r["ok"] for r in report["equivalence"])

    # the paper's §5.1 ordering on Dir(0.1): FedECADO above the baselines
    def mean_acc(alg):
        accs = [
            r["acc"] for r in report["results"]
            if r["scenario"] == "dirichlet01" and r["algorithm"] == alg
        ]
        assert accs, f"no dirichlet01 rows for {alg}"
        return float(np.mean(accs))

    assert mean_acc("fedecado") >= mean_acc("fedprox")
    assert mean_acc("fedecado") >= mean_acc("fednova")

"""End-to-end driver: FEDERATED language-model training of a reduced
transformer (the assigned-arch substrate) with FedECADO — the paper's
Algorithm 2 applied to a real model definition, a few hundred client steps.

  PYTHONPATH=src python examples/fed_lm_training.py --arch smollm-360m \
      --rounds 30 --clients 8

Each client holds a slice of a synthetic token stream (Zipf + planted bigram
successor structure); FedECADO's flow variables are full parameter-shaped
pytrees of the transformer.
"""
import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import (
    ConsensusConfig,
    hutchinson_scalar,
    init_server_state,
    server_round,
    set_gains,
)
from repro.data import make_lm_stream
from repro.fed.client import fedecado_client_sim
from repro.models import init_params, loss_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=4, help="client steps/round")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"arch={args.arch} reduced params={n_params/1e6:.2f}M")

    lf = lambda p, b: loss_fn(p, b, cfg)

    # one stream per client with a client-specific planted successor table
    # (non-IID in sequence distribution)
    streams = [
        make_lm_stream(1 << 14, vocab=cfg.vocab_size, seed=100 + i)
        for i in range(args.clients)
    ]
    rng = np.random.RandomState(args.seed)

    def client_batches(i, n_steps):
        s = streams[i]
        starts = rng.randint(0, len(s) - args.seq_len - 1, (n_steps, args.batch_size))
        toks = np.stack(
            [[s[a : a + args.seq_len] for a in row] for row in starts]
        )
        return {"tokens": jnp.asarray(toks)}

    ccfg = ConsensusConfig(L=1.0, delta=1e-3, dt_init=0.05, max_substeps=32)
    state = init_server_state(params, args.clients, ccfg.dt_init)

    # precompute Ḡ_th per client (eq. 42, Hutchinson-estimated)
    hfn = jax.jit(lambda p, b, k: hutchinson_scalar(lf, p, b, k, 1))
    gains = []
    p_hat = 1.0  # equal-size client datasets here
    for i in range(args.clients):
        probe = jax.tree.map(lambda t: t[0], client_batches(i, 1))  # one batch
        h = float(hfn(state.x_c, probe, jax.random.fold_in(key, i)))
        gains.append(1.0 / (1.0 / 0.05 + p_hat * max(h, 0.0)))
    state = set_gains(state, jnp.asarray(gains, jnp.float32))
    print("gains (g_inv):", [round(g, 4) for g in gains])

    A = max(1, int(args.participation * args.clients))
    client_fn = jax.jit(
        lambda x0, I, batches, lr: fedecado_client_sim(lf, x0, I, batches, lr, 1.0)
    )
    round_fn = jax.jit(lambda s, x, T, i: server_round(s, x, T, i, ccfg))

    t0 = time.time()
    for rnd in range(args.rounds):
        idx = np.sort(rng.choice(args.clients, A, replace=False))
        lrs = rng.uniform(5e-3, 2e-2, A)
        eps = rng.randint(1, 4, A)
        xs, Ts, losses = [], [], []
        for j, i in enumerate(idx):
            n_steps = int(eps[j]) * args.steps
            I_i = jax.tree.map(lambda l: l[int(i)], state.I)
            out = client_fn(state.x_c, I_i, client_batches(int(i), n_steps), float(lrs[j]))
            xs.append(out.x_new)
            Ts.append(float(out.T))
            losses.append(float(out.loss))
        x_new_a = jax.tree.map(lambda *t: jnp.stack(t), *xs)
        state, stats = round_fn(
            state, x_new_a, jnp.asarray(Ts, jnp.float32), jnp.asarray(idx, jnp.int32)
        )
        if rnd % 5 == 0 or rnd == args.rounds - 1:
            print(
                f"round {rnd:3d}  client-loss {np.mean(losses):.4f}  "
                f"substeps {int(stats.n_substeps)}  dt {float(stats.final_dt):.4f}  "
                f"({time.time()-t0:.0f}s)",
                flush=True,
            )
    print("done")


if __name__ == "__main__":
    main()

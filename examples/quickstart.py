"""Quickstart: FedECADO vs FedAvg on a synthetic non-IID problem in ~1 min.

  PYTHONPATH=src python examples/quickstart.py

Builds a 10-class synthetic dataset, partitions it across 20 clients with a
Dirichlet(0.1) skew, trains a small MLP with both algorithms under
heterogeneous client compute, and prints the accuracy trajectories.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig, HeteroConfig, dirichlet_partition


def main():
    data = make_classification(2048, dim=32, n_classes=10, seed=0)
    parts = dirichlet_partition(data["y"], 20, alpha=0.1, seed=0)
    print(f"client sizes: {[len(p) for p in parts]}")

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params0 = {
        "w0": jax.random.normal(k1, (32, 48)) / np.sqrt(32),
        "b0": jnp.zeros((48,)),
        "w1": jax.random.normal(k2, (48, 10)) / np.sqrt(48),
        "b1": jnp.zeros((10,)),
    }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1))

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    for alg in ("fedecado", "fedavg"):
        cfg = FedSimConfig(
            algorithm=alg, n_clients=20, participation=0.25, rounds=40,
            batch_size=32, steps_per_epoch=3,
            hetero=HeteroConfig(1e-3, 1e-2, 1, 5),
            seed=1, eval_every=10,
        )
        sim = FedSim(loss_fn, params0, data, parts, cfg, eval_fn)
        hist = sim.run()
        traj = " ".join(
            f"r{r}:{m['acc']:.3f}"
            for r, m in zip(hist.eval_rounds, hist.metrics)
        )
        print(f"{alg:10s} {traj}")


if __name__ == "__main__":
    main()

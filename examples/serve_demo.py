"""Serving demo: prefill + batched decode on a reduced assigned-arch config —
the same serve_step the dry-run lowers for decode_32k / long_500k.

  PYTHONPATH=src python examples/serve_demo.py --arch mixtral-8x7b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main()

"""Paper §5.2 experiment at reduced scale: heterogeneous client computation
via the scenario registry — the default ``hetero-devices`` scenario keeps
IID data and draws each client's (lr_i, e_i) from its pinned device tier
(paper eqs. 43-44, stratified). Isolates the multi-rate Γ-synchronized
integration (gains are identical under IID, so any win is attributable to
the multi-rate machinery alone). ``--scenario`` swaps in any registered
regime (e.g. ``diurnal`` adds an availability trace, ``flaky-dropout``
mid-round dropout) with zero code changes.

  PYTHONPATH=src python examples/heterogeneous_clients.py --rounds 40
  PYTHONPATH=src python examples/heterogeneous_clients.py \
      --scenario flaky-dropout --backend event --event-horizon 0.6
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig
from repro.fed.algorithms import (
    available_algorithms,
    comparison_algorithms,
    get_algorithm,
)
from repro.scenarios import available_scenarios, get_scenario


def main():
    # every registered algorithm that supports partial participation rides
    # along automatically (so a newly registered plugin shows up in the
    # Table-2-style comparison with zero edits here)
    default_algs = comparison_algorithms()
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=25)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--scenario", default="hetero-devices", choices=available_scenarios(),
        help="heterogeneity scenario (repro/scenarios registry)",
    )
    ap.add_argument(
        "--algorithms", default=",".join(default_algs),
        help="comma-separated registry names to compare "
        f"(registered: {', '.join(available_algorithms())})",
    )
    ap.add_argument(
        "--backend",
        choices=("sequential", "vectorized", "event", "sharded", "auto"),
        default="vectorized",
        help="execution engine (repro/sim): vectorized = whole cohort in one "
        "dispatch; event = async arrivals with staleness (fedecado only); "
        "sharded = shard_map over every local device with psum consensus "
        "reductions and jit-resident multi-round segments (run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 to see true "
        "multi-device execution on CPU); auto = let the HLO cost model pick "
        "(repro.tune.autotune, decision recorded in the run-log header)",
    )
    ap.add_argument(
        "--event-horizon", type=float, default=0.75,
        help="event backend: quantile of in-flight windows absorbed per round",
    )
    from repro.comm import available_compressors

    ap.add_argument(
        "--compress", choices=available_compressors(), default=None,
        help="lossy uplink compressor (repro/comm registry); combos are "
        "validated against every compared algorithm's capability flags "
        "(e.g. topk is refused when a flow-dynamics algorithm is in the "
        "comparison)",
    )
    ap.add_argument(
        "--compress-level", type=int, default=None,
        help="compressor-specific level (omit for the default; invalid "
        "levels are rejected with the valid set listed)",
    )
    args = ap.parse_args()
    if args.compress_level is not None and args.compress is None:
        ap.error("--compress-level requires --compress (one of: "
                 f"{', '.join(available_compressors())})")

    data = make_classification(2048, dim=32, n_classes=10, seed=0)
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    params0 = {
        "w0": jax.random.normal(k1, (32, 48)) / np.sqrt(32),
        "b0": jnp.zeros((48,)),
        "w1": jax.random.normal(k2, (48, 10)) / np.sqrt(48),
        "b1": jnp.zeros((10,)),
    }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1))

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    scenario = get_scenario(args.scenario)
    algs = [get_algorithm(a).name for a in args.algorithms.split(",") if a]
    if args.compress:
        # fail before any training: level + compressor × algorithm combos
        from repro.comm import check_algorithm, get_compressor

        try:
            get_compressor(args.compress)(args.compress_level)
            for a in algs:
                check_algorithm(args.compress, get_algorithm(a))
        except ValueError as e:
            ap.error(str(e))
    results = {a: [] for a in algs}
    for rep in range(args.repeats):
        for alg in results:
            # the event scheduler only handles flow dynamics — ask the
            # plugin's capability flag instead of matching names
            backend = args.backend
            if backend == "event" and not get_algorithm(alg).has_flow_dynamics:
                backend = "vectorized"
            cfg = FedSimConfig(
                algorithm=alg, n_clients=args.clients, participation=0.2,
                rounds=args.rounds, batch_size=32, steps_per_epoch=3,
                seed=200 + rep, eval_every=args.rounds, scenario=scenario,
                backend=backend, event_horizon=args.event_horizon,
                compress=args.compress, compress_level=args.compress_level,
            )
            sim = FedSim(loss_fn, params0, data, None, cfg, eval_fn)
            hist = sim.run()
            acc = hist.metrics[-1]["acc"]
            results[alg].append(acc)
            wire = ""
            if args.compress:
                from repro.obs import format_bytes

                wire = f"  up={format_bytes(hist.summary()['bytes_up'])}"
            print(f"rep {rep} {alg:10s} acc={acc:.4f}{wire}", flush=True)
            if backend == "event" and rep == 0:
                # make the async behaviour observable: the event backend's
                # per-round shared-schema telemetry (arrivals absorbed,
                # stragglers pending, BE waves, adaptive substeps, busy
                # re-draws dropped from the plan), rendered through the
                # same formatter the launch drivers use
                from repro.obs import format_round_line

                for rec in sim.backend.round_stats:
                    print("    " + format_round_line(rec), flush=True)

    print(f"\n== Table-2-style summary ({scenario.name}: {scenario.axes()}; "
          "mean ± std over device draws) ==")
    for alg, accs in results.items():
        print(f"{alg:10s} {np.mean(accs)*100:5.1f} ({np.std(accs)*100:.1f})")


if __name__ == "__main__":
    main()

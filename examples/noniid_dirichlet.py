"""Paper §5.1 experiment at reduced scale: statistical-skew scenarios from
the repro/scenarios registry (default: the paper's Dir(0.1) label skew),
all comparison algorithms, repeated over multiple partition seeds (paper
Table 1). ``--scenario`` enumerates the scenario registry exactly as
``--algorithm`` CLIs enumerate the algorithm registry; ``--alpha``
overrides the Dirichlet concentration on an ad-hoc spec copy.

  PYTHONPATH=src python examples/noniid_dirichlet.py --repeats 3 --rounds 40
  PYTHONPATH=src python examples/noniid_dirichlet.py --scenario label-shard2
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_classification
from repro.fed import FedSim, FedSimConfig
from repro.fed.algorithms import comparison_algorithms
from repro.scenarios import PartitionSpec, available_scenarios, get_scenario


def build_problem(seed):
    data = make_classification(2048, dim=32, n_classes=10, seed=0)
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    params0 = {
        "w0": jax.random.normal(k1, (32, 48)) / np.sqrt(32),
        "b0": jnp.zeros((48,)),
        "w1": jax.random.normal(k2, (48, 10)) / np.sqrt(48),
        "b1": jnp.zeros((10,)),
    }

    def fwd(p, x):
        return jnp.tanh(x @ p["w0"] + p["b0"]) @ p["w1"] + p["b1"]

    def loss_fn(p, batch):
        lp = jax.nn.log_softmax(fwd(p, batch["x"]))
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None].astype(jnp.int32), -1))

    def eval_fn(p):
        pred = jnp.argmax(fwd(p, jnp.asarray(data["x"])), -1)
        return {"acc": float(jnp.mean(pred == jnp.asarray(data["y"])))}

    return data, params0, loss_fn, eval_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--scenario", default="dirichlet01", choices=available_scenarios(),
        help="heterogeneity scenario (repro/scenarios registry)",
    )
    ap.add_argument(
        "--alpha", type=float, default=None,
        help="override the scenario's Dirichlet alpha (ad-hoc spec copy)",
    )
    ap.add_argument("--clients", type=int, default=25)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    scenario = get_scenario(args.scenario)
    if args.alpha is not None:
        if scenario.partition.kind != "dirichlet":
            raise SystemExit(
                f"--alpha only applies to Dirichlet scenarios; "
                f"{scenario.name!r} partitions by {scenario.partition.kind!r}"
            )
        scenario = dataclasses.replace(
            scenario,
            name=f"{scenario.name}@alpha{args.alpha:g}",
            partition=dataclasses.replace(scenario.partition, alpha=args.alpha),
        )

    results = {a: [] for a in comparison_algorithms()}
    for rep in range(args.repeats):
        data, params0, loss_fn, eval_fn = build_problem(rep)
        for alg in results:
            cfg = FedSimConfig(
                algorithm=alg, n_clients=args.clients, participation=0.2,
                rounds=args.rounds, batch_size=32, steps_per_epoch=3,
                seed=100 + rep, eval_every=args.rounds, scenario=scenario,
            )
            sim = FedSim(loss_fn, params0, data, None, cfg, eval_fn)
            hist = sim.run()
            acc = hist.metrics[-1]["acc"]
            results[alg].append(acc)
            print(f"rep {rep} {alg:10s} acc={acc:.4f}", flush=True)

    print(f"\n== Table-1-style summary ({scenario.name}: {scenario.axes()}; "
          "mean ± std over partitions) ==")
    for alg, accs in results.items():
        print(f"{alg:10s} {np.mean(accs)*100:5.1f} ({np.std(accs)*100:.1f})")


if __name__ == "__main__":
    main()
